//! Suite run manifests (DESIGN.md §10): one
//! `runs/suite/<id>/manifest.json` per suite invocation, recording the
//! plan set, each plan's declared-spec hash and its completion state.
//!
//! Resume semantics: a rerun loads the manifest, and any plan whose
//! entry is `done` with a matching spec hash (same grid, same config)
//! is *restored* — its specs never reach the solver and its stored
//! markdown artifact is re-printed. Plans whose spec hash changed (a
//! config knob or grid edit) re-run from whatever the operating-point
//! cache still answers. A manifest whose `config_key` disagrees with
//! the session is ignored wholesale.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::Result;

use crate::util::json::{obj, Json};

pub const MANIFEST_VERSION: f64 = 1.0;

/// Per-plan completion record.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanEntry {
    /// Hash over the plan's sorted declared spec cache keys (empty
    /// grid hashes too — it pins "this plan declared nothing").
    pub spec_hash: String,
    /// Declared specs at completion time (reporting only).
    pub n_specs: usize,
    /// True once the plan's report was rendered and emitted.
    pub done: bool,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SuiteManifest {
    pub suite_id: String,
    /// Fingerprint of every config knob that can change a plan's
    /// output; a mismatch invalidates the whole manifest.
    pub config_key: String,
    pub plans: BTreeMap<String, PlanEntry>,
}

impl SuiteManifest {
    pub fn new(suite_id: &str, config_key: &str) -> SuiteManifest {
        SuiteManifest {
            suite_id: suite_id.to_string(),
            config_key: config_key.to_string(),
            plans: BTreeMap::new(),
        }
    }

    /// True when `plan` completed under exactly this spec hash.
    pub fn is_done(&self, plan: &str, spec_hash: &str) -> bool {
        self.plans
            .get(plan)
            .map(|e| e.done && e.spec_hash == spec_hash)
            .unwrap_or(false)
    }

    pub fn mark_done(
        &mut self,
        plan: &str,
        spec_hash: &str,
        n_specs: usize,
    ) {
        self.plans.insert(
            plan.to_string(),
            PlanEntry {
                spec_hash: spec_hash.to_string(),
                n_specs,
                done: true,
            },
        );
    }

    /// Load from disk; `None` on missing, corrupt (including
    /// wrong-typed fields), version-mismatched or foreign-config
    /// manifests (all treated as "start fresh").
    pub fn load(path: &Path, config_key: &str)
        -> Option<SuiteManifest> {
        let text = fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        let str_of = |v: &Json| -> Option<String> {
            match v {
                Json::Str(s) => Some(s.clone()),
                _ => None,
            }
        };
        let num_of = |v: &Json| -> Option<f64> {
            match v {
                Json::Num(n) => Some(*n),
                _ => None,
            }
        };
        if num_of(j.get("version")?)? != MANIFEST_VERSION {
            return None;
        }
        let mut m = SuiteManifest::new(
            &str_of(j.get("suite_id")?)?,
            &str_of(j.get("config_key")?)?,
        );
        if m.config_key != config_key {
            return None;
        }
        let plans = match j.get("plans")? {
            Json::Obj(map) => map,
            _ => return None,
        };
        for (name, e) in plans {
            m.plans.insert(
                name.clone(),
                PlanEntry {
                    spec_hash: str_of(e.get("spec_hash")?)?,
                    n_specs: num_of(e.get("n_specs")?)? as usize,
                    done: match e.get("done")? {
                        Json::Bool(b) => *b,
                        _ => return None,
                    },
                },
            );
        }
        Some(m)
    }

    /// Write atomically (tmp + rename) so a kill mid-write never
    /// leaves a truncated manifest behind.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let plans = Json::Obj(
            self.plans
                .iter()
                .map(|(name, e)| {
                    (
                        name.clone(),
                        obj(vec![
                            (
                                "spec_hash",
                                Json::Str(e.spec_hash.clone()),
                            ),
                            ("n_specs", Json::Num(e.n_specs as f64)),
                            ("done", Json::Bool(e.done)),
                        ]),
                    )
                })
                .collect(),
        );
        let j = obj(vec![
            ("version", Json::Num(MANIFEST_VERSION)),
            ("suite_id", Json::Str(self.suite_id.clone())),
            ("config_key", Json::Str(self.config_key.clone())),
            ("plans", plans),
        ]);
        let tmp = path.with_extension("json.tmp");
        fs::write(&tmp, j.to_string())?;
        fs::rename(tmp, path)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "capmin_manifest_{tag}_{}",
            std::process::id()
        ))
    }

    #[test]
    fn roundtrip_and_resume_checks() {
        let dir = tmp("rt");
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("manifest.json");
        let mut m = SuiteManifest::new("abc12345", "cfg1");
        m.mark_done("fig8", "deadbeef00000000", 24);
        m.save(&path).unwrap();

        let back = SuiteManifest::load(&path, "cfg1").unwrap();
        assert_eq!(back, m);
        assert!(back.is_done("fig8", "deadbeef00000000"));
        // spec-hash drift or unknown plans are not done
        assert!(!back.is_done("fig8", "0000000000000000"));
        assert!(!back.is_done("fig9", "deadbeef00000000"));
        // a different config key invalidates the file
        assert!(SuiteManifest::load(&path, "cfg2").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_or_missing_is_fresh() {
        let dir = tmp("bad");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        assert!(SuiteManifest::load(&path, "cfg").is_none());
        fs::write(&path, "{truncated").unwrap();
        assert!(SuiteManifest::load(&path, "cfg").is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}
