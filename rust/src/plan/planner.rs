//! The suite planner (DESIGN.md §10): collect plans, deduplicate their
//! declared specs globally, resolve the union through one
//! `DesignSession::query_many` batch, then reduce/render/emit each
//! plan in order — streaming progress and checkpointing a resume
//! manifest after every completed plan.

use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::config::ExperimentConfig;
use crate::session::{
    DesignSession, OperatingPoint, OperatingPointSpec, SessionStats,
};
use crate::util::hash::hex16;

use super::manifest::SuiteManifest;
use super::report::{self, Emit};
use super::ExperimentPlan;

/// Options of one `suite` invocation.
pub struct SuiteOptions {
    /// Extra artifact formats under the suite dir (markdown is always
    /// written there; `--emit json|csv|md`).
    pub emit: Vec<Emit>,
    /// Override the derived suite id (`--suite-id`).
    pub suite_id: Option<String>,
    /// Load the manifest and skip completed plans (`--no-resume`
    /// disables).
    pub resume: bool,
}

impl Default for SuiteOptions {
    fn default() -> SuiteOptions {
        SuiteOptions {
            emit: vec![],
            suite_id: None,
            resume: true,
        }
    }
}

/// What a suite run did — tests assert resume behaviour through this.
pub struct SuiteOutcome {
    pub suite_id: String,
    /// `runs/suite/<id>/`.
    pub dir: PathBuf,
    /// Plans reduced and rendered in this invocation.
    pub completed: Vec<String>,
    /// Plans restored from the manifest without touching the solver.
    pub restored: Vec<String>,
}

/// Fingerprint of every config knob that can change a plan's output
/// (mirrors the spec cache-key material plus the sweep-shape knobs);
/// a drift invalidates suite manifests wholesale. Dataset selection
/// is *per plan* ([`ExperimentPlan::scope`], folded into each plan's
/// spec hash) so growing the plan set or reusing a pinned suite id
/// never invalidates unrelated completed plans.
fn config_key(cfg: &ExperimentConfig) -> String {
    let ks: Vec<String> =
        cfg.ks.iter().map(|k| k.to_string()).collect();
    // fast mode keys on its tolerance too; paper/analytic ignore it
    let mode = if cfg.mc_mode == "fast" {
        format!("fast@{:e}", cfg.mc_tol)
    } else {
        cfg.mc_mode.clone()
    };
    hex16(
        // v3: Monte-Carlo solve *mode* became key material (the
        // paper/fast/analytic engines agree statistically, not
        // bitwise); v2 was the chunked-draw schedule change — neither
        // era's manifests may restore across the boundary
        format!(
            "v3|steps{}|lr{:e}|lrh{}|tl{}|el{}|hl{}|\
             sigma{:e}|mc{}|mode{}|ks{}|seeds{}|engine{}|be{}|seed{}",
            cfg.train_steps,
            cfg.lr0,
            cfg.lr_halve_every,
            cfg.train_limit,
            cfg.eval_limit,
            cfg.hist_limit,
            cfg.sigma_rel,
            cfg.mc_samples,
            mode,
            ks.join(","),
            cfg.n_seeds,
            cfg.engine,
            crate::backend::BackendKind::resolve(cfg),
            cfg.seed,
        )
        .as_bytes(),
    )
}

/// Hash of a plan's declared grid (sorted full cache keys) plus its
/// [`ExperimentPlan::scope`], so the manifest notices any config,
/// grid *or dataset-selection* drift per plan — an empty-grid plan
/// like fig1/fig5 hashes differently across `--dataset` selections
/// even though its grid is always empty.
fn spec_hash(
    specs: &[OperatingPointSpec],
    cfg: &ExperimentConfig,
    scope: &str,
) -> String {
    let mut keys: Vec<String> =
        specs.iter().map(|s| s.cache_key(cfg)).collect();
    keys.sort();
    hex16(format!("{}|scope:{scope}", keys.join("|")).as_bytes())
}

/// Run one plan directly (the single-figure CLI commands): resolve its
/// grid in one batch, render markdown to stdout, persist its series,
/// and — when `--emit` formats are requested — write the artifacts to
/// `<run-dir>/reports/<plan>.<ext>` (the suite has its own per-run
/// directory instead).
pub fn run_one(
    session: &DesignSession,
    plan: &dyn ExperimentPlan,
    emit: &[Emit],
) -> Result<()> {
    let specs = plan.specs(session.config());
    let points = session.query_many(&specs)?;
    let rep = plan.reduce(session, &points)?;
    print!("{}", report::render_md(&rep));
    report::persist_series(session.store(), &rep)?;
    if !emit.is_empty() {
        let dir = session.store().path("reports");
        fs::create_dir_all(&dir)?;
        for fmt in emit {
            let path =
                dir.join(format!("{}.{}", plan.name(), fmt.ext()));
            fs::write(&path, rep.render(*fmt))?;
            println!("[plan {}] wrote {}", plan.name(), path.display());
        }
    }
    Ok(())
}

pub struct Planner<'s> {
    session: &'s DesignSession,
    plans: Vec<Box<dyn ExperimentPlan>>,
}

impl<'s> Planner<'s> {
    pub fn new(session: &'s DesignSession) -> Planner<'s> {
        Planner {
            session,
            plans: vec![],
        }
    }

    pub fn add(&mut self, plan: Box<dyn ExperimentPlan>) -> &mut Self {
        self.plans.push(plan);
        self
    }

    pub fn n_plans(&self) -> usize {
        self.plans.len()
    }

    /// Run every added plan as one deduplicated, resumable suite.
    pub fn run_suite(&self, opts: &SuiteOptions)
        -> Result<SuiteOutcome> {
        let t0 = Instant::now();
        let cfg = self.session.config();
        let ckey = config_key(cfg);

        // 1. declare: every plan's grid + its resume hash (grid keys
        // + the plan's dataset scope)
        let declared: Vec<Vec<OperatingPointSpec>> =
            self.plans.iter().map(|p| p.specs(cfg)).collect();
        let hashes: Vec<String> = self
            .plans
            .iter()
            .zip(&declared)
            .map(|(p, s)| spec_hash(s, cfg, &p.scope()))
            .collect();

        let suite_id = opts.suite_id.clone().unwrap_or_else(|| {
            let names: Vec<&str> =
                self.plans.iter().map(|p| p.name()).collect();
            hex16(
                format!(
                    "{ckey}|{}|{}",
                    names.join(","),
                    hashes.join(",")
                )
                .as_bytes(),
            )[..8]
                .to_string()
        });
        let dir = self
            .session
            .store()
            .path(&format!("suite/{suite_id}"));
        fs::create_dir_all(&dir)?;
        let mpath = dir.join("manifest.json");
        let mut manifest = if opts.resume {
            SuiteManifest::load(&mpath, &ckey)
        } else {
            None
        }
        .unwrap_or_else(|| SuiteManifest::new(&suite_id, &ckey));

        let restored_flags: Vec<bool> = self
            .plans
            .iter()
            .zip(&hashes)
            .map(|(p, h)| manifest.is_done(p.name(), h))
            .collect();

        // 2. cross-plan dedup over the plans that still need solving
        let mut union: Vec<OperatingPointSpec> = vec![];
        let mut index_of: HashMap<String, usize> = HashMap::new();
        let mut plan_indices: Vec<Vec<usize>> = vec![];
        let mut shared_counts: Vec<usize> = vec![];
        for (pi, specs) in declared.iter().enumerate() {
            if restored_flags[pi] {
                plan_indices.push(vec![]);
                shared_counts.push(0);
                continue;
            }
            let mut idxs = Vec::with_capacity(specs.len());
            let mut shared = 0usize;
            for s in specs {
                let key = s.cache_key(cfg);
                match index_of.get(&key) {
                    Some(&i) => {
                        shared += 1;
                        idxs.push(i);
                    }
                    None => {
                        union.push(*s);
                        index_of.insert(key, union.len() - 1);
                        idxs.push(union.len() - 1);
                    }
                }
            }
            plan_indices.push(idxs);
            shared_counts.push(shared);
        }

        let total_declared: usize =
            declared.iter().map(|s| s.len()).sum();
        let n_restored =
            restored_flags.iter().filter(|&&r| r).count();
        println!(
            "[suite {suite_id}] {} plans | {} specs declared | {} \
             unique after cross-plan dedup | {} restored from manifest",
            self.plans.len(),
            total_declared,
            union.len(),
            n_restored,
        );
        for (pi, plan) in self.plans.iter().enumerate() {
            if restored_flags[pi] {
                println!(
                    "[plan {}] restored ({} specs solved in an \
                     earlier run)",
                    plan.name(),
                    manifest
                        .plans
                        .get(plan.name())
                        .map(|e| e.n_specs)
                        .unwrap_or(0),
                );
            } else {
                println!(
                    "[plan {}] {} specs ({} shared with earlier plans)",
                    plan.name(),
                    declared[pi].len(),
                    shared_counts[pi],
                );
            }
        }

        // 3. one global solve for the whole suite
        if !union.is_empty() {
            println!(
                "[suite {suite_id}] solving {} unique operating \
                 points on {} threads...",
                union.len(),
                self.session.threads(),
            );
        }
        let points = self.session.query_many(&union)?;

        // 4. reduce, render, emit and checkpoint each plan in order
        let mut completed = vec![];
        let mut restored = vec![];
        for (pi, plan) in self.plans.iter().enumerate() {
            let md_path = dir.join(format!("{}.md", plan.name()));
            if restored_flags[pi] {
                match fs::read_to_string(&md_path) {
                    Ok(text) => print!("{text}"),
                    Err(_) => println!(
                        "[plan {}] done in an earlier run (no stored \
                         markdown to re-print)",
                        plan.name(),
                    ),
                }
                // a restored plan is not re-reduced, so a format
                // requested only on this rerun can't be produced —
                // say so instead of silently skipping it
                for fmt in &opts.emit {
                    if *fmt != Emit::Md
                        && !dir
                            .join(format!(
                                "{}.{}",
                                plan.name(),
                                fmt.ext()
                            ))
                            .exists()
                    {
                        println!(
                            "[plan {}] restored without a .{} \
                             artifact — rerun with --no-resume to \
                             emit it",
                            plan.name(),
                            fmt.ext(),
                        );
                    }
                }
                restored.push(plan.name().to_string());
                continue;
            }
            let plan_points: Vec<Arc<OperatingPoint>> = plan_indices
                [pi]
                .iter()
                .map(|&i| points[i].clone())
                .collect();
            let rep = plan.reduce(self.session, &plan_points)?;
            let md = report::render_md(&rep);
            print!("{md}");
            fs::write(&md_path, &md)?;
            for fmt in &opts.emit {
                if *fmt == Emit::Md {
                    continue; // always written above
                }
                fs::write(
                    dir.join(format!(
                        "{}.{}",
                        plan.name(),
                        fmt.ext()
                    )),
                    rep.render(*fmt),
                )?;
            }
            report::persist_series(self.session.store(), &rep)?;
            manifest.mark_done(
                plan.name(),
                &hashes[pi],
                declared[pi].len(),
            );
            manifest.save(&mpath)?;
            completed.push(plan.name().to_string());
        }

        // 5. aggregate session stats footer: makes the cross-plan
        // dedup observable at exit
        println!(
            "{}",
            stats_footer(
                &self.session.stats(),
                t0.elapsed().as_secs_f64(),
            )
        );
        println!("[suite {suite_id}] artifacts: {}", dir.display());

        Ok(SuiteOutcome {
            suite_id,
            dir,
            completed,
            restored,
        })
    }
}

/// The reporter footer `suite` / `all` print at exit.
pub fn stats_footer(s: &SessionStats, wall_s: f64) -> String {
    format!(
        "\nsuite stats: {} queries | {} memory hits | {} disk hits | \
         {} batch-deduped | {} solves | {} evals | hit rate {:.1}% | \
         {:.1}s wall",
        s.queries,
        s.mem_hits,
        s.disk_hits,
        s.deduped,
        s.solves,
        s.evals,
        100.0 * s.hit_rate(),
        wall_s,
    )
}
