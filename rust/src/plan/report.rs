//! The unified reporter (DESIGN.md §10): every experiment plan reduces
//! to one typed [`Report`] — headings, notes, tables and numeric
//! series — and this module renders it once per output surface:
//! markdown to stdout (and `<suite-dir>/<plan>.md`), plus optional
//! `--emit json|csv` artifacts under the suite run directory. Numeric
//! series are additionally persisted through
//! [`crate::coordinator::report::Report::save_series`] so the
//! pre-plan-engine `runs/results_*.json` consumers keep working.

use anyhow::Result;

use crate::util::json::{arr_f64, fmt_num, obj, Json};
use crate::util::table::Table;

/// Artifact formats of `--emit` (markdown is always written to the
/// suite dir so resumed runs can re-print completed plans).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Emit {
    Md,
    Json,
    Csv,
}

/// Valid `--emit` values, in the order the error message lists them.
pub const EMIT_CHOICES: &[&str] = &["md", "json", "csv"];

impl Emit {
    pub fn from_name(name: &str) -> Option<Emit> {
        match name {
            "md" => Some(Emit::Md),
            "json" => Some(Emit::Json),
            "csv" => Some(Emit::Csv),
            _ => None,
        }
    }

    pub fn ext(&self) -> &'static str {
        match self {
            Emit::Md => "md",
            Emit::Json => "json",
            Emit::Csv => "csv",
        }
    }
}

/// One renderable block of a plan's report.
pub enum Section {
    /// A sub-heading (per-dataset block, ablation part, ...).
    Heading(String),
    /// Free-form note lines (the old drivers' trailing `println!`s).
    Text(String),
    /// A paper-style table; `title` may be empty.
    Table { title: String, table: Table },
    /// A named numeric series (figure plot data). Persisted as
    /// `runs/results_<name>.json` exactly like the pre-plan drivers.
    Series {
        name: String,
        meta: Vec<(String, Json)>,
        columns: Vec<(String, Vec<f64>)>,
    },
}

/// A plan's typed result: what `reduce` returns and every renderer
/// consumes.
pub struct Report {
    /// Plan name (artifact file stem).
    pub plan: String,
    /// Human title (top-level markdown heading).
    pub title: String,
    pub sections: Vec<Section>,
}

impl Report {
    pub fn new(plan: &str, title: &str) -> Report {
        Report {
            plan: plan.to_string(),
            title: title.to_string(),
            sections: vec![],
        }
    }

    pub fn heading<S: Into<String>>(&mut self, s: S) -> &mut Self {
        self.sections.push(Section::Heading(s.into()));
        self
    }

    pub fn text<S: Into<String>>(&mut self, s: S) -> &mut Self {
        self.sections.push(Section::Text(s.into()));
        self
    }

    pub fn table(&mut self, title: &str, table: Table) -> &mut Self {
        self.sections.push(Section::Table {
            title: title.to_string(),
            table,
        });
        self
    }

    pub fn series(
        &mut self,
        name: &str,
        meta: Vec<(String, Json)>,
        columns: Vec<(String, Vec<f64>)>,
    ) -> &mut Self {
        self.sections.push(Section::Series {
            name: name.to_string(),
            meta,
            columns,
        });
        self
    }

    /// Render in `fmt` (the dispatch the planner and goldens use).
    pub fn render(&self, fmt: Emit) -> String {
        match fmt {
            Emit::Md => render_md(self),
            Emit::Json => render_json(self).to_string(),
            Emit::Csv => render_csv(self),
        }
    }
}

/// `f64` CSV cell formatting: finite values via the JSON writer's
/// shared [`fmt_num`] (so the two artifacts agree by construction);
/// non-finite values print as Rust's `NaN`/`inf` (JSON has null
/// instead).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        fmt_num(v)
    } else {
        format!("{v}")
    }
}

/// Markdown: the stdout surface (tables are pipe tables already).
pub fn render_md(r: &Report) -> String {
    let mut out = format!("\n## {}\n", r.title);
    for s in &r.sections {
        match s {
            Section::Heading(h) => {
                out.push_str(&format!("\n### {h}\n"));
            }
            Section::Text(t) => {
                out.push_str(t);
                out.push('\n');
            }
            Section::Table { title, table } => {
                if !title.is_empty() {
                    out.push_str(&format!("\n**{title}**\n"));
                }
                out.push('\n');
                out.push_str(&table.render());
            }
            Section::Series { name, columns, .. } => {
                let cols: Vec<&str> = columns
                    .iter()
                    .map(|(k, _)| k.as_str())
                    .collect();
                out.push_str(&format!(
                    "*(series `{name}`: {} — saved as \
                     results_{name}.json)*\n",
                    cols.join(", ")
                ));
            }
        }
    }
    out
}

/// JSON: one object per report, sections as a typed array.
pub fn render_json(r: &Report) -> Json {
    let sections: Vec<Json> = r
        .sections
        .iter()
        .map(|s| match s {
            Section::Heading(h) => obj(vec![
                ("type", Json::Str("heading".into())),
                ("text", Json::Str(h.clone())),
            ]),
            Section::Text(t) => obj(vec![
                ("type", Json::Str("text".into())),
                ("text", Json::Str(t.clone())),
            ]),
            Section::Table { title, table } => {
                let headers = Json::Arr(
                    table
                        .headers()
                        .iter()
                        .map(|h| Json::Str(h.clone()))
                        .collect(),
                );
                let rows = Json::Arr(
                    table
                        .rows()
                        .iter()
                        .map(|row| {
                            Json::Arr(
                                row.iter()
                                    .map(|c| Json::Str(c.clone()))
                                    .collect(),
                            )
                        })
                        .collect(),
                );
                obj(vec![
                    ("type", Json::Str("table".into())),
                    ("title", Json::Str(title.clone())),
                    ("headers", headers),
                    ("rows", rows),
                ])
            }
            Section::Series {
                name,
                meta,
                columns,
            } => {
                let meta_j = Json::Obj(
                    meta.iter()
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect(),
                );
                let cols_j = Json::Obj(
                    columns
                        .iter()
                        .map(|(k, v)| (k.clone(), arr_f64(v)))
                        .collect(),
                );
                obj(vec![
                    ("type", Json::Str("series".into())),
                    ("name", Json::Str(name.clone())),
                    ("meta", meta_j),
                    ("columns", cols_j),
                ])
            }
        })
        .collect();
    obj(vec![
        ("plan", Json::Str(r.plan.clone())),
        ("title", Json::Str(r.title.clone())),
        ("sections", Json::Arr(sections)),
    ])
}

/// CSV: tables and series as sections separated by `#` comment lines
/// (headings become comments, free text is dropped).
pub fn render_csv(r: &Report) -> String {
    let mut out = format!("# plan: {}\n# {}\n", r.plan, r.title);
    for s in &r.sections {
        match s {
            Section::Heading(h) => {
                out.push_str(&format!("# {h}\n"));
            }
            Section::Text(_) => {}
            Section::Table { title, table } => {
                if !title.is_empty() {
                    out.push_str(&format!("# table: {title}\n"));
                }
                out.push_str(&table.to_csv());
            }
            Section::Series { name, columns, .. } => {
                out.push_str(&format!("# series: {name}\n"));
                let mut t = Table::new(
                    &columns
                        .iter()
                        .map(|(k, _)| k.as_str())
                        .collect::<Vec<_>>(),
                );
                let n = columns
                    .iter()
                    .map(|(_, v)| v.len())
                    .max()
                    .unwrap_or(0);
                for i in 0..n {
                    t.row(
                        columns
                            .iter()
                            .map(|(_, v)| {
                                v.get(i)
                                    .map(|&x| fmt_f64(x))
                                    .unwrap_or_default()
                            })
                            .collect(),
                    );
                }
                out.push_str(&t.to_csv());
            }
        }
    }
    out
}

/// Persist every series section into the run store as
/// `results_<name>.json` (backwards-compatible with the pre-plan
/// drivers' output files).
pub fn persist_series(
    store: &crate::coordinator::store::Store,
    report: &Report,
) -> Result<()> {
    let rep = crate::coordinator::report::Report::new(store);
    for s in &report.sections {
        if let Section::Series {
            name,
            meta,
            columns,
        } = s
        {
            rep.save_series(
                name,
                meta.iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
                columns
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new("fig0", "Fig. 0: sample");
        r.heading("block one");
        r.text("a note");
        let mut t = Table::new(&["k", "C"]);
        t.row(vec!["32".into(), "135.2 pF".into()]);
        r.table("caps", t);
        r.series(
            "fig0_x",
            vec![("dataset".into(), Json::Str("x".into()))],
            vec![
                ("k".into(), vec![32.0, 16.0]),
                ("acc".into(), vec![0.5, f64::NAN]),
            ],
        );
        r
    }

    #[test]
    fn markdown_carries_every_section() {
        let md = render_md(&sample());
        assert!(md.contains("## Fig. 0: sample"), "{md}");
        assert!(md.contains("### block one"), "{md}");
        assert!(md.contains("a note"), "{md}");
        assert!(md.contains("| k  | C        |"), "{md}");
        assert!(md.contains("series `fig0_x`"), "{md}");
    }

    #[test]
    fn json_is_parseable_and_typed() {
        let j = render_json(&sample());
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(re.req("plan").as_str(), "fig0");
        let sections = re.req("sections").as_arr();
        assert_eq!(sections.len(), 4);
        assert_eq!(sections[2].req("type").as_str(), "table");
        assert_eq!(
            sections[2].req("headers").as_arr()[0].as_str(),
            "k"
        );
        // NaN series entries survive as null -> NaN
        assert!(sections[3].req("columns").req("acc").as_arr()[1]
            .as_f64()
            .is_nan());
    }

    #[test]
    fn csv_zips_series_columns() {
        let csv = render_csv(&sample());
        assert!(csv.contains("# plan: fig0"), "{csv}");
        assert!(csv.contains("# series: fig0_x"), "{csv}");
        assert!(csv.contains("k,acc\n32,0.5\n16,NaN\n"), "{csv}");
        assert!(csv.contains("k,C\n32,135.2 pF\n"), "{csv}");
        // free text stays out of CSV
        assert!(!csv.contains("a note"), "{csv}");
    }

    #[test]
    fn emit_parsing() {
        assert_eq!(Emit::from_name("json"), Some(Emit::Json));
        assert_eq!(Emit::from_name("yaml"), None);
        assert_eq!(Emit::Csv.ext(), "csv");
    }
}
