//! The declarative experiment-plan engine (DESIGN.md §10).
//!
//! Every paper table/figure is an [`ExperimentPlan`]: it *declares* an
//! [`OperatingPointSpec`] grid and supplies a pure reduction from the
//! resolved [`OperatingPoint`]s to a typed [`report::Report`]. The
//! [`planner::Planner`] collects the selected plans, deduplicates
//! identical specs across all of them, resolves the union through one
//! [`DesignSession::query_many`] batch on the shared pool, and hands
//! each plan its slice — so `capmin suite` issues each unique spec to
//! the solver at most once per run, however many figures ask for it.
//!
//! ```text
//!   plans ──declare──▶ specs ──dedup──▶ query_many ──▶ points
//!     │                                                  │
//!     └────────────────reduce◀──────slice per plan───────┘
//!                        │
//!                 Report ─▶ render (md stdout, --emit json|csv)
//!                        └▶ runs/suite/<id>/manifest.json (resume)
//! ```
//!
//! Plan definitions live next to the experiments they replace, in
//! [`crate::experiments`]; this module owns the trait, the registry,
//! the reporter and the manifest/resume machinery.

pub mod manifest;
pub mod planner;
pub mod report;

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::config::ExperimentConfig;
use crate::data::synth::Dataset;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};

use self::report::Report;

/// One experiment as the planner sees it: a name, a declared
/// operating-point grid, and a reduction to a typed report.
pub trait ExperimentPlan {
    /// Stable name — CLI selector, manifest key and artifact stem.
    fn name(&self) -> &'static str;

    /// Human title for the report heading.
    fn title(&self) -> String;

    /// The operating-point grid this experiment needs. May be empty
    /// (registry tables, pure-analog figures); must be deterministic
    /// in `cfg` so resume hashes are stable.
    fn specs(&self, cfg: &ExperimentConfig) -> Vec<OperatingPointSpec>;

    /// Non-config input this plan's output depends on beyond its
    /// declared grid — for dataset-driven plans, the dataset
    /// selection ([`dataset_scope`]). Folded into the suite manifest
    /// identity so an empty-grid plan (fig1, fig5) can never be
    /// "restored" from a run over a different selection.
    fn scope(&self) -> String {
        String::new()
    }

    /// Reduce the resolved points (aligned 1:1 with [`Self::specs`]'s
    /// order) to a report. May consult the session for non-grid data
    /// (F_MAC histograms, registry metadata, ad-hoc backend runs) but
    /// must not mutate it.
    fn reduce(
        &self,
        session: &DesignSession,
        points: &[Arc<OperatingPoint>],
    ) -> Result<Report>;
}

/// Canonical scope string for dataset-driven plans (the
/// [`ExperimentPlan::scope`] of every plan holding a dataset list).
pub fn dataset_scope(datasets: &[Dataset]) -> String {
    datasets
        .iter()
        .map(|d| d.spec().name)
        .collect::<Vec<_>>()
        .join(",")
}

/// Registry order — the order `suite` (and the old `all`) runs in.
pub const PLAN_NAMES: &[&str] = &[
    "table1",
    "table2",
    "fig1",
    "fig3",
    "fig5",
    "fig6",
    "fig8",
    "fig9",
    "headline",
    "ablation",
    "sigma-sweep",
    "pareto",
];

/// Build one plan by registry name over the selected datasets; errors
/// list the valid names (the `--dataset` error style).
pub fn build(name: &str, datasets: &[Dataset])
    -> Result<Box<dyn ExperimentPlan>> {
    use crate::experiments as ex;
    let ds = datasets.to_vec();
    Ok(match name {
        "table1" => Box::new(ex::tables::Table1Plan),
        "table2" => Box::new(ex::tables::Table2Plan),
        "fig1" => Box::new(ex::fig1::Fig1Plan { datasets: ds }),
        "fig3" => Box::new(ex::fig3::Fig3Plan),
        "fig5" => Box::new(ex::fig5::Fig5Plan { datasets: ds }),
        "fig6" => Box::new(ex::fig6::Fig6Plan),
        "fig8" => Box::new(ex::fig8::Fig8Plan { datasets: ds }),
        "fig9" => Box::new(ex::fig9::Fig9Plan { datasets: ds }),
        "headline" => {
            Box::new(ex::headline::HeadlinePlan { datasets: ds })
        }
        "ablation" => {
            Box::new(ex::ablation::AblationPlan { datasets: ds })
        }
        "sigma-sweep" => {
            Box::new(ex::sigma_sweep::SigmaSweepPlan { datasets: ds })
        }
        "pareto" => Box::new(ex::pareto::ParetoPlan { datasets: ds }),
        other => {
            return Err(anyhow!(
                "unknown plan `{other}` (valid choices: {})",
                PLAN_NAMES.join(", ")
            ))
        }
    })
}

/// Every plan in registry order.
pub fn all_plans(datasets: &[Dataset])
    -> Vec<Box<dyn ExperimentPlan>> {
    PLAN_NAMES
        .iter()
        .map(|n| build(n, datasets).expect("registry names are valid"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_every_plan() {
        let ds = vec![Dataset::FashionSyn];
        for name in PLAN_NAMES {
            let p = build(name, &ds).unwrap();
            assert_eq!(p.name(), *name);
        }
        assert_eq!(all_plans(&ds).len(), PLAN_NAMES.len());
    }

    #[test]
    fn unknown_plan_error_lists_choices() {
        let e = build("fig99", &[Dataset::FashionSyn])
            .unwrap_err()
            .to_string();
        assert!(e.contains("fig99"), "{e}");
        assert!(e.contains("sigma-sweep"), "{e}");
    }
}
