//! Capacitor sizing — the heart of CapMin's HW half.
//!
//! Two models (DESIGN.md §4, §6):
//!
//! * `Physics` — first-principles: the smallest C such that every
//!   represented level's spike time lands on a distinct rising clock edge
//!   (paper Sec. II-C). Closed form: adjacent levels M, M+1 are separated
//!   by `C*V0*lambda/i_on * 1/(M(M+1))`, tightest at the window top, so
//!   `C_min = t_clk * i_on * q_hi*(q_hi-1) / (V0*lambda)`; a binary-search
//!   solver over the actual quantized feasibility check cross-validates
//!   the closed form (property-tested).
//!
//! * `PaperFit` — the paper's SPICE-derived C(k) is close to exponential
//!   in the window top (fit through its published points 135.2 pF @ k=32,
//!   12.27 pF @ k=16, 9.6 pF @ k=14). The paper's own first-order
//!   equations do not reproduce its 14x headline (our physics model gives
//!   ~1.8x for the same window; see EXPERIMENTS.md §Fig9 discussion), so
//!   both models are reported side by side.

use super::neuron::SpikeTimeSet;
use super::params::{
    AnalogParams, PAPER_BASELINE_C, PAPER_CAPMIN_C,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapacitorModel {
    Physics,
    PaperFit,
}

pub struct CapacitorSolver {
    pub params: AnalogParams,
    pub model: CapacitorModel,
}

impl CapacitorSolver {
    pub fn new(params: AnalogParams, model: CapacitorModel) -> Self {
        CapacitorSolver { params, model }
    }

    /// Minimum capacitance representing the level window [q_lo, q_hi]
    /// (q_lo >= 1) with distinct quantized spike times.
    pub fn size_for_window(&self, q_lo: usize, q_hi: usize) -> f64 {
        assert!(q_lo >= 1 && q_hi >= q_lo);
        match self.model {
            CapacitorModel::Physics => self.physics_closed_form(q_hi),
            CapacitorModel::PaperFit => paper_fit(q_hi - q_lo + 1),
        }
    }

    /// Closed-form physics sizing (see module docs). Only the window top
    /// matters: lower levels have wider gaps. A hair of margin keeps the
    /// exactly-one-clock-period gap at the tightest pair from colliding
    /// under f64 rounding when a spike time sits on a clock edge.
    fn physics_closed_form(&self, q_hi: usize) -> f64 {
        const MARGIN: f64 = 1.0 + 1e-9;
        let p = &self.params;
        if q_hi == 1 {
            // single level: just needs one clock period to fire
            return MARGIN * p.t_clk() * p.i_on / (p.v0 * p.lambda());
        }
        let m = q_hi as f64;
        MARGIN * p.t_clk() * p.i_on * m * (m - 1.0) / (p.v0 * p.lambda())
    }

    /// Binary-search the smallest feasible C against the real quantized
    /// distinctness check (validates the closed form; also handles
    /// non-contiguous level sets from CapMin-V merges).
    pub fn solve_binary_search(&self, levels: &[usize]) -> f64 {
        let p = &self.params;
        let feasible = |c: f64| {
            SpikeTimeSet::new(p, c, levels.to_vec()).distinct(p)
        };
        let mut hi = 1e-9; // 1 nF upper bracket
        let mut lo = 1e-15;
        assert!(feasible(hi), "1 nF must be feasible for a <= 32");
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if feasible(mid) {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }
}

/// Exponential fit through the paper's published (k, C) points:
/// C(k) = A * exp(gamma * k); gamma from (14, 9.6 pF) and (32, 135.2 pF).
pub fn paper_fit(k: usize) -> f64 {
    let gamma = (PAPER_BASELINE_C / PAPER_CAPMIN_C).ln() / (32.0 - 14.0);
    let a = PAPER_CAPMIN_C / (gamma * 14.0).exp();
    a * (gamma * k as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solver(model: CapacitorModel) -> CapacitorSolver {
        CapacitorSolver::new(AnalogParams::paper_calibrated(), model)
    }

    #[test]
    fn physics_baseline_is_calibrated_to_paper() {
        let s = solver(CapacitorModel::Physics);
        let c = s.size_for_window(1, 32);
        assert!((c - PAPER_BASELINE_C).abs() / PAPER_BASELINE_C < 1e-6);
    }

    #[test]
    fn closed_form_matches_binary_search() {
        let s = solver(CapacitorModel::Physics);
        for (lo, hi) in [(1, 32), (10, 23), (9, 24), (14, 18), (1, 2)] {
            let cf = s.size_for_window(lo, hi);
            let bs = s.solve_binary_search(&(lo..=hi).collect::<Vec<_>>());
            // the closed form guarantees distinctness for any clock
            // phase (ideal gap >= t_clk); the search finds the smallest C
            // whose *particular* quantization stays distinct, which can
            // undercut the guarantee slightly — never exceed it
            assert!(
                bs <= cf * 1.001,
                "search must not exceed closed form: [{lo},{hi}]"
            );
            // opportunistic phase alignment lets the search undercut the
            // guarantee, but never below half (slots would collide)
            assert!(
                bs >= cf * 0.49,
                "window [{lo},{hi}]: closed {cf:.3e} vs search {bs:.3e}"
            );
        }
    }

    #[test]
    fn smaller_windows_need_smaller_caps() {
        let s = solver(CapacitorModel::Physics);
        let c32 = s.size_for_window(1, 32);
        let c14 = s.size_for_window(10, 23);
        assert!(c14 < c32);
        let ratio = c32 / c14;
        assert!(ratio > 1.5 && ratio < 3.0, "physics ratio {ratio}");
    }

    #[test]
    fn paper_fit_reproduces_published_points() {
        assert!((paper_fit(32) - PAPER_BASELINE_C).abs()
            / PAPER_BASELINE_C < 1e-6);
        assert!((paper_fit(14) - PAPER_CAPMIN_C).abs()
            / PAPER_CAPMIN_C < 1e-6);
        // k=16 published as 12.27 pF; the 2-point fit lands within 6%
        let c16 = paper_fit(16);
        assert!((c16 - 12.27e-12).abs() / 12.27e-12 < 0.06, "{c16:.3e}");
    }

    #[test]
    fn paper_fit_headline_ratio() {
        let ratio = paper_fit(32) / paper_fit(14);
        assert!((ratio - 14.08).abs() < 0.1, "{ratio}");
    }

    #[test]
    fn binary_search_handles_merged_sets() {
        let s = solver(CapacitorModel::Physics);
        // CapMin-V-style thinned set: some levels removed
        let c = s.solve_binary_search(&[10, 12, 14, 17, 20, 23]);
        let c_full = s.solve_binary_search(&(10..=23).collect::<Vec<_>>());
        assert!(c <= c_full * 1.001, "thinned set never needs more C");
    }
}
