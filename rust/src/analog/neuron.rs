//! Spike-time sets: S_FIRE and its mapping to MAC levels (paper Sec. II-B).
//!
//! A `SpikeTimeSet` is the hardware read-out configuration: for each
//! represented sub-MAC level (a contiguous window [q_lo, q_hi] selected by
//! CapMin, possibly thinned by CapMin-V merges) the ideal and quantized
//! spike times at a given capacitance. Decision boundaries for read-out
//! sit midway between adjacent spike times (paper Sec. IV-C); everything
//! slower than the last boundary is decoded as the slowest represented
//! level at the guaranteed response time (GRT).

use super::clock;
use super::params::AnalogParams;
use super::rc;

#[derive(Clone, Debug)]
pub struct SpikeTimeSet {
    /// Capacitance this set was realized with [F].
    pub c: f64,
    /// Represented levels, ascending (e.g. [10, 11, ..., 23]); level 0 is
    /// never in the set (no current -> no spike).
    pub levels: Vec<usize>,
    /// Quantized spike time per represented level [s] (descending: higher
    /// level = larger current = earlier spike).
    pub times: Vec<f64>,
    /// Clock slot (rising-edge index) per represented level.
    pub slots: Vec<u64>,
    /// Decision boundaries between adjacent represented levels, in time
    /// order: boundary[j] separates levels[j+1]'s bucket (faster) from
    /// levels[j]'s ... see `decode`.
    pub boundaries: Vec<f64>,
}

impl SpikeTimeSet {
    /// Build the set for a contiguous window of levels at capacitance c.
    pub fn new(p: &AnalogParams, c: f64, levels: Vec<usize>) -> SpikeTimeSet {
        assert!(!levels.is_empty());
        assert!(levels[0] >= 1, "level 0 has no spike time");
        let ideal: Vec<f64> = levels
            .iter()
            .map(|&m| rc::level_spike_time(p, c, m))
            .collect();
        let slots: Vec<u64> =
            ideal.iter().map(|&t| clock::slot(p, t)).collect();
        let times: Vec<f64> =
            ideal.iter().map(|&t| clock::quantize(p, t)).collect();
        // boundaries between adjacent levels (ascending level = descending
        // time): midpoint rule from the paper.
        let mut boundaries = vec![];
        for j in 0..levels.len() - 1 {
            boundaries.push(0.5 * (times[j] + times[j + 1]));
        }
        SpikeTimeSet {
            c,
            levels,
            times,
            slots,
            boundaries,
        }
    }

    /// All spike times distinct after clock quantization (the sizing
    /// feasibility criterion, paper Sec. II-C)? Uses the slots computed
    /// from the *ideal* times at construction — re-quantizing the
    /// already-quantized times would hit f64 edge rounding.
    pub fn distinct(&self, _p: &AnalogParams) -> bool {
        let mut slots = self.slots.clone();
        let n = slots.len();
        slots.dedup();
        slots.len() == n && self.times.iter().all(|t| t.is_finite())
    }

    /// Decode an observed firing time into a represented level.
    /// Faster than the fastest boundary -> highest level; slower than the
    /// slowest boundary (or no spike) -> lowest level (GRT timeout).
    pub fn decode(&self, t: f64) -> usize {
        // times are descending with ascending level index
        let n = self.levels.len();
        if n == 1 {
            return self.levels[0];
        }
        // walk from fastest (last index) to slowest
        for j in (0..n - 1).rev() {
            // bucket of levels[j+1]: t <= boundaries[j]
            if t <= self.boundaries[j] {
                return self.levels[j + 1];
            }
        }
        self.levels[0]
    }

    /// Guaranteed response time: the instant the read-out can finalize —
    /// one boundary interval past the slowest spike time (anything later
    /// decodes to the lowest level anyway).
    pub fn grt(&self) -> f64 {
        let n = self.levels.len();
        if n == 1 {
            return self.times[0];
        }
        // slowest spike time + half the gap to its faster neighbour,
        // mirrored on the slow side (symmetric bucket).
        let slowest = self.times[0];
        let gap = self.times[0] - self.times[1];
        slowest + 0.5 * gap
    }

    /// Length |B_i| of level i's decision interval (paper Sec. III-B);
    /// outermost buckets are half-open, reported as f64::INFINITY.
    pub fn bucket_len(&self, idx: usize) -> f64 {
        let n = self.levels.len();
        if n == 1 || idx == 0 || idx == n - 1 {
            return f64::INFINITY;
        }
        self.boundaries[idx - 1] - self.boundaries[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> AnalogParams {
        AnalogParams::paper_calibrated()
    }

    #[test]
    fn times_descend_with_level() {
        let p = p();
        let s = SpikeTimeSet::new(&p, 50e-12, (10..=23).collect());
        for j in 0..s.times.len() - 1 {
            assert!(s.times[j] > s.times[j + 1]);
        }
    }

    #[test]
    fn decode_recovers_exact_times() {
        let p = p();
        let s = SpikeTimeSet::new(&p, 135.2e-12, (1..=32).collect());
        assert!(s.distinct(&p), "paper baseline must be feasible");
        for (j, &m) in s.levels.iter().enumerate() {
            assert_eq!(s.decode(s.times[j]), m, "level {m}");
        }
    }

    #[test]
    fn decode_clips_at_extremes() {
        let p = p();
        let s = SpikeTimeSet::new(&p, 50e-12, (10..=23).collect());
        assert_eq!(s.decode(0.0), 23, "too fast -> highest level");
        assert_eq!(s.decode(1.0), 10, "too slow -> lowest level");
        assert_eq!(s.decode(f64::INFINITY), 10, "no spike -> lowest");
    }

    #[test]
    fn grt_past_slowest_spike() {
        let p = p();
        let s = SpikeTimeSet::new(&p, 50e-12, (10..=23).collect());
        assert!(s.grt() > s.times[0]);
    }

    #[test]
    fn interior_buckets_grow_with_time() {
        // |B_i| grows for slower spike times (paper Sec. III-B analysis)
        let p = p();
        let s = SpikeTimeSet::new(&p, 135.2e-12, (1..=32).collect());
        let b_slow = s.bucket_len(1); // level 2 (slow side)
        let b_fast = s.bucket_len(s.levels.len() - 2); // level 31
        assert!(b_slow > b_fast);
    }
}
