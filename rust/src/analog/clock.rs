//! Clock quantization: the FF registers a spike at the first rising edge
//! at or after the comparator output (paper Sec. II-C, Fig. 3).

use super::params::AnalogParams;

/// Clock slot (1-based rising-edge index) that registers an ideal spike
/// at time `t`. Slot 0 is reserved for "fires before the first edge can
/// sample" and never occurs for t > 0 quantization.
pub fn slot(p: &AnalogParams, t: f64) -> u64 {
    if !t.is_finite() {
        return u64::MAX; // never fires (level 0 / timeout)
    }
    let ticks = t / p.t_clk();
    ticks.ceil().max(1.0) as u64
}

/// Quantized spike time: the wall-clock time of `slot(t)`'s rising edge.
pub fn quantize(p: &AnalogParams, t: f64) -> f64 {
    if !t.is_finite() {
        return f64::INFINITY;
    }
    slot(p, t) as f64 * p.t_clk()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::params::AnalogParams;

    fn p() -> AnalogParams {
        AnalogParams::paper_calibrated()
    }

    #[test]
    fn rounds_up_to_edges() {
        let p = p();
        let tc = p.t_clk();
        assert_eq!(slot(&p, 0.2 * tc), 1);
        assert_eq!(slot(&p, 1.0 * tc), 1);
        assert_eq!(slot(&p, 1.0001 * tc), 2);
        assert!((quantize(&p, 2.5 * tc) - 3.0 * tc).abs() < 1e-18);
    }

    #[test]
    fn infinite_never_fires() {
        let p = p();
        assert_eq!(slot(&p, f64::INFINITY), u64::MAX);
        assert!(quantize(&p, f64::INFINITY).is_infinite());
    }

    #[test]
    fn quantization_is_monotone() {
        let p = p();
        let mut prev = 0;
        for j in 1..1000 {
            let s = slot(&p, j as f64 * 0.37e-9);
            assert!(s >= prev);
            prev = s;
        }
    }
}
