//! Hardware cost accounting for the neuron circuit: per-set circuit
//! ratios (Fig. 9) and the full per-operating-point [`CostVector`]
//! (DESIGN.md §13).
//!
//! Energy per sub-MAC read-out is the capacitor charge energy
//! E = 1/2 C Vth^2 (the paper's own expression, Sec. IV-B); latency is
//! the guaranteed response time (GRT, [3]) rounded up to the read-out
//! clock; area is MIM-cap area plus a VSA-style computing-array slice.
//! The absolute constants are order-of-magnitude 14nm-class figures —
//! every report compares operating points against each other, so only
//! the *ratios* carry weight (same convention as the capacitor model's
//! physics mode).

use super::clock;
use super::neuron::SpikeTimeSet;
use super::params::AnalogParams;
use crate::util::json::{obj, Json};
use anyhow::{anyhow, Result};

/// MIM capacitor density [F/m^2]; ~8 fF/µm^2 for a 14nm-class MIM stack.
/// Only ratios are reported, so the constant cancels in comparisons.
pub const CAP_DENSITY: f64 = 8e-3;

/// Area of one computing-array cell [m^2] (~0.2 µm^2: a 14nm-class
/// XNOR/match-line cell, the VSA vectorwise-accelerator datapoint).
pub const CELL_AREA: f64 = 2e-13;

/// Area of one read-out boundary slot [m^2] (~1 µm^2: the time
/// reference register + comparator tap a represented spike time costs
/// in the decoder). CapMin-V merges shrink exactly this term.
pub const READOUT_AREA: f64 = 1e-12;

#[derive(Clone, Copy, Debug)]
pub struct CircuitCost {
    /// Capacitance [F].
    pub c: f64,
    /// Energy per sub-MAC read-out [J].
    pub energy: f64,
    /// Guaranteed response time [s].
    pub grt: f64,
    /// Capacitor area [m^2].
    pub area: f64,
}

/// Energy of one sub-MAC read-out at capacitance `c` [J] — the
/// paper's Sec. IV-B expression, shared by every consumer (fig9, the
/// per-point [`CostVector`]) so the formula lives in exactly one
/// place.
pub fn readout_energy(p: &AnalogParams, c: f64) -> f64 {
    0.5 * c * p.vth * p.vth
}

pub fn cost(p: &AnalogParams, set: &SpikeTimeSet) -> CircuitCost {
    CircuitCost {
        c: set.c,
        energy: readout_energy(p, set.c),
        grt: set.grt(),
        area: set.c / CAP_DENSITY,
    }
}

impl CircuitCost {
    /// Ratios vs a baseline cost — (c, energy, grt, area), each as
    /// `base/self` (the paper reports everything as "x smaller than
    /// the state of the art").
    pub fn ratio_vs(&self, base: &CircuitCost) -> (f64, f64, f64, f64) {
        (
            base.c / self.c,
            base.energy / self.energy,
            base.grt / self.grt,
            base.area / self.area,
        )
    }
}

/// GRT of one read-out window from its quantized spike times
/// (descending: `times[0]` is the slowest represented level) — the
/// same rule as [`SpikeTimeSet::grt`], recomputable from a persisted
/// operating point's `times` rows alone.
pub fn window_grt(times: &[f64]) -> f64 {
    assert!(!times.is_empty(), "a window represents >= 1 level");
    if times.len() == 1 {
        return times[0];
    }
    times[0] + 0.5 * (times[0] - times[1])
}

/// The multi-objective price of one whole operating point (DESIGN.md
/// §13) — the design-space explorer's coordinates. Derived purely
/// from the point's own persisted fields (C + per-matmul spike
/// times), so it is *recomputed* wherever a point materializes and is
/// never part of any cache key: old `runs/points/*.json` files stay
/// valid and re-pricings never invalidate solves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostVector {
    /// Shared membrane capacitance [F].
    pub c: f64,
    /// Total represented spike times across all matmul windows.
    pub spike_times: usize,
    /// Read-out energy for one full network pass [J]: per-window
    /// spike-time count x the capacitor charge energy 1/2 C Vth^2.
    pub energy: f64,
    /// Silicon area of the neuron slice [m^2]: MIM cap + computing
    /// array cells + one decoder slot per represented spike time.
    pub area: f64,
    /// End-to-end latency [s]: the matmuls run sequentially, each
    /// waiting out its own window's GRT rounded up to the read-out
    /// clock (clock period x GRT slots).
    pub latency: f64,
}

impl CostVector {
    /// Price an operating point from its capacitance and per-matmul
    /// quantized spike-time rows (each descending, slowest first).
    pub fn price(
        p: &AnalogParams,
        c: f64,
        times: &[Vec<f64>],
    ) -> CostVector {
        assert!(!times.is_empty(), "a point prices >= 1 matmul");
        let spike_times: usize = times.iter().map(|t| t.len()).sum();
        let energy = spike_times as f64 * readout_energy(p, c);
        let area = c / CAP_DENSITY
            + p.array_size as f64 * CELL_AREA
            + spike_times as f64 * READOUT_AREA;
        let t_clk = p.t_clk();
        let latency: f64 = times
            .iter()
            .map(|t| clock::slot(p, window_grt(t)) as f64 * t_clk)
            .sum();
        CostVector {
            c,
            spike_times,
            energy,
            area,
            latency,
        }
    }

    /// Stable JSON form — embedded in point files and `serve` `Point`
    /// replies (informational there: loaders recompute, see
    /// [`crate::session::point::OperatingPoint::from_json`]).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("c", Json::Num(self.c)),
            ("spike_times", Json::Num(self.spike_times as f64)),
            ("energy", Json::Num(self.energy)),
            ("area", Json::Num(self.area)),
            ("latency", Json::Num(self.latency)),
        ])
    }

    /// Parse the JSON form (for clients reading `serve` replies or
    /// point files directly).
    pub fn from_json(j: &Json) -> Result<CostVector> {
        let num = |k: &str| -> Result<f64> {
            match j.get(k) {
                Some(Json::Num(n)) => Ok(*n),
                other => {
                    Err(anyhow!("cost vector missing `{k}`: {other:?}"))
                }
            }
        };
        Ok(CostVector {
            c: num("c")?,
            spike_times: num("spike_times")? as usize,
            energy: num("energy")?,
            area: num("area")?,
            latency: num("latency")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::capacitor::{CapacitorModel, CapacitorSolver};

    #[test]
    fn energy_proportional_to_c() {
        let p = AnalogParams::paper_calibrated();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let c32 = solver.size_for_window(1, 32);
        let c14 = solver.size_for_window(10, 23);
        let s32 = SpikeTimeSet::new(&p, c32, (1..=32).collect());
        let s14 = SpikeTimeSet::new(&p, c14, (10..=23).collect());
        let b = cost(&p, &s32);
        let m = cost(&p, &s14);
        let (rc_, re, _, ra) = m.ratio_vs(&b);
        assert!((rc_ - re).abs() < 1e-9, "energy ratio == cap ratio");
        assert!((rc_ - ra).abs() < 1e-9, "area ratio == cap ratio");
        assert!(rc_ > 1.0);
    }

    #[test]
    fn capmin_reduces_latency_strongly() {
        // GRT gain combines smaller C and a faster slowest level
        let p = AnalogParams::paper_calibrated();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let c32 = solver.size_for_window(1, 32);
        let c14 = solver.size_for_window(10, 23);
        let b = cost(&p, &SpikeTimeSet::new(&p, c32, (1..=32).collect()));
        let m = cost(&p, &SpikeTimeSet::new(&p, c14, (10..=23).collect()));
        let (_, _, rt, _) = m.ratio_vs(&b);
        assert!(rt > 5.0, "latency ratio {rt}");
    }

    #[test]
    fn window_grt_matches_spike_time_set() {
        let p = AnalogParams::paper_calibrated();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        for (lo, hi) in [(1, 32), (10, 23), (16, 16)] {
            let c = solver.size_for_window(lo, hi);
            let s = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
            assert_eq!(window_grt(&s.times), s.grt(), "[{lo},{hi}]");
        }
    }

    #[test]
    fn price_aggregates_per_window() {
        let p = AnalogParams::paper_calibrated();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let c = solver.size_for_window(10, 23);
        let narrow = SpikeTimeSet::new(&p, c, (12..=17).collect());
        let wide = SpikeTimeSet::new(&p, c, (10..=23).collect());
        let cv = CostVector::price(
            &p,
            c,
            &[narrow.times.clone(), wide.times.clone()],
        );
        assert_eq!(cv.spike_times, 6 + 14);
        assert!(
            (cv.energy - 20.0 * readout_energy(&p, c)).abs() < 1e-24
        );
        // each window's latency is clock-aligned at or past its GRT
        let lat_lower = narrow.grt() + wide.grt();
        assert!(cv.latency >= lat_lower);
        assert!(cv.latency <= lat_lower + 2.0 * p.t_clk());
        // area: MIM cap dominates, both other terms present
        assert!(cv.area > cv.c / CAP_DENSITY);
    }

    #[test]
    fn cost_vector_json_roundtrip_exact() {
        let p = AnalogParams::paper_calibrated();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let c = solver.size_for_window(8, 21);
        let s = SpikeTimeSet::new(&p, c, (8..=21).collect());
        let cv = CostVector::price(&p, c, &[s.times]);
        let back = CostVector::from_json(
            &Json::parse(&cv.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(cv, back);
    }
}
