//! Energy / latency / area accounting for the neuron circuit (Fig. 9).
//!
//! Energy per MAC read-out is the capacitor charge energy E = 1/2 C Vth^2
//! (the paper's own expression, Sec. IV-B); latency is the guaranteed
//! response time (GRT, [3]); area is proportional to C (MIM-cap density).

use super::neuron::SpikeTimeSet;
use super::params::AnalogParams;

/// MIM capacitor density [F/m^2]; ~8 fF/µm^2 for a 14nm-class MIM stack.
/// Only ratios are reported, so the constant cancels in comparisons.
pub const CAP_DENSITY: f64 = 8e-3;

#[derive(Clone, Copy, Debug)]
pub struct CircuitCost {
    /// Capacitance [F].
    pub c: f64,
    /// Energy per sub-MAC read-out [J].
    pub energy: f64,
    /// Guaranteed response time [s].
    pub grt: f64,
    /// Capacitor area [m^2].
    pub area: f64,
}

pub fn cost(p: &AnalogParams, set: &SpikeTimeSet) -> CircuitCost {
    CircuitCost {
        c: set.c,
        energy: 0.5 * set.c * p.vth * p.vth,
        grt: set.grt(),
        area: set.c / CAP_DENSITY,
    }
}

impl CircuitCost {
    /// Ratios vs a baseline cost (the paper reports everything as "x
    /// smaller than the state of the art").
    pub fn ratio_vs(&self, base: &CircuitCost) -> (f64, f64, f64) {
        (base.c / self.c, base.energy / self.energy, base.grt / self.grt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::capacitor::{CapacitorModel, CapacitorSolver};

    #[test]
    fn energy_proportional_to_c() {
        let p = AnalogParams::paper_calibrated();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let c32 = solver.size_for_window(1, 32);
        let c14 = solver.size_for_window(10, 23);
        let s32 = SpikeTimeSet::new(&p, c32, (1..=32).collect());
        let s14 = SpikeTimeSet::new(&p, c14, (10..=23).collect());
        let b = cost(&p, &s32);
        let m = cost(&p, &s14);
        let (rc_, re, _) = m.ratio_vs(&b);
        assert!((rc_ - re).abs() < 1e-9, "energy ratio == cap ratio");
        assert!(rc_ > 1.0);
    }

    #[test]
    fn capmin_reduces_latency_strongly() {
        // GRT gain combines smaller C and a faster slowest level
        let p = AnalogParams::paper_calibrated();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let c32 = solver.size_for_window(1, 32);
        let c14 = solver.size_for_window(10, 23);
        let b = cost(&p, &SpikeTimeSet::new(&p, c32, (1..=32).collect()));
        let m = cost(&p, &SpikeTimeSet::new(&p, c14, (10..=23).collect()));
        let (_, _, rt) = m.ratio_vs(&b);
        assert!(rt > 5.0, "latency ratio {rt}");
    }
}
