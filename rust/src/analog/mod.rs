//! Analog IF-SNN circuit substrate (the paper's SPICE stand-in).
//!
//! The paper evaluates on SPICE with a BSIM-IMG 14nm FD-SOI model-card;
//! the *method* layer only consumes the first-order circuit behaviour the
//! paper itself derives (Eqs. 2/3/5): an RC membrane charged by the
//! computing array's summed current, a comparator firing at Vth, and a
//! 2 GHz flip-flop quantizing the spike to clock edges. This module
//! implements exactly that model plus the Monte-Carlo variation analysis
//! used to build the paper's P_map (Eq. 6). DESIGN.md §4 and §6 record
//! the substitution and its calibration against the paper's published
//! capacitor numbers.

pub mod capacitor;
pub mod clock;
pub mod cost;
pub mod montecarlo;
pub mod neuron;
pub mod params;
pub mod pmap;
pub mod rc;

pub use capacitor::{CapacitorModel, CapacitorSolver};
pub use cost::CostVector;
pub use montecarlo::{McMode, McSettings, MonteCarlo};
pub use neuron::SpikeTimeSet;
pub use params::AnalogParams;
pub use pmap::{tv_distance, Pmap};
