//! Electrical constants of the IF-SNN circuit (paper Sec. II-C / IV-A2).
//!
//! `i_on` is the calibration knob: the paper does not publish the cell
//! on-current, but it does publish the baseline capacitor (135.2 pF for
//! k = 32 spike times at a 2 GHz read-out clock, Vth = 0.225 V). We pick
//! `i_on` so that the first-principles sizing rule (all 32 spike times
//! land on distinct clock edges, see `capacitor.rs`) reproduces that
//! baseline exactly; every other capacitor value is then a *prediction*
//! of the model, compared against the paper in EXPERIMENTS.md.

/// Parameters of the neuron circuit + computing array.
#[derive(Clone, Copy, Debug)]
pub struct AnalogParams {
    /// Supply voltage V0 [V].
    pub v0: f64,
    /// Comparator threshold Vth [V] (paper: 0.225 V).
    pub vth: f64,
    /// Read-out clock frequency [Hz] (paper: 2 GHz Verilog-A FF).
    pub f_clk: f64,
    /// Single-cell on-state current I_ON [A]; current for sub-MAC level M
    /// is M * i_on (Kirchhoff sum on the match line).
    pub i_on: f64,
    /// Computing array size a (paper: 32).
    pub array_size: usize,
    /// Relative current variation sigma (epsilon_i proportional to I_i,
    /// paper Sec. III-B); calibratable per technology.
    pub sigma_rel: f64,
}

/// The paper's published k=32 baseline capacitor [F].
pub const PAPER_BASELINE_C: f64 = 135.2e-12;
/// The paper's CapMin capacitor at k=14 [F] (Fig. 9).
pub const PAPER_CAPMIN_C: f64 = 9.6e-12;
/// The paper's k=16 capacitor [F] (CapMin-V starting point, Sec. IV-C).
pub const PAPER_K16_C: f64 = 12.27e-12;

impl AnalogParams {
    /// -ln(1 - Vth/V0): the charging-curve factor in Eq. (5).
    pub fn lambda(&self) -> f64 {
        -(1.0 - self.vth / self.v0).ln()
    }

    /// Clock period [s].
    pub fn t_clk(&self) -> f64 {
        1.0 / self.f_clk
    }

    /// Calibrated to the paper's testbed: V0 = 0.8 V (14nm FD-SOI core
    /// rail), Vth = 0.225 V, 2 GHz clock, a = 32, and i_on solved so the
    /// k = 32 baseline sizes to exactly 135.2 pF (see module docs).
    pub fn paper_calibrated() -> AnalogParams {
        let mut p = AnalogParams {
            v0: 0.8,
            vth: 0.225,
            f_clk: 2e9,
            i_on: 0.0,
            array_size: 32,
            sigma_rel: 0.02,
        };
        // C_base = t_clk * i_on * M(M+1) / (V0 * lambda) at the tightest
        // adjacent pair M = a-1 (see capacitor.rs closed form); invert.
        let a = p.array_size as f64;
        p.i_on = PAPER_BASELINE_C * p.v0 * p.lambda()
            / (p.t_clk() * a * (a - 1.0));
        p
    }

    /// Same testbed with a different variation strength.
    pub fn with_sigma(mut self, sigma_rel: f64) -> AnalogParams {
        self.sigma_rel = sigma_rel;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_matches_hand_computation() {
        let p = AnalogParams::paper_calibrated();
        // -ln(1 - 0.225/0.8) = -ln(0.71875)
        assert!((p.lambda() - 0.330_241_f64).abs() < 1e-5);
    }

    #[test]
    fn calibration_solves_positive_current() {
        let p = AnalogParams::paper_calibrated();
        // ~70 µA match-line drive; sanity band, not an exact target.
        assert!(p.i_on > 1e-6 && p.i_on < 1e-3, "i_on = {}", p.i_on);
    }
}
