//! Monte-Carlo variation engine — the paper's SPICE-MC stand-in
//! (Sec. IV-C: samples per spike time, bucket decode at midpoints),
//! rebuilt around three solve modes (DESIGN.md §15):
//!
//! * **paper** — the literal Sec. IV-C schedule: `n_samples` i.i.d.
//!   normal draws per level, chunked into independently-seeded
//!   [`MC_CHUNK`]-draw streams for thread-count-invariant parallelism.
//! * **fast** — adaptive variance-reduced sampling: each round draws
//!   one sample per equal-probability normal stratum ([`MC_STRATA`]
//!   strata, inverse-CDF), antithetically paired (z, -z), and a level
//!   stops growing rounds as soon as every bucket probability's
//!   Wilson confidence interval is inside the target tolerance.
//!   Because decode is monotone in the current draw, all estimator
//!   uncertainty is confined to the few strata that contain a decode
//!   boundary — the stopping rule measures exactly those.
//! * **analytic** — the closed-form oracle: spike time is monotone in
//!   current and decode buckets are current intervals, so
//!   P(decode j | level m) is an exact normal-CDF difference with
//!   clock quantization folded in as interval snapping. Zero draws;
//!   ground truth for the statistical-equivalence pins.
//!
//! Current variation is proportional to the level current (epsilon_i ~
//! sigma_rel * I_i, paper Sec. III-B); each sample charges the capacitor,
//! fires at Eq. (5)'s time, is clock-quantized, and decoded through the
//! spike-time set's decision boundaries. Counting decodes yields P_map.

use anyhow::{anyhow, Result};

use super::clock;
use super::neuron::SpikeTimeSet;
use super::params::AnalogParams;
use super::pmap::Pmap;
use super::rc;
use crate::capmin::N_LEVELS;
use crate::util::pool::ScopedPool;
use crate::util::rng::{normal_cdf, normal_inv_cdf, Rng};

/// Samples per independently-seeded draw chunk (paper mode): the unit
/// of work the level sweep fans out over. Each (level, chunk) pair
/// draws from its own deterministic `rng.split` sub-stream, so the
/// fan-out geometry depends only on `n_samples` — never on the thread
/// count — and the default 1000-sample sweep exposes `4 x k` work
/// items instead of `k`, enough to saturate the pool even for narrow
/// windows (the CapMin-V phi sweep's common case).
pub const MC_CHUNK: usize = 250;

/// Equal-probability normal strata per fast-mode round. One round
/// draws exactly one sample per stratum (antithetically paired), so a
/// level's draw count is always a multiple of this. 128 strata put
/// the per-round bracketing resolution of every decode boundary at
/// 1/128 of probability mass — two rounds already localize each
/// boundary well inside the default tolerance for realistic sigma.
pub const MC_STRATA: usize = 128;

/// Fast mode never stops before this many rounds: the first round
/// locates the boundary strata, the second gives the Wilson rule a
/// non-degenerate count in each of them.
pub const MC_MIN_ROUNDS: usize = 2;

/// Default fast-mode tolerance: target half-width of each bucket
/// probability's 95% Wilson interval.
pub const MC_DEFAULT_TOL: f64 = 0.01;

/// z-score of the Wilson stopping intervals (95%).
const WILSON_Z: f64 = 1.96;

/// Monte-Carlo solve mode (`--mc paper|fast|analytic`). The mode is
/// part of the spec's hardware cache-key material (spec::hw_material,
/// v3) — maps from different modes agree statistically (TV distance
/// under tolerance) but not bitwise, so points never replay across
/// modes. Draw counts actually used are provenance (PointMeta), never
/// key material.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum McMode {
    /// Fixed-draw i.i.d. sampling, the paper's Sec. IV-C schedule.
    Paper,
    /// Stratified antithetic draws with per-level Wilson early
    /// stopping (DESIGN.md §15).
    Fast,
    /// Closed-form normal-CDF oracle, zero draws.
    Analytic,
}

impl McMode {
    pub const CHOICES: &'static [&'static str] =
        &["paper", "fast", "analytic"];

    pub fn parse(s: &str) -> Result<McMode> {
        match s {
            "paper" => Ok(McMode::Paper),
            "fast" => Ok(McMode::Fast),
            "analytic" => Ok(McMode::Analytic),
            other => Err(anyhow!(
                "unknown Monte-Carlo mode `{other}` (valid: paper, \
                 fast, analytic)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            McMode::Paper => "paper",
            McMode::Fast => "fast",
            McMode::Analytic => "analytic",
        }
    }
}

/// The Monte-Carlo knobs a solve carries around as one value: mode,
/// paper-mode draw count (doubling as the fast-mode budget cap) and
/// the fast-mode stopping tolerance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct McSettings {
    pub mode: McMode,
    /// Draws per level in paper mode; fast mode spends at most
    /// (roughly) this many per level before giving up on tightening.
    pub samples: usize,
    /// Fast-mode target: per-bucket 95% Wilson half-width.
    pub tol: f64,
}

impl McSettings {
    /// The paper-faithful default at `samples` draws per level.
    pub fn paper(samples: usize) -> McSettings {
        McSettings {
            mode: McMode::Paper,
            samples,
            tol: MC_DEFAULT_TOL,
        }
    }
}

/// One stratified antithetic round: exactly one standard-normal draw
/// per stratum, emitted as (stratum, z) pairs. For each `s` in the
/// lower half, `u ~ U[0,1)` places a draw at quantile `(s + u) / S`
/// (inverse-CDF), and its antithetic mirror `-z` lands exactly in
/// stratum `S - 1 - s` (at quantile `1 - (s + u) / S`). Every stratum
/// is covered exactly once per round and every draw is paired with
/// its reflection, so a round's sample mean is exactly zero and each
/// stratum's conditional distribution is sampled without clumping.
pub fn stratified_round(rng: &mut Rng, strata: usize) -> Vec<(usize, f64)> {
    debug_assert!(strata >= 2 && strata % 2 == 0);
    let s_f = strata as f64;
    let mut out = Vec::with_capacity(strata);
    for s in 0..strata / 2 {
        let u = rng.f64();
        let z = normal_inv_cdf((s as f64 + u) / s_f);
        out.push((s, z));
        out.push((strata - 1 - s, -z));
    }
    out
}

/// Half-width of the 95% Wilson score interval for `x` successes in
/// `n` trials.
fn wilson_half_width(x: f64, n: f64) -> f64 {
    let z2 = WILSON_Z * WILSON_Z;
    (WILSON_Z / (n + z2)) * (x * (n - x) / n + z2 / 4.0).sqrt()
}

pub struct MonteCarlo {
    pub params: AnalogParams,
    /// Paper-mode draws per level (also the fast-mode budget cap);
    /// clamped to >= 1 — zero draws would divide rows by zero.
    pub n_samples: usize,
    /// Solve mode; Paper by default (see [`McMode`]).
    pub mode: McMode,
    /// Fast-mode per-bucket Wilson tolerance.
    pub tol: f64,
    /// Level-sweep fan-out (sequential by default). Paper mode fans
    /// (level, chunk-of-[`MC_CHUNK`]-draws) pairs, fast mode fans
    /// whole levels (each level's adaptive round loop is
    /// self-contained); both run on decorrelated `rng.split`
    /// sub-streams, so any thread count produces bit-identical maps
    /// *within* a mode.
    pool: ScopedPool,
}

impl MonteCarlo {
    pub fn new(params: AnalogParams) -> MonteCarlo {
        MonteCarlo {
            params,
            n_samples: 1000,
            mode: McMode::Paper,
            tol: MC_DEFAULT_TOL,
            pool: ScopedPool::sequential(),
        }
    }

    /// Paper-mode draws per level. `0` is clamped to `1`: an empty
    /// sample budget has no meaningful map, and the old behaviour
    /// (0-draw chunks normalized by `n = 0`) produced NaN rows.
    pub fn with_samples(mut self, n: usize) -> MonteCarlo {
        self.n_samples = n.max(1);
        self
    }

    pub fn with_mode(mut self, mode: McMode) -> MonteCarlo {
        self.mode = mode;
        self
    }

    /// Fast-mode stopping tolerance (per-bucket 95% Wilson
    /// half-width). Non-positive values are clamped to the default.
    pub fn with_tol(mut self, tol: f64) -> MonteCarlo {
        self.tol = if tol > 0.0 { tol } else { MC_DEFAULT_TOL };
        self
    }

    /// Apply a full [`McSettings`] bundle.
    pub fn with_settings(self, s: McSettings) -> MonteCarlo {
        self.with_samples(s.samples).with_mode(s.mode).with_tol(s.tol)
    }

    /// Fan the sampling loops of `pmap`/`full_map` out over `threads`
    /// workers (0 = all cores). Results are bit-identical at any
    /// setting.
    pub fn with_threads(mut self, threads: usize) -> MonteCarlo {
        self.pool = if threads == 1 {
            ScopedPool::sequential()
        } else {
            ScopedPool::new(threads)
        };
        self
    }

    /// Fan over a caller-supplied pool instead of constructing one —
    /// a long-running session shares one persistent crew across every
    /// solve (DESIGN.md §12). Results are bit-identical either way.
    pub fn with_pool(mut self, pool: ScopedPool) -> MonteCarlo {
        self.pool = pool;
        self
    }

    /// One varied read-out of level `m` through `set`: sample the current,
    /// fire, quantize, decode.
    pub fn sample_decode(
        &self,
        set: &SpikeTimeSet,
        m: usize,
        rng: &mut Rng,
    ) -> usize {
        let p = &self.params;
        if m == 0 {
            // no conducting cell -> no current -> GRT timeout
            return set.levels[0];
        }
        let i_nom = rc::level_current(p, m);
        let i = rng
            .normal_scaled(i_nom, p.sigma_rel * i_nom)
            .max(1e-3 * p.i_on);
        let t = clock::quantize(p, rc::spike_time(p, set.c, i));
        set.decode(t)
    }

    /// Decode level `m` at a *given* standard-normal deviate `z` —
    /// the deterministic core the stratified sampler drives.
    fn decode_z(&self, set: &SpikeTimeSet, m: usize, z: f64) -> usize {
        debug_assert!(m >= 1);
        let p = &self.params;
        let i_nom = rc::level_current(p, m);
        let i = (i_nom + p.sigma_rel * i_nom * z).max(1e-3 * p.i_on);
        let t = clock::quantize(p, rc::spike_time(p, set.c, i));
        set.decode(t)
    }

    /// Decoded-level -> bucket-index table over `set`'s levels.
    fn index_of(set: &SpikeTimeSet) -> [usize; N_LEVELS] {
        let mut index_of = [usize::MAX; N_LEVELS];
        for (i, &l) in set.levels.iter().enumerate() {
            index_of[l] = i;
        }
        index_of
    }

    /// The (chunk index -> sample range) schedule of paper mode:
    /// fixed-size [`MC_CHUNK`] spans, a pure function of `n_samples`.
    fn chunks(&self) -> usize {
        self.n_samples.div_ceil(MC_CHUNK).max(1)
    }

    /// Sample counts of chunk `c`.
    fn chunk_span(&self, c: usize) -> usize {
        let lo = c * MC_CHUNK;
        let hi = ((c + 1) * MC_CHUNK).min(self.n_samples);
        hi.saturating_sub(lo)
    }

    /// k x k P_map over the represented levels (paper Eq. 6), in the
    /// configured [`McMode`]; `sigma_rel == 0` short-circuits every
    /// mode to the exact closed-form map (no draws — the old paper
    /// path burned 1000 draws per level reproducing a deterministic
    /// identity block).
    pub fn pmap(&self, set: &SpikeTimeSet, rng: &mut Rng) -> Pmap {
        self.pmap_counted(set, rng).0
    }

    /// [`MonteCarlo::pmap`] plus the number of normal draws actually
    /// consumed — provenance for `PointMeta` and the draw-reduction
    /// benches; never cache-key material.
    pub fn pmap_counted(
        &self,
        set: &SpikeTimeSet,
        rng: &mut Rng,
    ) -> (Pmap, u64) {
        let _span = crate::span!("mc.pmap");
        if self.params.sigma_rel == 0.0 || self.mode == McMode::Analytic
        {
            return (self.analytic_pmap(set), 0);
        }
        match self.mode {
            McMode::Paper => self.pmap_paper(set, rng),
            McMode::Fast => self.pmap_fast(set, rng),
            McMode::Analytic => unreachable!("handled above"),
        }
    }

    /// Paper-mode pmap: each (level, chunk) work item samples an
    /// independent `rng.split(level).split(chunk)` stream (the parent
    /// state is never advanced), so fanning the chunked loop over the
    /// pool is bit-identical to the sequential sweep at any thread
    /// count. Decoded levels map to row slots through a precomputed
    /// level->index table instead of an O(k) scan per sample.
    fn pmap_paper(&self, set: &SpikeTimeSet, rng: &mut Rng)
        -> (Pmap, u64) {
        let k = set.levels.len();
        let index_of = MonteCarlo::index_of(set);
        let parent: &Rng = rng;
        let nc = self.chunks();
        let parts: Vec<Vec<u64>> = self.pool.map(k * nc, |j| {
            // nests under mc.pmap even on pool workers: for_each
            // forwards the submitter's trace context (DESIGN.md §17)
            let _span = crate::span!("mc.chunk");
            let (i, chunk) = (j / nc, j % nc);
            let m = set.levels[i];
            let mut row = vec![0u64; k];
            let mut r = parent.split(m as u64 + 1).split(chunk as u64);
            for _ in 0..self.chunk_span(chunk) {
                let d = self.sample_decode(set, m, &mut r);
                row[index_of[d]] += 1;
            }
            row
        });
        // merge chunk partials per level, in chunk order (exact: u64)
        let mut counts = vec![vec![0u64; k]; k];
        for (j, part) in parts.iter().enumerate() {
            let row = &mut counts[j / nc];
            for (a, b) in row.iter_mut().zip(part.iter()) {
                *a += b;
            }
        }
        let p = counts
            .iter()
            .map(|row| {
                row.iter()
                    .map(|&c| c as f64 / self.n_samples as f64)
                    .collect()
            })
            .collect();
        (
            Pmap {
                levels: set.levels.clone(),
                p,
            },
            (k * self.n_samples) as u64,
        )
    }

    /// Fast-mode pmap: one work item per level (each level's adaptive
    /// round loop is sequential and self-contained, so the map is
    /// bit-identical at any thread count).
    fn pmap_fast(&self, set: &SpikeTimeSet, rng: &mut Rng)
        -> (Pmap, u64) {
        let k = set.levels.len();
        let parent: &Rng = rng;
        let rows: Vec<(Vec<f64>, u64)> = self.pool.map(k, |i| {
            let _span = crate::span!("mc.chunk");
            let m = set.levels[i];
            let stream = parent.split(m as u64 + 1);
            self.fast_row(set, m, &stream)
        });
        let draws = rows.iter().map(|(_, d)| d).sum();
        (
            Pmap {
                levels: set.levels.clone(),
                p: rows.into_iter().map(|(r, _)| r).collect(),
            },
            draws,
        )
    }

    /// Adaptive stratified-antithetic bucket distribution of one
    /// level: grow draws in rounds of [`MC_STRATA`] until the
    /// stopping rule ([`MonteCarlo::fast_converged`]) holds or the
    /// paper budget is spent. Returns (bucket probabilities over
    /// `set.levels`, draws consumed). Every stratum holds exactly
    /// `rounds` draws, so the stratified estimator reduces to the
    /// pooled bucket frequency.
    fn fast_row(&self, set: &SpikeTimeSet, m: usize, stream: &Rng)
        -> (Vec<f64>, u64) {
        let k = set.levels.len();
        if m == 0 {
            // no current -> GRT timeout -> lowest represented level
            let mut row = vec![0.0; k];
            row[0] = 1.0;
            return (row, 0);
        }
        if k == 1 {
            return (vec![1.0], 0);
        }
        let index_of = MonteCarlo::index_of(set);
        let max_rounds =
            self.n_samples.div_ceil(MC_STRATA).max(MC_MIN_ROUNDS);
        let mut strat_counts = vec![vec![0u32; k]; MC_STRATA];
        let mut rounds = 0;
        while rounds < max_rounds {
            let mut r = stream.split(rounds as u64);
            for (s, z) in stratified_round(&mut r, MC_STRATA) {
                let d = self.decode_z(set, m, z);
                strat_counts[s][index_of[d]] += 1;
            }
            rounds += 1;
            if rounds >= MC_MIN_ROUNDS
                && self.fast_converged(&strat_counts, rounds)
            {
                break;
            }
        }
        let draws = (rounds * MC_STRATA) as u64;
        let mut row = vec![0.0; k];
        for counts in &strat_counts {
            for (j, &c) in counts.iter().enumerate() {
                row[j] += c as f64;
            }
        }
        for v in row.iter_mut() {
            *v /= draws as f64;
        }
        (row, draws)
    }

    /// The fast-mode stopping rule. Decode is monotone in z (spike
    /// time is monotone in current, current is affine in z), so each
    /// bucket is a z-interval and a stratum's observed decodes form a
    /// contiguous bucket range; all estimator uncertainty lives in
    /// the *uncertain* strata — those observed mixed, or adjacent to
    /// an observed between-strata transition (the boundary could sit
    /// on either side of the shared edge). For each bucket, a 95%
    /// Wilson interval over the draws in its uncertain strata, scaled
    /// back by those strata's total probability mass, bounds how much
    /// the bucket probability can still move; stop when every bucket
    /// is inside `tol`. Certain strata contribute exactly-known mass
    /// (up to the q^rounds chance that a boundary stratum looked
    /// pure, which the transition marking covers) and cost nothing.
    fn fast_converged(&self, strat_counts: &[Vec<u32>], rounds: usize)
        -> bool {
        let s_n = strat_counts.len();
        let k = strat_counts[0].len();
        // observed bucket range per stratum (contiguous by monotonicity)
        let mut lo = vec![usize::MAX; s_n];
        let mut hi = vec![0usize; s_n];
        for (s, counts) in strat_counts.iter().enumerate() {
            for (j, &c) in counts.iter().enumerate() {
                if c > 0 {
                    lo[s] = lo[s].min(j);
                    hi[s] = hi[s].max(j);
                }
            }
        }
        let mut uncertain = vec![false; s_n];
        for s in 0..s_n {
            if lo[s] < hi[s] {
                uncertain[s] = true; // mixed: a boundary inside
            }
        }
        for s in 0..s_n - 1 {
            if hi[s] != lo[s + 1] {
                // observed transition at the shared edge: the
                // boundary may be in either stratum
                uncertain[s] = true;
                uncertain[s + 1] = true;
            }
        }
        for j in 0..k {
            let mut x = 0u64;
            let mut n_strata = 0u64;
            for s in 0..s_n {
                if !uncertain[s] {
                    continue;
                }
                // stratum s can still move mass in or out of bucket j
                // only if j borders its observed range
                if j + 1 < lo[s] || j > hi[s] + 1 {
                    continue;
                }
                x += strat_counts[s][j] as u64;
                n_strata += 1;
            }
            if n_strata == 0 {
                continue; // bucket fully pinned by certain strata
            }
            let n = (n_strata as usize * rounds) as f64;
            let hw = wilson_half_width(x as f64, n) * n_strata as f64
                / s_n as f64;
            if hw > self.tol {
                return false;
            }
        }
        true
    }

    /// Closed-form decode distribution of physical level `m` through
    /// `set` — the analytic oracle. Decode compares the quantized
    /// spike time `t_q = slot * t_clk` against each boundary, so
    /// `P(t_q <= b_j)` is `P(slot <= K_j)` with `K_j` the largest
    /// slot whose rising edge is still `<= b_j` *in the same f64
    /// comparisons the Monte-Carlo decode performs* (the candidate
    /// from real arithmetic is corrected against the exact grid —
    /// quantized times carry large probability atoms, so boundary
    /// snapping must be bit-faithful). In current space that is
    /// `P(I >= C*V0*lambda / (K_j * t_clk))`, a normal-CDF value with
    /// the `1e-3 * i_on` clamp handled as a saturation case. Exact up
    /// to ulp-level threshold rounding in the continuous part —
    /// orders of magnitude below every tolerance here.
    pub fn analytic_row(&self, set: &SpikeTimeSet, m: usize)
        -> Vec<f64> {
        let p = &self.params;
        let k = set.levels.len();
        let mut row = vec![0.0; k];
        if m == 0 || k == 1 {
            // level 0 never spikes (GRT timeout -> lowest bucket);
            // a single bucket takes everything
            row[0] = 1.0;
            return row;
        }
        let i_nom = rc::level_current(p, m);
        let sigma = p.sigma_rel * i_nom;
        if sigma == 0.0 {
            // deterministic: one exact decode replaces all sampling
            let i = i_nom.max(1e-3 * p.i_on);
            let t = clock::quantize(p, rc::spike_time(p, set.c, i));
            let index_of = MonteCarlo::index_of(set);
            row[index_of[set.decode(t)]] = 1.0;
            return row;
        }
        let t_clk = p.t_clk();
        let i_min = 1e-3 * p.i_on;
        // f[j] = P(t_q <= boundaries[j]); boundaries descend with j,
        // so f descends too
        let mut f = vec![0.0; k - 1];
        for (j, fj) in f.iter_mut().enumerate() {
            let b = set.boundaries[j];
            debug_assert!(b.is_finite());
            // candidate snap slot from real arithmetic, corrected
            // with the exact f64 grid comparisons decode uses
            let mut kk = (b / t_clk).floor() as i64;
            while kk > 0 && kk as f64 * t_clk > b {
                kk -= 1;
            }
            while (kk + 1) as f64 * t_clk <= b {
                kk += 1;
            }
            *fj = if kk < 1 {
                // even the first clock edge is past the boundary:
                // nothing can decode on the fast side
                0.0
            } else {
                // slot <= kk  <=>  t <= kk * t_clk  <=>  I >= i_crit
                let i_crit =
                    set.c * p.v0 * p.lambda() / (kk as f64 * t_clk);
                if i_crit <= i_min {
                    1.0 // the clamp floor already spikes fast enough
                } else {
                    normal_cdf((i_nom - i_crit) / sigma)
                }
            };
        }
        // bucket 0 is t > b_0, bucket i (interior) is b_i < t <=
        // b_{i-1}, bucket k-1 is t <= b_{k-2} (see SpikeTimeSet::decode)
        row[0] = (1.0 - f[0]).max(0.0);
        for i in 1..k - 1 {
            row[i] = (f[i - 1] - f[i]).max(0.0);
        }
        row[k - 1] = f[k - 2].max(0.0);
        row
    }

    /// Analytic k x k P_map over the represented levels.
    pub fn analytic_pmap(&self, set: &SpikeTimeSet) -> Pmap {
        let p = set
            .levels
            .iter()
            .map(|&m| self.analytic_row(set, m))
            .collect();
        Pmap {
            levels: set.levels.clone(),
            p,
        }
    }

    /// Analytic full 33x33 level-transition matrix.
    pub fn analytic_full_map(&self, set: &SpikeTimeSet)
        -> Vec<Vec<f64>> {
        (0..N_LEVELS)
            .map(|m| {
                let buckets = self.analytic_row(set, m);
                let mut row = vec![0.0; N_LEVELS];
                for (j, &l) in set.levels.iter().enumerate() {
                    row[l] = buckets[j];
                }
                row
            })
            .collect()
    }

    /// Full 33x33 level-transition matrix: every physical level 0..=32 is
    /// read out through `set` (clipping of out-of-window levels and
    /// variation effects in one matrix — the runtime input of the eval
    /// engines), in the configured [`McMode`]; `sigma_rel == 0`
    /// short-circuits to the exact map.
    pub fn full_map(&self, set: &SpikeTimeSet, rng: &mut Rng)
        -> Vec<Vec<f64>> {
        self.full_map_counted(set, rng).0
    }

    /// [`MonteCarlo::full_map`] plus the draws actually consumed.
    pub fn full_map_counted(
        &self,
        set: &SpikeTimeSet,
        rng: &mut Rng,
    ) -> (Vec<Vec<f64>>, u64) {
        let _span = crate::span!("mc.full_map");
        if self.params.sigma_rel == 0.0 || self.mode == McMode::Analytic
        {
            return (self.analytic_full_map(set), 0);
        }
        match self.mode {
            McMode::Paper => self.full_map_paper(set, rng),
            McMode::Fast => self.full_map_fast(set, rng),
            McMode::Analytic => unreachable!("handled above"),
        }
    }

    /// Paper-mode full map: (level, chunk) items fan out over the
    /// pool like `pmap`; counts merge exactly before one
    /// normalization.
    fn full_map_paper(&self, set: &SpikeTimeSet, rng: &mut Rng)
        -> (Vec<Vec<f64>>, u64) {
        let parent: &Rng = rng;
        let nc = self.chunks();
        let parts: Vec<Vec<u64>> = self.pool.map(N_LEVELS * nc, |j| {
            let _span = crate::span!("mc.chunk");
            let (m, chunk) = (j / nc, j % nc);
            let mut row = vec![0u64; N_LEVELS];
            let mut r = parent.split(1000 + m as u64).split(chunk as u64);
            for _ in 0..self.chunk_span(chunk) {
                row[self.sample_decode(set, m, &mut r)] += 1;
            }
            row
        });
        let mut counts = vec![vec![0u64; N_LEVELS]; N_LEVELS];
        for (j, part) in parts.iter().enumerate() {
            let row = &mut counts[j / nc];
            for (a, b) in row.iter_mut().zip(part.iter()) {
                *a += b;
            }
        }
        let full = counts
            .iter()
            .map(|row| {
                row.iter()
                    .map(|&c| c as f64 / self.n_samples as f64)
                    .collect()
            })
            .collect();
        // level 0 never consumes a draw (no current, no sampling)
        (full, ((N_LEVELS - 1) * self.n_samples) as u64)
    }

    /// Fast-mode full map: one adaptive work item per physical level.
    fn full_map_fast(&self, set: &SpikeTimeSet, rng: &mut Rng)
        -> (Vec<Vec<f64>>, u64) {
        let parent: &Rng = rng;
        let rows: Vec<(Vec<f64>, u64)> =
            self.pool.map(N_LEVELS, |m| {
                let _span = crate::span!("mc.chunk");
                let stream = parent.split(1000 + m as u64);
                self.fast_row(set, m, &stream)
            });
        let draws = rows.iter().map(|(_, d)| d).sum();
        let full = rows
            .into_iter()
            .map(|(buckets, _)| {
                let mut row = vec![0.0; N_LEVELS];
                for (j, &l) in set.levels.iter().enumerate() {
                    row[l] = buckets[j];
                }
                row
            })
            .collect();
        (full, draws)
    }

    /// Deterministic (sigma = 0) full map: pure CapMin clipping.
    pub fn clean_map(&self, set: &SpikeTimeSet) -> Vec<Vec<f64>> {
        let p = &self.params;
        let mut full = vec![vec![0.0; N_LEVELS]; N_LEVELS];
        for (m, row) in full.iter_mut().enumerate() {
            let t = clock::quantize(p, rc::level_spike_time(p, set.c, m));
            row[set.decode(t)] = 1.0;
        }
        full
    }

    /// Variation interval E_i = [t(I+eps), t(I-eps)] with eps = 3 sigma
    /// (Fig. 6 regeneration + the r_i = |B_i|/|E_i| analysis).
    pub fn variation_interval(&self, set: &SpikeTimeSet, m: usize)
        -> (f64, f64) {
        let p = &self.params;
        let i_nom = rc::level_current(p, m);
        let eps = 3.0 * p.sigma_rel * i_nom;
        (
            rc::spike_time(p, set.c, i_nom + eps),
            rc::spike_time(p, set.c, i_nom - eps),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::pmap::tv_distance;

    fn setup(sigma: f64, window: (usize, usize)) -> (MonteCarlo, SpikeTimeSet) {
        let p = AnalogParams::paper_calibrated().with_sigma(sigma);
        let solver = crate::analog::capacitor::CapacitorSolver::new(
            p,
            crate::analog::capacitor::CapacitorModel::Physics,
        );
        let c = solver.size_for_window(window.0, window.1);
        let set = SpikeTimeSet::new(&p, c, (window.0..=window.1).collect());
        (MonteCarlo::new(p), set)
    }

    #[test]
    fn mode_parse_roundtrips_and_rejects_typos() {
        for name in McMode::CHOICES {
            assert_eq!(McMode::parse(name).unwrap().name(), *name);
        }
        let e = McMode::parse("spice").unwrap_err();
        assert!(e.to_string().contains("spice"), "{e}");
        assert!(e.to_string().contains("analytic"), "{e}");
    }

    #[test]
    fn zero_variation_gives_identity_block() {
        let (mc, set) = setup(0.0, (10, 23));
        let mut rng = Rng::new(1);
        let pm = mc.pmap(&set, &mut rng);
        for (i, row) in pm.p.iter().enumerate() {
            assert!((row[i] - 1.0).abs() < 1e-12, "row {i}: {row:?}");
        }
    }

    #[test]
    fn sigma_zero_short_circuits_every_mode_to_zero_draws() {
        // satellite: no mode burns draws reproducing a deterministic
        // clipping block
        let (mc, set) = setup(0.0, (10, 23));
        for mode in [McMode::Paper, McMode::Fast, McMode::Analytic] {
            let mc = MonteCarlo::new(mc.params).with_mode(mode);
            let (pm, draws) = mc.pmap_counted(&set, &mut Rng::new(1));
            assert_eq!(draws, 0, "{mode:?}");
            for (i, row) in pm.p.iter().enumerate() {
                assert_eq!(row[i], 1.0, "{mode:?} row {i}");
            }
            let (full, draws) =
                mc.full_map_counted(&set, &mut Rng::new(2));
            assert_eq!(draws, 0, "{mode:?}");
            assert_eq!(full, mc.clean_map(&set), "{mode:?}");
        }
    }

    #[test]
    fn zero_samples_clamped_to_one() {
        // satellite: with_samples(0) used to normalize by n = 0 and
        // emit NaN rows
        let (mc, set) = setup(0.03, (10, 23));
        let mc = mc.with_samples(0);
        assert_eq!(mc.n_samples, 1);
        let pm = mc.pmap(&set, &mut Rng::new(9));
        for (s, row) in pm.row_sums().iter().zip(pm.p.iter()) {
            assert!((s - 1.0).abs() < 1e-12, "{s}");
            assert!(row.iter().all(|v| v.is_finite()), "{row:?}");
        }
    }

    #[test]
    fn pmap_rows_are_stochastic() {
        let (mc, set) = setup(0.03, (10, 23));
        let mut rng = Rng::new(2);
        let pm = mc.pmap(&set, &mut rng);
        for s in pm.row_sums() {
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn higher_levels_less_tolerant() {
        // the paper's hypothesis: slower spike times (lower levels) have
        // larger diagonal probability
        let (mc, set) = setup(0.04, (1, 32));
        let mut rng = Rng::new(3);
        let pm = mc.pmap(&set, &mut rng);
        let d = pm.diag();
        let low_avg: f64 = d[..5].iter().sum::<f64>() / 5.0;
        let high_avg: f64 = d[d.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(
            low_avg > high_avg + 0.05,
            "low {low_avg} vs high {high_avg}"
        );
    }

    #[test]
    fn clean_map_equals_eq4_clipping() {
        let (mc, set) = setup(0.0, (10, 23));
        let full = mc.clean_map(&set);
        for m in 0..=32usize {
            let want = m.clamp(10, 23);
            assert_eq!(full[m][want], 1.0, "level {m}");
        }
    }

    #[test]
    fn analytic_rows_are_distributions() {
        let (mc, set) = setup(0.03, (10, 23));
        let pm = mc.analytic_pmap(&set);
        for s in pm.row_sums() {
            assert!((s - 1.0).abs() < 1e-6, "{s}");
        }
        let full = mc.analytic_full_map(&set);
        for (m, row) in full.iter().enumerate() {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "level {m}: {s}");
            assert!(row.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        // level 0 deterministically times out to the lowest level
        assert_eq!(full[0][10], 1.0);
    }

    #[test]
    fn full_map_and_pmap_match_the_analytic_oracle() {
        // derandomized form of the old pmap-vs-full_map cross-check:
        // both sampled maps are compared against the exact oracle, so
        // the tolerance absorbs ONE draw noise source instead of two
        let (mc, set) = setup(0.03, (12, 20));
        let pm = mc.pmap(&set, &mut Rng::new(7));
        let full = mc.full_map(&set, &mut Rng::new(8));
        let oracle = mc.analytic_pmap(&set);
        for (i, &mi) in set.levels.iter().enumerate() {
            for (j, &mj) in set.levels.iter().enumerate() {
                assert!(
                    (pm.p[i][j] - oracle.p[i][j]).abs() < 0.06,
                    "pmap ({mi},{mj}): {} vs oracle {}",
                    pm.p[i][j],
                    oracle.p[i][j]
                );
                assert!(
                    (full[mi][mj] - oracle.p[i][j]).abs() < 0.06,
                    "full ({mi},{mj}): {} vs oracle {}",
                    full[mi][mj],
                    oracle.p[i][j]
                );
            }
        }
    }

    #[test]
    fn modes_are_statistically_equivalent() {
        // the statistical-equivalence pin that replaced cross-mode
        // bit-identity: paper and fast maps sit within their declared
        // tolerance of the analytic truth, row by row (TV distance)
        let (mc, set) = setup(0.02, (10, 23));
        let oracle = mc.analytic_pmap(&set);
        let paper = mc.pmap(&set, &mut Rng::new(4));
        let fast = MonteCarlo::new(mc.params)
            .with_mode(McMode::Fast)
            .pmap(&set, &mut Rng::new(4));
        let mut fast_sum = 0.0;
        for i in 0..set.levels.len() {
            let tv_paper = tv_distance(&paper.p[i], &oracle.p[i]);
            let tv_fast = tv_distance(&fast.p[i], &oracle.p[i]);
            // 1000 iid draws: row TV vs truth concentrates well
            // under 0.04
            assert!(tv_paper < 0.04, "paper row {i}: TV {tv_paper}");
            // fast stops on a per-bucket 0.01 Wilson tolerance: rows
            // land well inside 2x the tolerance
            assert!(tv_fast < 0.02, "fast row {i}: TV {tv_fast}");
            fast_sum += tv_fast;
        }
        let fast_mean = fast_sum / set.levels.len() as f64;
        assert!(fast_mean < MC_DEFAULT_TOL, "mean fast TV {fast_mean}");
    }

    #[test]
    fn fast_mode_cuts_draws_at_least_3x() {
        let (mc, set) = setup(0.02, (10, 23));
        let (_, paper_draws) = mc.pmap_counted(&set, &mut Rng::new(5));
        let fast = MonteCarlo::new(mc.params).with_mode(McMode::Fast);
        let (_, fast_draws) = fast.pmap_counted(&set, &mut Rng::new(5));
        assert!(fast_draws > 0);
        assert!(
            paper_draws as f64 / fast_draws as f64 >= 3.0,
            "paper {paper_draws} vs fast {fast_draws}"
        );
    }

    #[test]
    fn stratified_round_covers_every_stratum_once() {
        // satellite property test: each round hits every stratum
        // exactly once, inside its quantile bounds
        for seed in [1u64, 2, 3] {
            let mut rng = Rng::new(seed);
            for strata in [8usize, 64, MC_STRATA] {
                let round = stratified_round(&mut rng, strata);
                assert_eq!(round.len(), strata);
                let mut seen = vec![0usize; strata];
                for &(s, z) in &round {
                    seen[s] += 1;
                    let lo = normal_inv_cdf(s as f64 / strata as f64);
                    let hi =
                        normal_inv_cdf((s + 1) as f64 / strata as f64);
                    assert!(
                        z >= lo - 1e-9 && z <= hi + 1e-9,
                        "stratum {s}: z {z} outside [{lo}, {hi}]"
                    );
                }
                assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
            }
        }
    }

    #[test]
    fn antithetic_pairs_mirror_exactly() {
        // satellite property test: consecutive emissions are (z, -z)
        // in mirrored strata, so every pair's mean is exactly zero
        let mut rng = Rng::new(11);
        let strata = MC_STRATA;
        let round = stratified_round(&mut rng, strata);
        for pair in round.chunks(2) {
            let (s_a, z_a) = pair[0];
            let (s_b, z_b) = pair[1];
            assert_eq!(s_b, strata - 1 - s_a);
            assert_eq!(z_b, -z_a, "antithetic mirror must be exact");
            assert_eq!(z_a + z_b, 0.0);
        }
    }

    #[test]
    fn early_stopped_map_matches_tenfold_reference_across_seeds() {
        // satellite property test: the early-stopped fast map stays
        // within the declared tolerance of a 10x-draw paper reference
        let (mc, set) = setup(0.02, (10, 23));
        let reference = MonteCarlo::new(mc.params).with_samples(10_000);
        let fast = MonteCarlo::new(mc.params).with_mode(McMode::Fast);
        for seed in [11u64, 12, 13] {
            let r = reference.pmap(&set, &mut Rng::new(seed));
            let f = fast.pmap(&set, &mut Rng::new(seed ^ 0xF00D));
            let mut sum = 0.0;
            for i in 0..set.levels.len() {
                let tv = tv_distance(&f.p[i], &r.p[i]);
                assert!(tv < 2.0 * MC_DEFAULT_TOL, "seed {seed} row {i}: {tv}");
                sum += tv;
            }
            let mean = sum / set.levels.len() as f64;
            assert!(mean < MC_DEFAULT_TOL, "seed {seed}: mean TV {mean}");
        }
    }

    #[test]
    fn ragged_sample_counts_cover_every_draw() {
        // n_samples not a multiple of MC_CHUNK: the tail chunk is
        // short, rows still sum to exactly n/n = 1
        let (mc, set) = setup(0.03, (10, 23));
        let mc = mc.with_samples(333);
        let pm = mc.pmap(&set, &mut Rng::new(5));
        for s in pm.row_sums() {
            assert!((s - 1.0).abs() < 1e-12, "{s}");
        }
        let full = mc.full_map(&set, &mut Rng::new(6));
        for row in &full {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "{s}");
        }
    }

    #[test]
    fn parallel_maps_bit_identical_to_sequential() {
        // within a mode, thread count never changes a map (the
        // *cross-mode* guarantee is statistical: see
        // modes_are_statistically_equivalent)
        for mode in [McMode::Paper, McMode::Fast] {
            let (mc_seq, set) = setup(0.03, (9, 24));
            let mc_seq = mc_seq.with_mode(mode);
            let mc_par = MonteCarlo::new(mc_seq.params)
                .with_samples(mc_seq.n_samples)
                .with_mode(mode)
                .with_threads(4);
            let a = mc_seq.pmap(&set, &mut Rng::new(21));
            let b = mc_par.pmap(&set, &mut Rng::new(21));
            assert_eq!(a.p, b.p, "{mode:?} pmap thread-dependent");
            let fa = mc_seq.full_map(&set, &mut Rng::new(22));
            let fb = mc_par.full_map(&set, &mut Rng::new(22));
            assert_eq!(fa, fb, "{mode:?} full_map thread-dependent");
        }
    }

    #[test]
    fn variation_interval_brackets_nominal() {
        let (mc, set) = setup(0.02, (10, 23));
        for m in 10..=23 {
            let t_nom = rc::level_spike_time(&mc.params, set.c, m);
            let (lo, hi) = mc.variation_interval(&set, m);
            assert!(lo < t_nom && t_nom < hi);
        }
    }

    #[test]
    fn ratio_r_grows_for_slower_spikes() {
        // r_i = |B_i| / |E_i| grows with i (slower spike times) —
        // the monotonicity CapMin-V's hypothesis rests on
        let (mc, set) = setup(0.02, (1, 32));
        let k = set.levels.len();
        let r_at = |idx: usize| {
            let (lo, hi) = mc.variation_interval(&set, set.levels[idx]);
            set.bucket_len(idx) / (hi - lo)
        };
        let r_slow = r_at(2); // low level = slow spike
        let r_fast = r_at(k - 3);
        assert!(r_slow > r_fast, "r_slow {r_slow} r_fast {r_fast}");
    }
}
