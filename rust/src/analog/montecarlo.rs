//! Monte-Carlo variation engine — the paper's SPICE-MC stand-in
//! (Sec. IV-C: 1000 samples per spike time, bucket decode at midpoints).
//!
//! Current variation is proportional to the level current (epsilon_i ~
//! sigma_rel * I_i, paper Sec. III-B); each sample charges the capacitor,
//! fires at Eq. (5)'s time, is clock-quantized, and decoded through the
//! spike-time set's decision boundaries. Counting decodes yields P_map.

use super::clock;
use super::neuron::SpikeTimeSet;
use super::params::AnalogParams;
use super::pmap::Pmap;
use super::rc;
use crate::capmin::N_LEVELS;
use crate::util::pool::ScopedPool;
use crate::util::rng::Rng;

/// Samples per independently-seeded draw chunk: the unit of work the
/// level sweep fans out over. Each (level, chunk) pair draws from its
/// own deterministic `rng.split` sub-stream, so the fan-out geometry
/// depends only on `n_samples` — never on the thread count — and the
/// default 1000-sample sweep exposes `4 x k` work items instead of
/// `k`, enough to saturate the pool even for narrow windows (the
/// CapMin-V phi sweep's common case).
pub const MC_CHUNK: usize = 250;

pub struct MonteCarlo {
    pub params: AnalogParams,
    pub n_samples: usize,
    /// Level-sweep fan-out (sequential by default). Work items are
    /// (level, chunk-of-[`MC_CHUNK`]-draws) pairs on decorrelated
    /// `rng.split` sub-streams, so any thread count produces
    /// bit-identical maps.
    pool: ScopedPool,
}

impl MonteCarlo {
    pub fn new(params: AnalogParams) -> MonteCarlo {
        MonteCarlo {
            params,
            n_samples: 1000,
            pool: ScopedPool::sequential(),
        }
    }

    pub fn with_samples(mut self, n: usize) -> MonteCarlo {
        self.n_samples = n;
        self
    }

    /// Fan the chunked sampling loops of `pmap`/`full_map` out over
    /// `threads` workers (0 = all cores). The work grid is
    /// (levels x sample chunks), so even narrow windows keep every
    /// worker busy; results are bit-identical at any setting.
    pub fn with_threads(mut self, threads: usize) -> MonteCarlo {
        self.pool = if threads == 1 {
            ScopedPool::sequential()
        } else {
            ScopedPool::new(threads)
        };
        self
    }

    /// Fan over a caller-supplied pool instead of constructing one —
    /// a long-running session shares one persistent crew across every
    /// solve (DESIGN.md §12). Results are bit-identical either way.
    pub fn with_pool(mut self, pool: ScopedPool) -> MonteCarlo {
        self.pool = pool;
        self
    }

    /// One varied read-out of level `m` through `set`: sample the current,
    /// fire, quantize, decode.
    pub fn sample_decode(
        &self,
        set: &SpikeTimeSet,
        m: usize,
        rng: &mut Rng,
    ) -> usize {
        let p = &self.params;
        if m == 0 {
            // no conducting cell -> no current -> GRT timeout
            return set.levels[0];
        }
        let i_nom = rc::level_current(p, m);
        let i = rng
            .normal_scaled(i_nom, p.sigma_rel * i_nom)
            .max(1e-3 * p.i_on);
        let t = clock::quantize(p, rc::spike_time(p, set.c, i));
        set.decode(t)
    }

    /// The (chunk index -> sample range) schedule: fixed-size
    /// [`MC_CHUNK`] spans, so it is a pure function of `n_samples`.
    fn chunks(&self) -> usize {
        self.n_samples.div_ceil(MC_CHUNK).max(1)
    }

    /// Sample counts of chunk `c`.
    fn chunk_span(&self, c: usize) -> usize {
        let lo = c * MC_CHUNK;
        let hi = ((c + 1) * MC_CHUNK).min(self.n_samples);
        hi.saturating_sub(lo)
    }

    /// k x k P_map over the represented levels (paper Eq. 6).
    ///
    /// Each (level, chunk) work item samples an independent
    /// `rng.split(level).split(chunk)` stream (the parent state is
    /// never advanced), so fanning the chunked loop over the pool is
    /// bit-identical to the sequential sweep at any thread count.
    /// Decoded levels map to row slots through a precomputed
    /// level->index table instead of an O(k) scan per sample.
    pub fn pmap(&self, set: &SpikeTimeSet, rng: &mut Rng) -> Pmap {
        let k = set.levels.len();
        let mut index_of = [usize::MAX; N_LEVELS];
        for (i, &l) in set.levels.iter().enumerate() {
            index_of[l] = i;
        }
        let parent: &Rng = rng;
        let nc = self.chunks();
        let parts: Vec<Vec<u64>> = self.pool.map(k * nc, |j| {
            let (i, chunk) = (j / nc, j % nc);
            let m = set.levels[i];
            let mut row = vec![0u64; k];
            let mut r = parent.split(m as u64 + 1).split(chunk as u64);
            for _ in 0..self.chunk_span(chunk) {
                let d = self.sample_decode(set, m, &mut r);
                row[index_of[d]] += 1;
            }
            row
        });
        // merge chunk partials per level, in chunk order (exact: u64)
        let mut counts = vec![vec![0u64; k]; k];
        for (j, part) in parts.iter().enumerate() {
            let row = &mut counts[j / nc];
            for (a, b) in row.iter_mut().zip(part.iter()) {
                *a += b;
            }
        }
        let p = counts
            .iter()
            .map(|row| {
                row.iter()
                    .map(|&c| c as f64 / self.n_samples as f64)
                    .collect()
            })
            .collect();
        Pmap {
            levels: set.levels.clone(),
            p,
        }
    }

    /// Full 33x33 level-transition matrix: every physical level 0..=32 is
    /// read out through `set` (clipping of out-of-window levels and
    /// variation effects in one matrix — the runtime input of the eval
    /// engines). (Level, chunk) items fan out over the pool like
    /// `pmap`; counts merge exactly before one normalization.
    pub fn full_map(&self, set: &SpikeTimeSet, rng: &mut Rng)
        -> Vec<Vec<f64>> {
        let parent: &Rng = rng;
        let nc = self.chunks();
        let parts: Vec<Vec<u64>> = self.pool.map(N_LEVELS * nc, |j| {
            let (m, chunk) = (j / nc, j % nc);
            let mut row = vec![0u64; N_LEVELS];
            let mut r = parent.split(1000 + m as u64).split(chunk as u64);
            for _ in 0..self.chunk_span(chunk) {
                row[self.sample_decode(set, m, &mut r)] += 1;
            }
            row
        });
        let mut counts = vec![vec![0u64; N_LEVELS]; N_LEVELS];
        for (j, part) in parts.iter().enumerate() {
            let row = &mut counts[j / nc];
            for (a, b) in row.iter_mut().zip(part.iter()) {
                *a += b;
            }
        }
        counts
            .iter()
            .map(|row| {
                row.iter()
                    .map(|&c| c as f64 / self.n_samples as f64)
                    .collect()
            })
            .collect()
    }

    /// Deterministic (sigma = 0) full map: pure CapMin clipping.
    pub fn clean_map(&self, set: &SpikeTimeSet) -> Vec<Vec<f64>> {
        let p = &self.params;
        let mut full = vec![vec![0.0; N_LEVELS]; N_LEVELS];
        for (m, row) in full.iter_mut().enumerate() {
            let t = clock::quantize(p, rc::level_spike_time(p, set.c, m));
            row[set.decode(t)] = 1.0;
        }
        full
    }

    /// Variation interval E_i = [t(I+eps), t(I-eps)] with eps = 3 sigma
    /// (Fig. 6 regeneration + the r_i = |B_i|/|E_i| analysis).
    pub fn variation_interval(&self, set: &SpikeTimeSet, m: usize)
        -> (f64, f64) {
        let p = &self.params;
        let i_nom = rc::level_current(p, m);
        let eps = 3.0 * p.sigma_rel * i_nom;
        (
            rc::spike_time(p, set.c, i_nom + eps),
            rc::spike_time(p, set.c, i_nom - eps),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(sigma: f64, window: (usize, usize)) -> (MonteCarlo, SpikeTimeSet) {
        let p = AnalogParams::paper_calibrated().with_sigma(sigma);
        let solver = crate::analog::capacitor::CapacitorSolver::new(
            p,
            crate::analog::capacitor::CapacitorModel::Physics,
        );
        let c = solver.size_for_window(window.0, window.1);
        let set = SpikeTimeSet::new(&p, c, (window.0..=window.1).collect());
        (MonteCarlo::new(p), set)
    }

    #[test]
    fn zero_variation_gives_identity_block() {
        let (mc, set) = setup(0.0, (10, 23));
        let mut rng = Rng::new(1);
        let pm = mc.pmap(&set, &mut rng);
        for (i, row) in pm.p.iter().enumerate() {
            assert!((row[i] - 1.0).abs() < 1e-12, "row {i}: {row:?}");
        }
    }

    #[test]
    fn pmap_rows_are_stochastic() {
        let (mc, set) = setup(0.03, (10, 23));
        let mut rng = Rng::new(2);
        let pm = mc.pmap(&set, &mut rng);
        for s in pm.row_sums() {
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn higher_levels_less_tolerant() {
        // the paper's hypothesis: slower spike times (lower levels) have
        // larger diagonal probability
        let (mc, set) = setup(0.04, (1, 32));
        let mut rng = Rng::new(3);
        let pm = mc.pmap(&set, &mut rng);
        let d = pm.diag();
        let low_avg: f64 = d[..5].iter().sum::<f64>() / 5.0;
        let high_avg: f64 = d[d.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(
            low_avg > high_avg + 0.05,
            "low {low_avg} vs high {high_avg}"
        );
    }

    #[test]
    fn clean_map_equals_eq4_clipping() {
        let (mc, set) = setup(0.0, (10, 23));
        let full = mc.clean_map(&set);
        for m in 0..=32usize {
            let want = m.clamp(10, 23);
            assert_eq!(full[m][want], 1.0, "level {m}");
        }
    }

    #[test]
    fn full_map_statistics_match_pmap_block() {
        let (mc, set) = setup(0.03, (12, 20));
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(8);
        let pm = mc.pmap(&set, &mut r1);
        let full = mc.full_map(&set, &mut r2);
        for (i, &mi) in set.levels.iter().enumerate() {
            for (j, &mj) in set.levels.iter().enumerate() {
                assert!(
                    (pm.p[i][j] - full[mi][mj]).abs() < 0.06,
                    "({mi},{mj}): {} vs {}",
                    pm.p[i][j],
                    full[mi][mj]
                );
            }
        }
    }

    #[test]
    fn ragged_sample_counts_cover_every_draw() {
        // n_samples not a multiple of MC_CHUNK: the tail chunk is
        // short, rows still sum to exactly n/n = 1
        let (mc, set) = setup(0.03, (10, 23));
        let mc = mc.with_samples(333);
        let pm = mc.pmap(&set, &mut Rng::new(5));
        for s in pm.row_sums() {
            assert!((s - 1.0).abs() < 1e-12, "{s}");
        }
        let full = mc.full_map(&set, &mut Rng::new(6));
        for row in &full {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "{s}");
        }
    }

    #[test]
    fn parallel_maps_bit_identical_to_sequential() {
        let (mc_seq, set) = setup(0.03, (9, 24));
        let mc_par = MonteCarlo::new(mc_seq.params)
            .with_samples(mc_seq.n_samples)
            .with_threads(4);
        let a = mc_seq.pmap(&set, &mut Rng::new(21));
        let b = mc_par.pmap(&set, &mut Rng::new(21));
        assert_eq!(a.p, b.p, "pmap must not depend on thread count");
        let fa = mc_seq.full_map(&set, &mut Rng::new(22));
        let fb = mc_par.full_map(&set, &mut Rng::new(22));
        assert_eq!(fa, fb, "full_map must not depend on thread count");
    }

    #[test]
    fn variation_interval_brackets_nominal() {
        let (mc, set) = setup(0.02, (10, 23));
        for m in 10..=23 {
            let t_nom = rc::level_spike_time(&mc.params, set.c, m);
            let (lo, hi) = mc.variation_interval(&set, m);
            assert!(lo < t_nom && t_nom < hi);
        }
    }

    #[test]
    fn ratio_r_grows_for_slower_spikes() {
        // r_i = |B_i| / |E_i| grows with i (slower spike times) —
        // the monotonicity CapMin-V's hypothesis rests on
        let (mc, set) = setup(0.02, (1, 32));
        let k = set.levels.len();
        let r_at = |idx: usize| {
            let (lo, hi) = mc.variation_interval(&set, set.levels[idx]);
            set.bucket_len(idx) / (hi - lo)
        };
        let r_slow = r_at(2); // low level = slow spike
        let r_fast = r_at(k - 3);
        assert!(r_slow > r_fast, "r_slow {r_slow} r_fast {r_fast}");
    }
}
