//! P_map — the spike-time transition-probability matrix (paper Eq. 6).
//!
//! Rows index the *true* spike time (level), columns the spike time
//! actually selected under current variation. CapMin-V (Alg. 1) edits
//! this matrix by merging columns/rows; the evaluator expands any P_map
//! into the full 33x33 level-transition matrix that the AOT kernels take
//! as a runtime input (row-CDF form).

use crate::capmin::N_LEVELS;

#[derive(Clone, Debug)]
pub struct Pmap {
    /// Represented levels, ascending (row/col labels).
    pub levels: Vec<usize>,
    /// Row-stochastic transition matrix, p[i][j] = P(level_i read as
    /// level_j).
    pub p: Vec<Vec<f64>>,
}

impl Pmap {
    pub fn identity(levels: Vec<usize>) -> Pmap {
        let k = levels.len();
        let mut p = vec![vec![0.0; k]; k];
        for (i, row) in p.iter_mut().enumerate() {
            row[i] = 1.0;
        }
        Pmap { levels, p }
    }

    pub fn k(&self) -> usize {
        self.levels.len()
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.k()).map(|i| self.p[i][i]).collect()
    }

    /// Index of the smallest diagonal element (Alg. 1 line 4).
    pub fn argmin_diag(&self) -> usize {
        let d = self.diag();
        let mut best = 0;
        for (i, &v) in d.iter().enumerate() {
            if v < d[best] {
                best = i;
            }
        }
        best
    }

    /// Merge column `j` into column `dst` (dst = j-1 or j+1), then remove
    /// row and column `j` (Alg. 1 lines 6-13). The merged bucket is the
    /// union of the two old decision intervals, so adding columns is
    /// exact, not an approximation.
    pub fn merge_into(&mut self, j: usize, dst: usize) {
        assert!(dst == j.wrapping_sub(1) || dst == j + 1);
        let k = self.k();
        assert!(j < k && dst < k);
        for row in self.p.iter_mut() {
            row[dst] += row[j];
            row.remove(j);
        }
        self.p.remove(j);
        self.levels.remove(j);
    }

    /// Row sums (must stay 1 under merges; checked by tests).
    pub fn row_sums(&self) -> Vec<f64> {
        self.p.iter().map(|r| r.iter().sum()).collect()
    }

    /// Expand to the full 33x33 level-transition matrix: rows for all
    /// levels 0..=32; unrepresented rows take the transition profile of
    /// the row computed for them by the caller (see montecarlo::full_map)
    /// — this type only handles the represented block plus deterministic
    /// clipping padding (Alg. 1 line 15: "add padding ... and 1s to
    /// realize the clipping from CapMin").
    pub fn pad_to_full(&self) -> Vec<Vec<f64>> {
        let mut full = vec![vec![0.0; N_LEVELS]; N_LEVELS];
        let lo = self.levels[0];
        let hi = *self.levels.last().unwrap();
        for m in 0..N_LEVELS {
            if m < lo {
                full[m][lo] = 1.0; // clip low (incl. level 0: no spike)
            } else if m > hi {
                full[m][hi] = 1.0; // clip high
            }
        }
        for (i, &mi) in self.levels.iter().enumerate() {
            for (j, &mj) in self.levels.iter().enumerate() {
                full[mi][mj] = self.p[i][j];
            }
        }
        // unrepresented interior levels (CapMin-V removed their spike
        // time): decode to the nearest represented level
        for m in lo..=hi {
            if !self.levels.contains(&m) {
                let nearest = self
                    .levels
                    .iter()
                    .min_by_key(|&&l| {
                        (l as i64 - m as i64).unsigned_abs()
                    })
                    .copied()
                    .unwrap();
                full[m] = vec![0.0; N_LEVELS];
                full[m][nearest] = 1.0;
            }
        }
        full
    }
}

/// Total-variation distance between two discrete distributions over the
/// same support: `0.5 * sum_i |a_i - b_i|`. The statistical-equivalence
/// metric of the Monte-Carlo mode pins (DESIGN.md §15): two P_map rows
/// are "the same answer" when their TV distance is inside the solver
/// tolerance, which is how the fast, paper and analytic modes are held
/// together now that they are no longer bit-identical.
pub fn tv_distance(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    0.5 * a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>()
}

/// Row-CDF (f32, 33x33 flattened row-major) + decoded level values, the
/// exact runtime-input format of the AOT eval artifacts.
pub fn to_cdf_inputs(full: &[Vec<f64>]) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(full.len(), N_LEVELS);
    let mut cdf = Vec::with_capacity(N_LEVELS * N_LEVELS);
    for row in full {
        assert_eq!(row.len(), N_LEVELS);
        let mut acc = 0.0f64;
        for (j, &v) in row.iter().enumerate() {
            acc += v;
            // clamp + pin the last column to exactly 1.0 so the kernel's
            // CDF inversion can never walk off the row
            let c = if j == N_LEVELS - 1 { 1.0 } else { acc.min(1.0) };
            cdf.push(c as f32);
        }
    }
    let vals: Vec<f32> = (0..N_LEVELS).map(|m| m as f32).collect();
    (cdf, vals)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Pmap {
        let levels: Vec<usize> = (10..=13).collect();
        let p = vec![
            vec![0.9, 0.1, 0.0, 0.0],
            vec![0.1, 0.8, 0.1, 0.0],
            vec![0.0, 0.2, 0.6, 0.2],
            vec![0.0, 0.0, 0.1, 0.9],
        ];
        Pmap { levels, p }
    }

    #[test]
    fn merge_preserves_row_stochasticity() {
        let mut pm = sample();
        pm.merge_into(2, 3);
        assert_eq!(pm.k(), 3);
        for s in pm.row_sums() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        assert_eq!(pm.levels, vec![10, 11, 13]);
    }

    #[test]
    fn merge_raises_destination_diag() {
        let pm = sample();
        let before = pm.p[3][3];
        let mut pm2 = pm.clone();
        pm2.merge_into(2, 3);
        // new diag of (old) level 13 row: p[13][13] + p[13][12]
        let after = pm2.p[2][2];
        assert!(after >= before);
        assert!((after - (0.9 + 0.1)).abs() < 1e-12);
    }

    #[test]
    fn pad_to_full_clips_like_eq4() {
        let pm = sample();
        let full = pm.pad_to_full();
        assert_eq!(full[0][10], 1.0, "level 0 -> q_lo");
        assert_eq!(full[5][10], 1.0, "below window -> q_lo");
        assert_eq!(full[32][13], 1.0, "above window -> q_hi");
        assert_eq!(full[11][11], 0.8, "represented block preserved");
    }

    #[test]
    fn pad_handles_removed_interior_level() {
        let mut pm = sample();
        pm.merge_into(1, 0); // remove level 11
        let full = pm.pad_to_full();
        // level 11 physically still occurs; decodes to nearest (10)
        assert_eq!(full[11][10], 1.0);
    }

    #[test]
    fn cdf_rows_end_at_one() {
        let pm = sample();
        let (cdf, vals) = to_cdf_inputs(&pm.pad_to_full());
        assert_eq!(cdf.len(), 33 * 33);
        for m in 0..33 {
            assert_eq!(cdf[m * 33 + 32], 1.0);
            // monotone
            for j in 1..33 {
                assert!(cdf[m * 33 + j] >= cdf[m * 33 + j - 1]);
            }
        }
        assert_eq!(vals[32], 32.0);
    }

    #[test]
    fn identity_pmap_is_identity() {
        let pm = Pmap::identity((5..=8).collect());
        assert_eq!(pm.argmin_diag(), 0);
        assert!(pm.diag().iter().all(|&d| d == 1.0));
    }
}
