//! RC membrane charging — the paper's Eqs. (2), (3), (5).

use super::params::AnalogParams;

/// Voltage across the membrane capacitor at time `t` under constant
/// initial current `i_init` (paper Eq. 3):
/// `V(t) = V0 * (1 - exp(-t * i_init / (C * V0)))`.
pub fn v_of_t(p: &AnalogParams, c: f64, i_init: f64, t: f64) -> f64 {
    p.v0 * (1.0 - (-t * i_init / (c * p.v0)).exp())
}

/// Ideal (unquantized) spike time for initial current `i` (paper Eq. 5):
/// `t(I) = -(C*V0/I) * ln(1 - Vth/V0) = C*V0*lambda / I`.
/// Returns +inf for non-positive current (level 0 never fires).
pub fn spike_time(p: &AnalogParams, c: f64, i: f64) -> f64 {
    if i <= 0.0 {
        return f64::INFINITY;
    }
    c * p.v0 * p.lambda() / i
}

/// Current for sub-MAC level `m` (Kirchhoff sum of m conducting cells).
pub fn level_current(p: &AnalogParams, m: usize) -> f64 {
    m as f64 * p.i_on
}

/// Ideal spike time of sub-MAC level `m` with capacitance `c`.
pub fn level_spike_time(p: &AnalogParams, c: f64, m: usize) -> f64 {
    spike_time(p, c, level_current(p, m))
}

/// Sample of the V(t) curve for plotting (Fig. 3 regeneration).
pub fn charging_curve(
    p: &AnalogParams,
    c: f64,
    i_init: f64,
    t_end: f64,
    n: usize,
) -> Vec<(f64, f64)> {
    (0..n)
        .map(|j| {
            let t = t_end * j as f64 / (n - 1) as f64;
            (t, v_of_t(p, c, i_init, t))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> AnalogParams {
        AnalogParams::paper_calibrated()
    }

    #[test]
    fn charging_is_monotone_and_saturates() {
        let p = p();
        let c = 10e-12;
        let i = 1e-5;
        let mut prev = -1.0;
        for j in 1..100 {
            let v = v_of_t(&p, c, i, j as f64 * 1e-9);
            assert!(v > prev);
            prev = v;
        }
        let v_late = v_of_t(&p, c, i, 1.0);
        assert!((v_late - p.v0).abs() < 1e-9, "saturates at V0");
    }

    #[test]
    fn spike_time_crosses_vth_exactly() {
        let p = p();
        let c = 20e-12;
        for m in 1..=32 {
            let i = level_current(&p, m);
            let t = spike_time(&p, c, i);
            let v = v_of_t(&p, c, i, t);
            assert!((v - p.vth).abs() < 1e-12, "m={m} v={v}");
        }
    }

    #[test]
    fn spike_time_reciprocal_in_current() {
        let p = p();
        let c = 5e-12;
        let t1 = level_spike_time(&p, c, 1);
        let t2 = level_spike_time(&p, c, 2);
        let t32 = level_spike_time(&p, c, 32);
        assert!((t1 / t2 - 2.0).abs() < 1e-12);
        assert!((t1 / t32 - 32.0).abs() < 1e-12);
    }

    #[test]
    fn level_zero_never_fires() {
        let p = p();
        assert!(level_spike_time(&p, 10e-12, 0).is_infinite());
    }

    #[test]
    fn faster_charging_with_larger_current_smaller_cap() {
        let p = p();
        let base = spike_time(&p, 10e-12, 1e-5);
        assert!(spike_time(&p, 10e-12, 2e-5) < base);
        assert!(spike_time(&p, 5e-12, 1e-5) < base);
    }
}
