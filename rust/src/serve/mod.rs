//! `capmin serve` — a long-running, multi-client operating-point +
//! inference server (DESIGN.md §12).
//!
//! Every other entry point in this crate pays the full warmup bill —
//! model folding, bit-packing, point-cache priming — once per process
//! and then exits. The serve subsystem keeps all of that hot behind a
//! TCP socket speaking newline-delimited JSON (the same hand-rolled
//! [`crate::util::json`] the run store uses; no HTTP stack offline —
//! DESIGN.md §8):
//!
//! * [`protocol`] — typed, versioned request/response forms
//!   (`Point`, `Infer`, `Stats`, `Shutdown`) with structured error
//!   replies;
//! * [`server`] — the accept loop, a fixed crew of connection workers
//!   spawned once at startup, a session thread owning the one warm
//!   [`crate::session::DesignSession`], and graceful drain on
//!   shutdown;
//! * [`batcher`] — the micro-batching queue that coalesces concurrent
//!   `Infer` requests into one
//!   [`crate::backend::NativeBackend::forward_many`] entry, replies
//!   bit-identical to solo execution;
//! * [`metrics`] — request counters plus batch-size and latency
//!   histograms, served through `Stats`;
//! * [`client`] — the blocking line-protocol client the loopback
//!   tests, the loadgen bench and `examples/serve_client.rs` share.
//!
//! Thread model (all spawned once, at startup — no thread or pool
//! construction on the request path):
//!
//! ```text
//!  accept loop ── conn queue ──> worker 0..W  (socket IO, parse)
//!                                  │      │
//!                    Point/Prepare │      │ Infer jobs
//!                                  v      v
//!                          session thread  batcher thread
//!                          (DesignSession, (NativeBackend,
//!                           persistent      persistent kernel
//!                           solve pool)     pool, micro-batches)
//! ```

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use server::{ServeOptions, Server};
