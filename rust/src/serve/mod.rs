//! `capmin serve` — a long-running, multi-client operating-point +
//! inference server (DESIGN.md §12, §16).
//!
//! Every other entry point in this crate pays the full warmup bill —
//! model folding, bit-packing, point-cache priming — once per process
//! and then exits. The serve subsystem keeps all of that hot behind a
//! TCP socket speaking newline-delimited JSON (the same hand-rolled
//! [`crate::util::json`] the run store uses; no HTTP stack offline —
//! DESIGN.md §8):
//!
//! * [`protocol`] — typed, versioned request/response forms
//!   (`Point`, `Infer`, `Stats`, `Shutdown`, the shard-internal
//!   `PeerPoint`) with structured error replies, including the
//!   admission-control `overloaded` shed;
//! * [`reactor`] — the epoll/kqueue event-loop threads that own every
//!   socket non-blocking: NDJSON framing, per-connection reply
//!   ordering, admission control, slow-client shedding and slowloris
//!   timeouts (built on [`crate::util::evloop`]);
//! * [`server`] — the non-blocking acceptor, reactor crew, the
//!   session thread owning the one warm
//!   [`crate::session::DesignSession`] (plus the shard ring's peer
//!   links), and graceful drain on shutdown;
//! * [`batcher`] — the micro-batching queue that coalesces concurrent
//!   `Infer` requests into one
//!   [`crate::backend::NativeBackend::forward_many`] entry, replies
//!   bit-identical to solo execution;
//! * [`shard`] — consistent hashing of operating-point cache keys
//!   over a ring of serving processes;
//! * [`metrics`] — request counters, batch-size and latency
//!   histograms, queue-depth/admission/connection gauges and
//!   peer-fetch counters — all registered on the process-global
//!   [`crate::obs::registry`] (DESIGN.md §17), so a `Stats` reply
//!   (or `stats --prom`) also exposes the session/MC/kernel series
//!   bumped by the same requests, plus the per-phase
//!   queue/batch-wait/forward/solve histograms;
//! * [`client`] — the blocking line-protocol client the loopback
//!   tests, the loadgen bench and `examples/serve_client.rs` share,
//!   with jittered-backoff retry ([`client::Backoff`]) for connects
//!   and sheds.
//!
//! Telemetry (DESIGN.md §17): every admitted compute request gets a
//! trace id at admission, carried reactor → session → batcher →
//! backend and echoed on the reply as a hex `"trace"` field; under
//! `--trace` the spans it links (`serve.queue`, `serve.batch`,
//! `backend.forward`, `serve.reply`, …) land in the Chrome-trace
//! export. Raw prints are gone — the serve tier logs through the
//! leveled [`crate::log_info!`]-family macros gated by `--log-level`.
//!
//! Thread model (all spawned once, at startup — no thread or pool
//! construction on the request path, and no thread ever blocked on a
//! client socket):
//!
//! ```text
//!  acceptor ──round robin──> reactor 0..R   (epoll/kqueue loops:
//!                              │      ^      all sockets, framing,
//!                     Work     │      │      admission, ordering)
//!                              v      │ replies (inbox + waker)
//!                        session thread ───────────┐
//!                        (DesignSession,           │ InferJob
//!                         persistent solve pool,   v
//!                         peer links to shards)  batcher thread
//!                                                (NativeBackend,
//!                                                 persistent kernel
//!                                                 pool, micro-batches)
//! ```

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod reactor;
pub mod server;
pub mod shard;

pub use client::{Backoff, Client, Overloaded};
pub use server::{ServeOptions, Server};
pub use shard::HashRing;
