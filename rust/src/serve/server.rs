//! The serve runtime (DESIGN.md §12): accept loop, a fixed crew of
//! connection workers, the session thread owning the one warm
//! [`DesignSession`], and the batcher thread owning the serving
//! [`NativeBackend`] — every thread and pool spawned once at startup,
//! nothing constructed per request.
//!
//! Lifetimes / shutdown (the drain order is the design):
//!
//! 1. a `Shutdown` request flips the flag and pokes the accept loop
//!    awake; the requesting connection is answered, then closed;
//! 2. the accept loop stops and drops the connection queue — workers
//!    finish their current connections (in-flight requests complete
//!    and reply) and exit;
//! 3. with every worker gone, the batcher's job senders are gone: it
//!    finishes the queued micro-batches and exits; likewise the
//!    session thread;
//! 4. `run`/`Server::join` returns only after every thread is joined,
//!    so a clean exit means a clean drain.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::arch;
use crate::backend::kernels::KernelKind;
use crate::backend::native::NativeBackend;
use crate::bnn::ErrorModel;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::store::NamedTensor;
use crate::data::synth::Dataset;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::json::{obj, Json};
use crate::util::pool::ScopedPool;

use super::batcher::{self, BatchPolicy, InferJob};
use super::metrics::{Kind, Metrics};
use super::protocol::{self, Request};

/// How often a blocked connection read wakes up to check the shutdown
/// flag.
const READ_POLL: Duration = Duration::from_millis(50);

#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub addr: SocketAddr,
    /// Most `Infer` requests coalesced into one backend entry.
    pub max_batch: usize,
    /// Longest a ready request waits for company (milliseconds).
    pub max_wait_ms: u64,
    /// Datasets to pre-warm (fold + F_MAC) before serving traffic.
    pub warm: Vec<Dataset>,
}

impl ServeOptions {
    pub fn new(addr: SocketAddr) -> ServeOptions {
        ServeOptions {
            addr,
            max_batch: 8,
            max_wait_ms: 2,
            warm: vec![],
        }
    }
}

/// Static facts fixed at startup, reported by `Stats` so clients can
/// pin that nothing is re-spawned per request.
struct ServerInfo {
    addr: SocketAddr,
    backend: &'static str,
    workers: usize,
    /// Persistent kernel-pool crews: (session solve pool, batcher
    /// inference pool). Stable for the server's life.
    session_pool_workers: usize,
    infer_pool_workers: usize,
    max_batch: usize,
    max_wait_ms: u64,
}

impl ServerInfo {
    fn to_json(&self) -> Json {
        obj(vec![
            ("backend", Json::Str(self.backend.to_string())),
            ("workers", Json::Num(self.workers as f64)),
            (
                "session_pool_workers",
                Json::Num(self.session_pool_workers as f64),
            ),
            (
                "infer_pool_workers",
                Json::Num(self.infer_pool_workers as f64),
            ),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("max_wait_ms", Json::Num(self.max_wait_ms as f64)),
        ])
    }
}

/// Everything a prepared `Infer` needs, resolved once per
/// (dataset, k, sigma, phi) by the session thread and cached there.
#[derive(Clone)]
struct Prepared {
    model: &'static str,
    pixels: usize,
    n_classes: usize,
    folded: Arc<Vec<NamedTensor>>,
    ems: Arc<Vec<ErrorModel>>,
}

enum SessionMsg {
    Point {
        spec: OperatingPointSpec,
        reply: Sender<Result<(String, Arc<OperatingPoint>), String>>,
    },
    Prepare {
        ds: Dataset,
        k: usize,
        sigma: f64,
        phi: usize,
        reply: Sender<Result<Prepared, String>>,
    },
}

/// A running server handle (`spawn`); `join` blocks until drain.
pub struct Server {
    addr: SocketAddr,
    handle: JoinHandle<Result<()>>,
}

impl Server {
    /// The actually bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn join(self) -> Result<()> {
        self.handle
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))?
    }
}

/// Bind and serve on a background thread (tests, benches, examples).
pub fn spawn(
    cfg: ExperimentConfig,
    opts: ServeOptions,
) -> Result<Server> {
    let listener = TcpListener::bind(opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?;
    let addr = listener.local_addr()?;
    let handle =
        std::thread::spawn(move || run_bound(listener, cfg, opts));
    Ok(Server { addr, handle })
}

/// Bind and serve on the calling thread (the CLI entry); returns after
/// a clean `Shutdown` drain.
pub fn run(cfg: ExperimentConfig, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?;
    println!(
        "capmin serve: listening on {}",
        listener.local_addr()?
    );
    run_bound(listener, cfg, opts)
}

fn run_bound(
    listener: TcpListener,
    cfg: ExperimentConfig,
    opts: ServeOptions,
) -> Result<()> {
    let addr = listener.local_addr()?;
    let threads = ScopedPool::new(cfg.threads).threads();
    // enough connection workers that a full micro-batch of
    // single-request clients can be in flight at once (workers block
    // on their request's reply; they are IO threads, not compute)
    let workers = threads.max(opts.max_batch).clamp(2, 64);
    let metrics = Arc::new(Metrics::new());
    let shutdown = Arc::new(AtomicBool::new(false));

    // both kernel crews are spawned here, once, and only referenced
    // afterwards (ScopedPool::spawned_workers stays constant)
    let session_pool = ScopedPool::persistent(cfg.threads);
    let infer_pool = ScopedPool::persistent(cfg.threads);
    let info = Arc::new(ServerInfo {
        addr,
        backend: "native",
        workers,
        session_pool_workers: session_pool.spawned_workers(),
        infer_pool_workers: infer_pool.spawned_workers(),
        max_batch: opts.max_batch.max(1),
        max_wait_ms: opts.max_wait_ms,
    });

    // session thread: owns the one warm DesignSession
    let (session_tx, session_rx) = mpsc::channel::<SessionMsg>();
    let session_handle = {
        let cfg = cfg.clone();
        let warm = opts.warm.clone();
        std::thread::spawn(move || {
            session_thread(cfg, warm, session_pool, session_rx)
        })
    };

    // batcher thread: owns the serving NativeBackend
    let (infer_tx, infer_rx) = mpsc::channel::<InferJob>();
    let batcher_handle = {
        let kind = KernelKind::resolve(&cfg.kernel)
            .unwrap_or_else(|_| KernelKind::detect());
        let backend = NativeBackend::with_pool(infer_pool, kind, true);
        let policy = BatchPolicy {
            max_batch: opts.max_batch.max(1),
            max_wait: Duration::from_millis(opts.max_wait_ms),
        };
        let metrics = metrics.clone();
        std::thread::spawn(move || {
            batcher::run(infer_rx, backend, policy, metrics)
        })
    };

    // connection workers: the fixed crew, spawned once. `admitted`
    // counts connections handed to the crew and not yet finished, so
    // the accept loop can refuse (with a structured error, not silent
    // starvation) instead of queueing behind long-lived connections.
    let admitted = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let worker_handles: Vec<JoinHandle<()>> = (0..workers)
        .map(|_| {
            let conn_rx = conn_rx.clone();
            let session_tx = session_tx.clone();
            let infer_tx = infer_tx.clone();
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            let info = info.clone();
            let admitted = admitted.clone();
            std::thread::spawn(move || {
                worker_loop(
                    &conn_rx, &session_tx, &infer_tx, &metrics,
                    &shutdown, &info, &admitted,
                )
            })
        })
        .collect();
    // workers hold the only long-lived clones: when they exit, the
    // compute threads see their queues close and drain out
    drop(session_tx);
    drop(infer_tx);

    // accept loop (this thread)
    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break; // the waking connection is dropped unserved
        }
        match conn {
            Ok(mut stream) => {
                // every worker busy AND a full extra batch already
                // queued: refuse loudly rather than park the client
                // behind connections that may never close
                if admitted.load(Ordering::SeqCst) >= 2 * workers {
                    metrics.inc_error();
                    let mut s = protocol::error_response(
                        None,
                        &format!(
                            "server at connection capacity ({workers} \
                             workers busy, {workers} queued) — retry"
                        ),
                    )
                    .to_string();
                    s.push('\n');
                    let _ = stream.write_all(s.as_bytes());
                    continue; // stream drops closed
                }
                admitted.fetch_add(1, Ordering::SeqCst);
                // a send can only fail after every worker exited,
                // which only happens on shutdown
                if conn_tx.send(stream).is_err() {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => continue,
        }
    }
    drop(conn_tx);
    for h in worker_handles {
        let _ = h.join();
    }
    let _ = batcher_handle.join();
    let _ = session_handle.join();
    Ok(())
}

/// The session thread: builds the `DesignSession` (on its own thread —
/// the session facade is deliberately single-threaded), pre-warms the
/// requested datasets, then serves Point/Prepare messages until every
/// worker is gone.
fn session_thread(
    cfg: ExperimentConfig,
    warm: Vec<Dataset>,
    pool: ScopedPool,
    rx: Receiver<SessionMsg>,
) {
    let session = match DesignSession::builder()
        .config(cfg)
        .pool(pool)
        .build()
    {
        Ok(s) => s,
        Err(e) => {
            // a session that cannot build answers every request with
            // the build error instead of hanging clients
            let msg = format!("session unavailable: {e}");
            for m in rx {
                match m {
                    SessionMsg::Point { reply, .. } => {
                        let _ = reply.send(Err(msg.clone()));
                    }
                    SessionMsg::Prepare { reply, .. } => {
                        let _ = reply.send(Err(msg.clone()));
                    }
                }
            }
            return;
        }
    };
    for ds in warm {
        // failures surface per request; warmup is best-effort priming
        if let Err(e) = session.fmac(ds) {
            eprintln!(
                "[serve] warmup {} failed: {e}",
                ds.spec().name
            );
        }
    }
    // (dataset, k, sigma bits, phi) -> prepared infer inputs
    let mut prepared: HashMap<(Dataset, usize, u64, usize), Prepared> =
        HashMap::new();
    for m in rx {
        match m {
            SessionMsg::Point { spec, reply } => {
                let r = session
                    .query(&spec)
                    .map(|p| {
                        (spec.cache_key(session.config()), p)
                    })
                    .map_err(|e| e.to_string());
                let _ = reply.send(r);
            }
            SessionMsg::Prepare {
                ds,
                k,
                sigma,
                phi,
                reply,
            } => {
                let key = (ds, k, sigma.to_bits(), phi);
                if let Some(p) = prepared.get(&key) {
                    let _ = reply.send(Ok(p.clone()));
                    continue;
                }
                let r = (|| -> Result<Prepared> {
                    let spec =
                        OperatingPointSpec::new(ds, k, sigma, phi);
                    let point = session.query(&spec)?;
                    let folded = session.folded(ds)?;
                    let dspec = ds.spec();
                    let meta = arch::model_meta(dspec.model)?;
                    Ok(Prepared {
                        model: dspec.model,
                        pixels: dspec.pixels(),
                        n_classes: meta.n_classes,
                        folded,
                        ems: Arc::new(point.ems.clone()),
                    })
                })();
                match r {
                    Ok(p) => {
                        prepared.insert(key, p.clone());
                        let _ = reply.send(Ok(p));
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e.to_string()));
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    conn_rx: &Mutex<Receiver<TcpStream>>,
    session_tx: &Sender<SessionMsg>,
    infer_tx: &Sender<InferJob>,
    metrics: &Metrics,
    shutdown: &AtomicBool,
    info: &ServerInfo,
    admitted: &std::sync::atomic::AtomicUsize,
) {
    loop {
        // one worker blocks in recv holding the lock; the rest queue
        // on the mutex — either way a new connection wakes exactly one
        let conn = { conn_rx.lock().unwrap().recv() };
        let Ok(stream) = conn else { return };
        let _ = handle_conn(
            stream, session_tx, infer_tx, metrics, shutdown, info,
        );
        admitted.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Serve one connection until EOF, a `Shutdown`, an IO error, or the
/// drain flag. Any number of requests per connection, answered in
/// order.
fn handle_conn(
    stream: TcpStream,
    session_tx: &Sender<SessionMsg>,
    infer_tx: &Sender<InferJob>,
    metrics: &Metrics,
    shutdown: &AtomicBool,
    info: &ServerInfo,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(READ_POLL))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(()); // in-flight work already replied
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // EOF
            Ok(_) => {
                let keep_going = process_line(
                    &line, &mut writer, session_tx, infer_tx, metrics,
                    shutdown, info,
                )?;
                line.clear();
                if !keep_going {
                    return Ok(());
                }
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                // poll tick; a partial line stays buffered in `line`
                continue;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

fn write_line(
    writer: &mut TcpStream,
    json: Json,
) -> std::io::Result<()> {
    let mut s = json.to_string();
    s.push('\n');
    writer.write_all(s.as_bytes())?;
    writer.flush()
}

/// Handle one request line; `Ok(false)` closes the connection (after
/// a `Shutdown`).
#[allow(clippy::too_many_arguments)]
fn process_line(
    line: &str,
    writer: &mut TcpStream,
    session_tx: &Sender<SessionMsg>,
    infer_tx: &Sender<InferJob>,
    metrics: &Metrics,
    shutdown: &AtomicBool,
    info: &ServerInfo,
) -> std::io::Result<bool> {
    if line.trim().is_empty() {
        return Ok(true); // blank keep-alives are free
    }
    let t0 = Instant::now();
    let req = match Request::parse(line) {
        Ok(r) => r,
        Err((id, msg)) => {
            metrics.inc_error();
            write_line(writer, protocol::error_response(id, &msg))?;
            return Ok(true);
        }
    };
    match req {
        Request::Stats { id } => {
            metrics.inc(Kind::Stats);
            let mut stats = match metrics.to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("metrics emit an object"),
            };
            stats.insert("server".into(), info.to_json());
            write_line(
                writer,
                protocol::stats_response(id, Json::Obj(stats)),
            )?;
            Ok(true)
        }
        Request::Shutdown { id } => {
            metrics.inc(Kind::Shutdown);
            write_line(writer, protocol::shutdown_response(id))?;
            shutdown.store(true, Ordering::SeqCst);
            // poke the accept loop out of `incoming()`; a wildcard
            // bind address is not connectable everywhere, so aim the
            // poke at loopback on the bound port
            let mut poke = info.addr;
            if poke.ip().is_unspecified() {
                poke.set_ip(match poke {
                    SocketAddr::V4(_) => std::net::IpAddr::V4(
                        std::net::Ipv4Addr::LOCALHOST,
                    ),
                    SocketAddr::V6(_) => std::net::IpAddr::V6(
                        std::net::Ipv6Addr::LOCALHOST,
                    ),
                });
            }
            let _ = TcpStream::connect(poke);
            Ok(false)
        }
        Request::Point(p) => {
            metrics.inc(Kind::Point);
            let mut spec = OperatingPointSpec::new(
                p.dataset, p.k, p.sigma, p.phi,
            );
            if p.eval {
                spec = spec.with_eval(1, 1);
            }
            let (tx, rx) = mpsc::channel();
            let sent = session_tx
                .send(SessionMsg::Point { spec, reply: tx })
                .is_ok();
            let reply = if sent {
                rx.recv().unwrap_or_else(|_| {
                    Err("session thread gone".into())
                })
            } else {
                Err("server draining".into())
            };
            let out = match reply {
                Ok((key, point)) => {
                    protocol::point_response(p.id, &key, &point)
                }
                Err(e) => {
                    metrics.inc_error();
                    protocol::error_response(Some(p.id), &e)
                }
            };
            metrics
                .point_latency_us
                .record(t0.elapsed().as_micros() as u64);
            write_line(writer, out)?;
            Ok(true)
        }
        Request::Infer(q) => {
            metrics.inc(Kind::Infer);
            let id = q.id;
            let out = run_infer(q, session_tx, infer_tx, t0);
            let out = match out {
                Ok(done) => protocol::infer_response(
                    id,
                    &done.logits,
                    done.batch,
                    done.n_classes,
                ),
                Err(e) => {
                    metrics.inc_error();
                    protocol::error_response(Some(id), &e)
                }
            };
            write_line(writer, out)?;
            Ok(true)
        }
    }
}

/// Resolve the operating point (cached in the session thread), then
/// queue the forward on the batcher and wait for the fan-back. Takes
/// the request by value so the sample buffer moves straight into the
/// job — no copies on the hot path.
fn run_infer(
    q: protocol::InferReq,
    session_tx: &Sender<SessionMsg>,
    infer_tx: &Sender<InferJob>,
    t0: Instant,
) -> Result<batcher::InferDone, String> {
    let (ptx, prx) = mpsc::channel();
    session_tx
        .send(SessionMsg::Prepare {
            ds: q.dataset,
            k: q.k,
            sigma: q.sigma,
            phi: q.phi,
            reply: ptx,
        })
        .map_err(|_| "server draining".to_string())?;
    let prep = prx
        .recv()
        .map_err(|_| "session thread gone".to_string())??;
    debug_assert_eq!(q.x.len(), q.n * prep.pixels);
    let (rtx, rrx) = mpsc::channel();
    infer_tx
        .send(InferJob {
            model: prep.model,
            n_classes: prep.n_classes,
            folded: prep.folded,
            ems: prep.ems,
            seed: q.seed,
            x: q.x,
            batch: q.n,
            reply: rtx,
            t0,
        })
        .map_err(|_| "server draining".to_string())?;
    rrx.recv().map_err(|_| "batcher gone".to_string())?
}
