//! The serve runtime (DESIGN.md §16): a non-blocking acceptor, a crew
//! of epoll/kqueue reactor threads that own every socket, the session
//! thread owning the one warm [`DesignSession`], and the batcher
//! thread owning the serving [`NativeBackend`] — every thread and pool
//! spawned once at startup, nothing constructed per request, and no
//! thread ever blocked on a client's socket.
//!
//! Request flow: reactor frames a line → admission control
//! ([`Metrics::try_admit`] bounds the compute queue; over-cap requests
//! shed with a structured `overloaded` reply) → [`Work`] to the
//! session thread → point solves answer directly, infers resolve
//! their folded model then queue on the batcher → the completed reply
//! returns through a [`reactor::ReplySink`] to the owning reactor,
//! which writes it in per-connection order.
//!
//! Sharding (`--peers`/`--shards`): N processes (or in-process
//! servers) agree on a consistent-hash ring over operating-point
//! cache keys ([`HashRing`]); a point owned by another shard is
//! fetched from it over a `peer_point` request — always solved
//! locally by the owner, never re-forwarded — and falls back to a
//! local solve when the peer is unreachable. Peer replies are
//! bit-identical to local solves because the cache key excludes
//! run-dir and thread-count knobs (`tests/serve.rs` pins this).
//!
//! Lifetimes / shutdown (the drain order is the design):
//!
//! 1. a `Shutdown` request is answered by its reactor, which then
//!    flips the shared flag;
//! 2. the acceptor notices within a tick, stops accepting, and drops
//!    the listener (the port is released before the drain finishes);
//! 3. each reactor stops reading, finishes delivering every admitted
//!    request's reply, closes its connections and exits — dropping
//!    its work sender;
//! 4. with every reactor gone, the session thread's queue closes: it
//!    finishes queued work and exits, dropping the batcher's job
//!    sender; the batcher finishes its micro-batches and exits;
//! 5. `run`/`Server::join` returns only after every thread is joined,
//!    so a clean exit means a clean drain.

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::arch;
use crate::backend::kernels::KernelKind;
use crate::backend::native::NativeBackend;
use crate::bnn::ErrorModel;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::store::NamedTensor;
use crate::data::synth::Dataset;
use crate::session::{DesignSession, OperatingPointSpec};
use crate::util::evloop::{fd_of, would_block, Interest, Poller};
use crate::util::json::{obj, Json};
use crate::util::pool::ScopedPool;

use super::batcher::{self, BatchPolicy, InferJob};
use super::client::{self, Backoff, Client};
use super::metrics::Metrics;
use super::protocol::{self, PointReq};
use super::reactor::{self, ReactorCfg, Work};
use super::shard::HashRing;

/// How often the acceptor wakes to check the shutdown flag.
const ACCEPT_TICK: Duration = Duration::from_millis(50);

/// Entry caps on the session thread's two lazily-filled caches.
/// Both are keyed by client-controlled knobs (sigma is a continuous
/// f64), so without a cap a client could mint unlimited distinct keys
/// and grow server memory monotonically — the same bounded-memory
/// rule as rbuf/wbuf/queue. Eviction re-costs one solve, so the caps
/// are generous versus any honest working set.
const PEER_CACHE_CAP: usize = 512;
const PREPARED_CACHE_CAP: usize = 64;

#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub addr: SocketAddr,
    /// Most `Infer` requests coalesced into one backend entry.
    pub max_batch: usize,
    /// Longest a ready request waits for company (milliseconds).
    pub max_wait_ms: u64,
    /// Datasets to pre-warm (fold + F_MAC) before serving traffic.
    pub warm: Vec<Dataset>,
    /// Event-loop threads owning the sockets (DESIGN.md §16).
    pub reactors: usize,
    /// Bound on admitted-but-unanswered compute requests across all
    /// connections; the excess sheds with `overloaded` replies.
    pub queue_cap: usize,
    /// Per-connection cap on in-flight compute requests.
    pub inflight_cap: u64,
    /// Close a connection stalled mid-request-line this long
    /// (milliseconds). Fully idle connections are never reaped.
    pub idle_timeout_ms: u64,
    /// Largest accepted request line, bytes.
    pub max_line: usize,
    /// Unflushed reply bytes tolerated before a slow client is shed.
    pub wbuf_cap: usize,
    /// The full ordered shard ring, **including this server**; empty
    /// means standalone. Every member must be started with the same
    /// list (order matters — ring points hash indices).
    pub peers: Vec<SocketAddr>,
    /// This server's index into `peers`.
    pub shard: usize,
    /// Bound on every peer-link socket operation (connect, read,
    /// write), milliseconds. A stalled or wedged owner costs at most
    /// this long before the requester falls back to a local solve —
    /// without it two shards fetching keys owned by each other would
    /// deadlock their session threads permanently.
    pub peer_timeout_ms: u64,
}

impl ServeOptions {
    pub fn new(addr: SocketAddr) -> ServeOptions {
        ServeOptions {
            addr,
            max_batch: 8,
            max_wait_ms: 2,
            warm: vec![],
            reactors: 2,
            queue_cap: 256,
            inflight_cap: reactor::DEFAULT_INFLIGHT_CAP,
            idle_timeout_ms: 30_000,
            max_line: reactor::DEFAULT_MAX_LINE,
            wbuf_cap: reactor::DEFAULT_WBUF_CAP,
            peers: vec![],
            shard: 0,
            peer_timeout_ms: 5_000,
        }
    }
}

/// Static facts fixed at startup, reported under `"server"` in every
/// `Stats` reply so clients can pin that nothing is re-spawned per
/// request.
struct ServerInfo {
    backend: &'static str,
    /// Reactor threads (kept under the historical `workers` key too,
    /// so pre-§16 stability checks keep holding).
    reactors: usize,
    /// Persistent kernel-pool crews: (session solve pool, batcher
    /// inference pool). Stable for the server's life.
    session_pool_workers: usize,
    infer_pool_workers: usize,
    max_batch: usize,
    max_wait_ms: u64,
    queue_cap: usize,
    shards: usize,
    shard: usize,
}

impl ServerInfo {
    fn to_json(&self) -> Json {
        obj(vec![
            ("backend", Json::Str(self.backend.to_string())),
            ("workers", Json::Num(self.reactors as f64)),
            ("reactors", Json::Num(self.reactors as f64)),
            (
                "session_pool_workers",
                Json::Num(self.session_pool_workers as f64),
            ),
            (
                "infer_pool_workers",
                Json::Num(self.infer_pool_workers as f64),
            ),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("max_wait_ms", Json::Num(self.max_wait_ms as f64)),
            ("queue_cap", Json::Num(self.queue_cap as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("shard", Json::Num(self.shard as f64)),
        ])
    }
}

/// A running server handle (`spawn`); `join` blocks until drain.
pub struct Server {
    addr: SocketAddr,
    handle: JoinHandle<Result<()>>,
}

impl Server {
    /// The actually bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn join(self) -> Result<()> {
        self.handle
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))?
    }
}

/// Bind and serve on a background thread (tests, benches, examples).
pub fn spawn(
    cfg: ExperimentConfig,
    opts: ServeOptions,
) -> Result<Server> {
    let listener = TcpListener::bind(opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?;
    spawn_on(listener, cfg, opts)
}

/// [`spawn`] on an already-bound listener — shard rings bind every
/// member first so each server can be handed the full address list.
pub fn spawn_on(
    listener: TcpListener,
    cfg: ExperimentConfig,
    opts: ServeOptions,
) -> Result<Server> {
    let addr = listener.local_addr()?;
    let handle =
        std::thread::spawn(move || run_bound(listener, cfg, opts));
    Ok(Server { addr, handle })
}

/// Bind and serve on the calling thread (the CLI entry); returns after
/// a clean `Shutdown` drain.
pub fn run(cfg: ExperimentConfig, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?;
    crate::log_info!(
        "serve",
        "capmin serve: listening on {}",
        listener.local_addr()?
    );
    run_bound(listener, cfg, opts)
}

/// `capmin serve --shards N`: spawn an in-process consistent-hash
/// ring — shard 0 on the requested address, the rest on ephemeral
/// loopback ports — and serve until shard 0 is shut down, then drain
/// the rest. One process, N independent serving stacks.
pub fn run_sharded(
    cfg: ExperimentConfig,
    opts: ServeOptions,
    shards: usize,
) -> Result<()> {
    let shards = shards.max(1);
    let mut listeners = vec![TcpListener::bind(opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?];
    for _ in 1..shards {
        listeners.push(TcpListener::bind("127.0.0.1:0")?);
    }
    let addrs: Vec<SocketAddr> = listeners
        .iter()
        .map(|l| l.local_addr())
        .collect::<std::io::Result<_>>()?;
    crate::log_info!(
        "serve",
        "capmin serve: listening on {} ({} shard ring: {})",
        addrs[0],
        shards,
        addrs
            .iter()
            .map(|a| a.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let mut rest = Vec::new();
    let mut iter = listeners.into_iter();
    let first = iter.next().unwrap();
    for (i, l) in iter.enumerate() {
        let mut o = opts.clone();
        o.addr = addrs[i + 1];
        o.peers = addrs.clone();
        o.shard = i + 1;
        rest.push(spawn_on(l, cfg.clone(), o)?);
    }
    let mut o = opts;
    o.peers = addrs.clone();
    o.shard = 0;
    let r = run_bound(first, cfg, o);
    // shard 0 drained: drain the others, best-effort, then join
    for addr in addrs.iter().skip(1) {
        if let Ok(mut c) = Client::connect(*addr) {
            let _ = c.shutdown();
        }
    }
    for s in rest {
        let _ = s.join();
    }
    r
}

/// Spawn a ring of in-process shard servers on ephemeral loopback
/// ports, one config per shard (tests give each its own run dir to
/// prove peer fetches really cross the wire). Returns the servers in
/// ring order.
pub fn spawn_ring(
    cfgs: Vec<ExperimentConfig>,
    base: ServeOptions,
) -> Result<Vec<Server>> {
    let mut listeners = Vec::new();
    for _ in 0..cfgs.len() {
        listeners.push(TcpListener::bind("127.0.0.1:0")?);
    }
    let addrs: Vec<SocketAddr> = listeners
        .iter()
        .map(|l| l.local_addr())
        .collect::<std::io::Result<_>>()?;
    cfgs.into_iter()
        .zip(listeners)
        .enumerate()
        .map(|(i, (cfg, l))| {
            let mut o = base.clone();
            o.addr = addrs[i];
            o.peers = addrs.clone();
            o.shard = i;
            spawn_on(l, cfg, o)
        })
        .collect()
}

fn run_bound(
    listener: TcpListener,
    cfg: ExperimentConfig,
    opts: ServeOptions,
) -> Result<()> {
    let n_reactors = opts.reactors.max(1);
    // serve metrics live on the process-global registry (DESIGN.md
    // §17) so one Stats/`--prom` exposition carries the serve series
    // next to the session/MC/kernel counters bumped by the same work
    let metrics = Arc::new(Metrics::on_registry(
        crate::obs::registry::global(),
        n_reactors,
    ));
    let shutdown = Arc::new(AtomicBool::new(false));

    // both kernel crews are spawned here, once, and only referenced
    // afterwards (ScopedPool::spawned_workers stays constant)
    let session_pool = ScopedPool::persistent(cfg.threads);
    let infer_pool = ScopedPool::persistent(cfg.threads);
    let shards = opts.peers.len().max(1);
    let info = ServerInfo {
        backend: "native",
        reactors: n_reactors,
        session_pool_workers: session_pool.spawned_workers(),
        infer_pool_workers: infer_pool.spawned_workers(),
        max_batch: opts.max_batch.max(1),
        max_wait_ms: opts.max_wait_ms,
        queue_cap: opts.queue_cap,
        shards,
        shard: opts.shard,
    }
    .to_json();

    // batcher thread: owns the serving NativeBackend
    let (infer_tx, infer_rx) = mpsc::channel::<InferJob>();
    let batcher_handle = {
        let kind = KernelKind::resolve(&cfg.kernel)
            .unwrap_or_else(|_| KernelKind::detect());
        let backend = NativeBackend::with_pool(infer_pool, kind, true);
        let policy = BatchPolicy {
            max_batch: opts.max_batch.max(1),
            max_wait: Duration::from_millis(opts.max_wait_ms),
        };
        let metrics = metrics.clone();
        std::thread::spawn(move || {
            batcher::run(infer_rx, backend, policy, metrics)
        })
    };

    // session thread: owns the one warm DesignSession and the shard
    // ring's outbound peer links
    let (work_tx, work_rx) = mpsc::channel::<Work>();
    let session_handle = {
        let cfg = cfg.clone();
        let warm = opts.warm.clone();
        let metrics = metrics.clone();
        let peers = opts.peers.clone();
        let shard = opts.shard;
        let peer_timeout =
            Duration::from_millis(opts.peer_timeout_ms.max(1));
        std::thread::spawn(move || {
            session_thread(
                cfg, warm, session_pool, work_rx, infer_tx, metrics,
                peers, shard, peer_timeout,
            )
        })
    };

    // reactor crew: own every socket from here on
    let mut reactor_shareds = Vec::new();
    let mut reactor_handles = Vec::new();
    for index in 0..n_reactors {
        let (shared, handle) = reactor::spawn(ReactorCfg {
            index,
            queue_cap: opts.queue_cap,
            inflight_cap: opts.inflight_cap.max(1),
            max_line: opts.max_line,
            wbuf_cap: opts.wbuf_cap,
            idle_timeout: Duration::from_millis(
                opts.idle_timeout_ms.max(1),
            ),
            retry_after_ms: reactor::DEFAULT_RETRY_AFTER_MS,
            shutdown: shutdown.clone(),
            metrics: metrics.clone(),
            info: info.clone(),
            work_tx: work_tx.clone(),
        })?;
        reactor_shareds.push(shared);
        reactor_handles.push(handle);
    }
    // the reactors hold the only work senders: when the last one
    // exits, the session thread sees its queue close and drains
    drop(work_tx);

    // non-blocking accept loop (this thread): hand connections to the
    // reactors round-robin. Errors here must NOT return early — the
    // worker threads would keep running headless with live
    // connections — so the loop's result is captured and the normal
    // shutdown/drain/join sequence below runs either way.
    let accept_result = (|| -> Result<()> {
        listener.set_nonblocking(true)?;
        let poller = Poller::new()?;
        poller.register(fd_of(&listener), 0, Interest::READ)?;
        let mut events = Vec::new();
        let mut next = 0usize;
        while !shutdown.load(Ordering::SeqCst) {
            poller.wait(&mut events, Some(ACCEPT_TICK))?;
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        reactor_shareds[next % n_reactors]
                            .push_conn(stream);
                        next += 1;
                    }
                    Err(ref e) if would_block(e) => break,
                    Err(ref e)
                        if e.kind()
                            == std::io::ErrorKind::Interrupted =>
                    {
                        continue
                    }
                    Err(_) => {
                        // transient accept failure (EMFILE and
                        // friends): refuse loudly in the metrics and
                        // back off a beat
                        metrics.refuse_conn();
                        std::thread::sleep(Duration::from_millis(10));
                        break;
                    }
                }
            }
        }
        poller.deregister(fd_of(&listener)).ok();
        Ok(())
    })();
    // a no-op on the clean path; on an accept-loop error this is what
    // tells the reactors (and through them the session and batcher)
    // to drain instead of serving forever under a dead acceptor
    shutdown.store(true, Ordering::SeqCst);
    // release the port before the drain finishes so a restart can
    // bind immediately
    drop(listener);
    for h in reactor_handles {
        let _ = h.join();
    }
    let _ = session_handle.join();
    let _ = batcher_handle.join();
    accept_result
}

/// Everything a prepared `Infer` needs, resolved once per
/// (dataset, k, sigma, phi) by the session thread and cached there.
#[derive(Clone)]
struct Prepared {
    model: &'static str,
    pixels: usize,
    n_classes: usize,
    folded: Arc<Vec<NamedTensor>>,
    ems: Arc<Vec<ErrorModel>>,
}

/// A lazily-connected outbound link to one ring peer; reconnects (with
/// a short backoff) after any failure. Every socket operation is
/// bounded by `timeout`: the link runs on the single session thread,
/// so an unbounded read against a wedged owner would block all compute
/// on this shard — and deadlock permanently if two shards ever fetch
/// keys owned by each other (each owner's inbound `peer_point` sits
/// unprocessed behind its own outbound fetch). With the bound, the
/// worst case is one timeout and a local-solve fallback.
struct PeerLink {
    addr: SocketAddr,
    timeout: Duration,
    conn: Option<Client>,
}

impl PeerLink {
    fn connect(&self) -> Result<Client> {
        let c = Backoff {
            attempts: 2,
            base_ms: 10,
            cap_ms: 50,
        }
        .retry(self.addr.port() as u64, || {
            Client::connect_within(self.addr, self.timeout)
        })?;
        c.set_io_timeout(Some(self.timeout))?;
        Ok(c)
    }

    fn fetch(&mut self, req: &PointReq) -> Result<Json> {
        let mut last = None;
        for _ in 0..2 {
            if self.conn.is_none() {
                match self.connect() {
                    Ok(c) => self.conn = Some(c),
                    Err(e) => {
                        let hung = client::timed_out(&e);
                        last = Some(e);
                        if hung {
                            break;
                        }
                        continue;
                    }
                }
            }
            let c = self.conn.as_mut().unwrap();
            match c.peer_point(
                req.dataset.spec().name,
                req.k,
                req.sigma,
                req.phi,
                req.eval,
            ) {
                Ok(j) => return Ok(j),
                Err(e) => {
                    // a broken link is dropped, not nursed; the retry
                    // reconnects fresh — unless the peer is wedged
                    // (timeout), where a retry would only double the
                    // stall before the caller's local-solve fallback
                    self.conn = None;
                    let hung = client::timed_out(&e);
                    last = Some(e);
                    if hung {
                        break;
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| {
            anyhow::anyhow!("peer {} unreachable", self.addr)
        }))
    }
}

/// A HashMap bounded by entry count: inserting at capacity evicts the
/// oldest-inserted entry (FIFO). Both session-side caches are keyed by
/// client-controlled knobs, so an unbounded map would let any client
/// grow server memory monotonically — this holds the §16
/// bounded-memory invariant at the cost of a re-solve on re-miss.
struct BoundedMap<K, V> {
    cap: usize,
    order: VecDeque<K>,
    map: HashMap<K, V>,
}

impl<K: Eq + Hash + Clone, V> BoundedMap<K, V> {
    fn new(cap: usize) -> BoundedMap<K, V> {
        BoundedMap {
            cap: cap.max(1),
            order: VecDeque::new(),
            map: HashMap::new(),
        }
    }

    fn get(&self, key: &K) -> Option<&V> {
        self.map.get(key)
    }

    fn insert(&mut self, key: K, value: V) {
        if !self.map.contains_key(&key) {
            if self.order.len() >= self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
            self.order.push_back(key.clone());
        }
        self.map.insert(key, value);
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.map.len()
    }
}

struct SessionSrv {
    session: DesignSession,
    metrics: Arc<Metrics>,
    infer_tx: Sender<InferJob>,
    ring: HashRing,
    shard: usize,
    peers: Vec<PeerLink>,
    /// key -> verified peer reply (id rewritten per request).
    peer_cache: BoundedMap<String, Json>,
    prepared: BoundedMap<(Dataset, usize, u64, usize), Prepared>,
}

/// The session thread: builds the `DesignSession` (on its own thread —
/// the session facade is deliberately single-threaded), pre-warms the
/// requested datasets, then serves reactor work until every reactor is
/// gone. Dropping `infer_tx` on exit is what lets the batcher drain.
#[allow(clippy::too_many_arguments)]
fn session_thread(
    cfg: ExperimentConfig,
    warm: Vec<Dataset>,
    pool: ScopedPool,
    rx: Receiver<Work>,
    infer_tx: Sender<InferJob>,
    metrics: Arc<Metrics>,
    peers: Vec<SocketAddr>,
    shard: usize,
    peer_timeout: Duration,
) {
    let session = match DesignSession::builder()
        .config(cfg)
        .pool(pool)
        .build()
    {
        Ok(s) => s,
        Err(e) => {
            // a session that cannot build answers every request with
            // the build error instead of hanging clients
            let msg = format!("session unavailable: {e}");
            for w in rx {
                let (id, sink) = match w {
                    Work::Point { req, sink, .. } => (req.id, sink),
                    Work::Infer { req, sink, .. } => (req.id, sink),
                };
                metrics.inc_error();
                sink.send(&protocol::error_response(
                    Some(id),
                    &msg,
                ));
            }
            return;
        }
    };
    for ds in warm {
        // failures surface per request; warmup is best-effort priming
        if let Err(e) = session.fmac(ds) {
            crate::log_warn!(
                "serve.warmup",
                "warmup {} failed: {e}",
                ds.spec().name
            );
        }
    }
    let mut srv = SessionSrv {
        session,
        metrics,
        infer_tx,
        ring: HashRing::new(peers.len()),
        shard,
        peers: peers
            .into_iter()
            .map(|addr| PeerLink {
                addr,
                timeout: peer_timeout,
                conn: None,
            })
            .collect(),
        peer_cache: BoundedMap::new(PEER_CACHE_CAP),
        prepared: BoundedMap::new(PREPARED_CACHE_CAP),
    };
    for w in rx {
        srv.handle(w);
    }
}

impl SessionSrv {
    fn handle(&mut self, work: Work) {
        match work {
            Work::Point {
                req,
                peer,
                sink,
                t0,
                trace,
            } => {
                // the request's own trace: queue wait is its root span
                let _ctx = crate::obs::TraceCtx {
                    trace_id: trace,
                    span: 0,
                }
                .attach();
                let queue_us = t0.elapsed().as_micros() as u64;
                crate::span_since!("serve.queue", t0);
                self.metrics.phase_queue_us.record(queue_us);
                self.session
                    .note_queue_ms(queue_us as f64 / 1_000.0);
                let t_solve = Instant::now();
                let reply = {
                    let _span = crate::span!("serve.point");
                    self.solve_point(&req, peer)
                };
                self.metrics
                    .phase_solve_us
                    .record(t_solve.elapsed().as_micros() as u64);
                let reply = protocol::with_trace(reply, trace);
                self.metrics
                    .point_latency_us
                    .record(t0.elapsed().as_micros() as u64);
                let t_reply = Instant::now();
                sink.send(&reply);
                crate::span_since!("serve.reply", t_reply);
            }
            Work::Infer {
                req,
                sink,
                t0,
                trace,
            } => {
                let prep = self.prepare(
                    req.dataset,
                    req.k,
                    req.sigma,
                    req.phi,
                );
                let prep = match prep {
                    Ok(p) => p,
                    Err(e) => {
                        self.metrics.inc_error();
                        sink.send(&protocol::error_response(
                            Some(req.id),
                            &e,
                        ));
                        return;
                    }
                };
                debug_assert_eq!(
                    req.x.len(),
                    req.n * prep.pixels
                );
                let job = InferJob {
                    model: prep.model,
                    n_classes: prep.n_classes,
                    folded: prep.folded,
                    ems: prep.ems,
                    seed: req.seed,
                    x: req.x,
                    batch: req.n,
                    id: req.id,
                    reply: sink,
                    t0,
                    trace,
                };
                if let Err(lost) = self.infer_tx.send(job) {
                    self.metrics.inc_error();
                    lost.0.reply.send(&protocol::error_response(
                        Some(req.id),
                        "server is draining",
                    ));
                }
            }
        }
    }

    /// Solve a point — locally, or via the ring peer that owns its
    /// cache key. `peer_req` marks an inbound `peer_point`, which is
    /// ALWAYS solved locally (the no-forwarding rule that makes
    /// routing loops structurally impossible).
    fn solve_point(&mut self, req: &PointReq, peer_req: bool) -> Json {
        let mut spec = OperatingPointSpec::new(
            req.dataset,
            req.k,
            req.sigma,
            req.phi,
        );
        if req.eval {
            spec = spec.with_eval(1, 1);
        }
        let key = spec.cache_key(self.session.config());
        if !peer_req && self.ring.shards() > 1 {
            let owner = self.ring.owner(&key);
            if owner != self.shard {
                if let Some(cached) = self.peer_cache.get(&key) {
                    return with_id(cached.clone(), req.id);
                }
                match self.peers[owner].fetch(req) {
                    Ok(reply)
                        if reply
                            .get("key")
                            .map(|k| k.as_str() == key)
                            .unwrap_or(false) =>
                    {
                        self.metrics.peer_fetch(true);
                        self.peer_cache
                            .insert(key, reply.clone());
                        return with_id(reply, req.id);
                    }
                    Ok(reply) => {
                        // answered, but for a different key: the peer
                        // runs different knobs — fall back local
                        self.metrics.peer_fetch(false);
                        crate::log_warn!(
                            "serve.peer",
                            "shard {} returned key {:?}, wanted \
                             {key}; solving locally",
                            owner,
                            reply.get("key").map(|k| k.to_string()),
                        );
                    }
                    Err(e) => {
                        self.metrics.peer_fetch(false);
                        crate::log_warn!(
                            "serve.peer",
                            "peer fetch from shard {owner} failed \
                             ({e}); solving locally"
                        );
                    }
                }
            }
        }
        match self.session.query(&spec) {
            Ok(point) => {
                protocol::point_response(req.id, &key, &point)
            }
            Err(e) => {
                self.metrics.inc_error();
                protocol::error_response(
                    Some(req.id),
                    &e.to_string(),
                )
            }
        }
    }

    fn prepare(
        &mut self,
        ds: Dataset,
        k: usize,
        sigma: f64,
        phi: usize,
    ) -> std::result::Result<Prepared, String> {
        let cache_key = (ds, k, sigma.to_bits(), phi);
        if let Some(p) = self.prepared.get(&cache_key) {
            return Ok(p.clone());
        }
        let r = (|| -> Result<Prepared> {
            let spec = OperatingPointSpec::new(ds, k, sigma, phi);
            let point = self.session.query(&spec)?;
            let folded = self.session.folded(ds)?;
            let dspec = ds.spec();
            let meta = arch::model_meta(dspec.model)?;
            Ok(Prepared {
                model: dspec.model,
                pixels: dspec.pixels(),
                n_classes: meta.n_classes,
                folded,
                ems: Arc::new(point.ems.clone()),
            })
        })();
        match r {
            Ok(p) => {
                self.prepared.insert(cache_key, p.clone());
                Ok(p)
            }
            Err(e) => Err(e.to_string()),
        }
    }
}

/// A peer reply re-addressed to the request that triggered it.
fn with_id(mut reply: Json, id: f64) -> Json {
    if let Json::Obj(m) = &mut reply {
        m.insert("id".into(), Json::Num(id));
    }
    reply
}

#[cfg(test)]
mod tests {
    use super::BoundedMap;

    #[test]
    fn bounded_map_evicts_oldest_and_never_exceeds_cap() {
        let mut m: BoundedMap<u64, u64> = BoundedMap::new(3);
        for k in 0..10 {
            m.insert(k, k * k);
            assert!(m.len() <= 3, "cap 3 exceeded at {k}");
        }
        // the three youngest survive, the rest were evicted FIFO
        for k in 7..10 {
            assert_eq!(m.get(&k), Some(&(k * k)));
        }
        for k in 0..7 {
            assert_eq!(m.get(&k), None);
        }
        // overwriting a live key neither grows nor evicts
        m.insert(8, 1);
        assert_eq!(m.len(), 3);
        assert_eq!(m.get(&8), Some(&1));
        assert_eq!(m.get(&7), Some(&49));
    }
}
