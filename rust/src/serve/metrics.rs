//! Serve-side observability: request counters, micro-batch sizes and
//! latency histograms, all lock-free atomics so the request path never
//! serializes on a metrics mutex (DESIGN.md §12). Served to clients
//! through the `Stats` request.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::{obj, Json};

/// Request kinds tracked by the counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Point,
    Infer,
    Stats,
    Shutdown,
}

const KINDS: [(&str, Kind); 4] = [
    ("point", Kind::Point),
    ("infer", Kind::Infer),
    ("stats", Kind::Stats),
    ("shutdown", Kind::Shutdown),
];

/// Power-of-two bucketed histogram: bucket `i` counts values in
/// `(2^(i-1), 2^i]` (bucket 0 counts zeros and ones). Quantiles
/// report the chosen bucket's upper bound `2^i` — coarse by design,
/// cheap to record, and honest about being an envelope (a p99 of
/// `4096` means "under 4.1 ms", not "exactly 4.096 ms").
pub struct Hist {
    buckets: Vec<AtomicU64>,
}

impl Hist {
    pub fn new(n_buckets: usize) -> Hist {
        Hist {
            buckets: (0..n_buckets).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Ceil-log2 bucket index: the smallest `i` with `v <= 2^i`
    /// (clamped into the last bucket).
    fn bucket_of(&self, v: u64) -> usize {
        let b = (64 - v.saturating_sub(1).leading_zeros()) as usize;
        b.min(self.buckets.len() - 1)
    }

    pub fn record(&self, v: u64) {
        self.buckets[self.bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Upper bound of the bucket holding the q-quantile (0 when
    /// empty).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << (self.buckets.len() - 1)
    }

    /// Raw bucket counts (trailing zero buckets trimmed).
    pub fn to_json(&self) -> Json {
        let mut counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        while counts.len() > 1 && counts.last() == Some(&0) {
            counts.pop();
        }
        Json::Arr(counts.into_iter().map(|c| Json::Num(c as f64)).collect())
    }
}

/// All serve counters; one instance shared by every thread via `Arc`.
pub struct Metrics {
    start: Instant,
    requests: [AtomicU64; 4],
    /// Requests answered with `ok: false` (parse errors included).
    errors: AtomicU64,
    /// Samples that went through the batcher.
    infer_samples: AtomicU64,
    /// `forward_many` entries executed.
    micro_batches: AtomicU64,
    /// Infer requests that shared their micro-batch with at least one
    /// other request — the coalescing the batcher exists for.
    batched_requests: AtomicU64,
    /// Largest micro-batch observed, in requests.
    max_batch: AtomicU64,
    /// Micro-batch size in requests.
    pub batch_hist: Hist,
    /// Point latency, microseconds (queue + solve + reply).
    pub point_latency_us: Hist,
    /// Infer latency, microseconds (queue + batch wait + forward).
    pub infer_latency_us: Hist,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            requests: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
            errors: AtomicU64::new(0),
            infer_samples: AtomicU64::new(0),
            micro_batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            batch_hist: Hist::new(12),
            point_latency_us: Hist::new(28),
            infer_latency_us: Hist::new(28),
        }
    }

    pub fn inc(&self, kind: Kind) {
        self.requests[kind as usize].fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self, kind: Kind) -> u64 {
        self.requests[kind as usize].load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Record one executed micro-batch of `reqs` requests covering
    /// `samples` samples.
    pub fn record_batch(&self, reqs: usize, samples: usize) {
        self.micro_batches.fetch_add(1, Ordering::Relaxed);
        self.infer_samples
            .fetch_add(samples as u64, Ordering::Relaxed);
        self.batch_hist.record(reqs as u64);
        if reqs > 1 {
            self.batched_requests
                .fetch_add(reqs as u64, Ordering::Relaxed);
        }
        self.max_batch.fetch_max(reqs as u64, Ordering::Relaxed);
    }

    pub fn max_batch(&self) -> u64 {
        self.max_batch.load(Ordering::Relaxed)
    }

    pub fn batched_requests(&self) -> u64 {
        self.batched_requests.load(Ordering::Relaxed)
    }

    /// The `Stats` payload (merged with the server's static info by
    /// the worker).
    pub fn to_json(&self) -> Json {
        let lat = |h: &Hist| {
            obj(vec![
                ("count", Json::Num(h.count() as f64)),
                ("p50_us_le", Json::Num(h.quantile(0.5) as f64)),
                ("p99_us_le", Json::Num(h.quantile(0.99) as f64)),
            ])
        };
        obj(vec![
            (
                "uptime_s",
                Json::Num(self.start.elapsed().as_secs_f64()),
            ),
            (
                "requests",
                obj(KINDS
                    .iter()
                    .map(|&(name, kind)| {
                        (name, Json::Num(self.count(kind) as f64))
                    })
                    .collect()),
            ),
            ("errors", Json::Num(self.errors() as f64)),
            (
                "infer",
                obj(vec![
                    (
                        "samples",
                        Json::Num(
                            self.infer_samples.load(Ordering::Relaxed)
                                as f64,
                        ),
                    ),
                    (
                        "micro_batches",
                        Json::Num(
                            self.micro_batches.load(Ordering::Relaxed)
                                as f64,
                        ),
                    ),
                    (
                        "batched_requests",
                        Json::Num(self.batched_requests() as f64),
                    ),
                    (
                        "max_batch_requests",
                        Json::Num(self.max_batch() as f64),
                    ),
                    ("batch_hist", self.batch_hist.to_json()),
                ]),
            ),
            (
                "latency",
                obj(vec![
                    ("point", lat(&self.point_latency_us)),
                    ("infer", lat(&self.infer_latency_us)),
                ]),
            ),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_and_quantiles_envelope() {
        let h = Hist::new(12);
        for v in [1u64, 1, 1, 2, 3, 900] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        // p50 of {1,1,1,2,3,900}: 3rd value = 1 -> bucket upper 1
        assert_eq!(h.quantile(0.5), 1);
        // the outlier lands in [512,1024) -> upper bound 1024
        assert_eq!(h.quantile(1.0), 1024);
        assert_eq!(h.quantile(0.99), 1024);
        // zero treated as the smallest bucket, values beyond the last
        // bucket clamp into it
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 8);
    }

    #[test]
    fn counters_and_batches_add_up() {
        let m = Metrics::new();
        m.inc(Kind::Point);
        m.inc(Kind::Infer);
        m.inc(Kind::Infer);
        m.inc_error();
        m.record_batch(1, 4);
        m.record_batch(2, 2);
        assert_eq!(m.count(Kind::Infer), 2);
        assert_eq!(m.count(Kind::Point), 1);
        assert_eq!(m.count(Kind::Shutdown), 0);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.max_batch(), 2);
        assert_eq!(m.batched_requests(), 2);
        let j = m.to_json();
        assert_eq!(
            j.req("requests").req("infer").as_f64(),
            2.0
        );
        assert_eq!(j.req("infer").req("samples").as_f64(), 6.0);
        assert_eq!(j.req("infer").req("micro_batches").as_f64(), 2.0);
    }
}
