//! Serve-side observability: request counters, micro-batch sizes and
//! latency histograms, plus the event-loop tier's gauges — compute
//! queue depth, admission rejections, per-reactor connection counts
//! and peer-fetch hit/miss counters (DESIGN.md §12/§16). Since the
//! telemetry PR (§17) every series here is a named handle into an
//! [`obs::registry::Registry`](crate::obs::registry::Registry) —
//! still lock-free atomics on the request path, but now scrapeable
//! through `stats --prom` and the additive `registry` section of the
//! `Stats` reply alongside the cross-layer session/MC/kernel series.
//! `Metrics::new()` builds on a fresh private registry (so unit tests
//! and side-by-side servers in one process never share counts); the
//! real server wires the process-global registry via
//! [`Metrics::on_registry`] so one snapshot covers every layer.

use std::sync::Arc;
use std::time::Instant;

use crate::obs::registry::{Counter, Gauge, Registry};
use crate::util::json::{obj, Json};

pub use crate::obs::registry::Hist;

/// Request kinds tracked by the counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Point,
    Infer,
    Stats,
    Shutdown,
    PeerPoint,
}

const KINDS: [(&str, Kind); 5] = [
    ("point", Kind::Point),
    ("infer", Kind::Infer),
    ("stats", Kind::Stats),
    ("shutdown", Kind::Shutdown),
    ("peer_point", Kind::PeerPoint),
];

/// All serve counters; one instance shared by every thread via `Arc`.
/// Handles resolve once at construction — the hot path never touches
/// the registry mutex.
pub struct Metrics {
    reg: Arc<Registry>,
    start: Instant,
    requests: [Arc<Counter>; 5],
    /// Requests answered with `ok: false` (parse errors included;
    /// admission sheds are counted separately below).
    errors: Arc<Counter>,
    /// Samples that went through the batcher.
    infer_samples: Arc<Counter>,
    /// `forward_many` entries executed.
    micro_batches: Arc<Counter>,
    /// Infer requests that shared their micro-batch with at least one
    /// other request — the coalescing the batcher exists for.
    batched_requests: Arc<Counter>,
    /// Largest micro-batch observed, in requests.
    max_batch: Arc<Gauge>,
    /// Micro-batch size in requests.
    pub batch_hist: Arc<Hist>,
    /// Point latency, microseconds (queue + solve + reply).
    pub point_latency_us: Arc<Hist>,
    /// Infer latency, microseconds (queue + batch wait + forward).
    pub infer_latency_us: Arc<Hist>,

    // ---- server-side phase attribution (DESIGN.md §17) ----
    /// Admission → worker pickup (reactor queue + channel).
    pub phase_queue_us: Arc<Hist>,
    /// Batcher receipt → micro-batch execution start.
    pub phase_batch_wait_us: Arc<Hist>,
    /// `forward_many` wall time per micro-batch.
    pub phase_forward_us: Arc<Hist>,
    /// Session solve wall time per point request.
    pub phase_solve_us: Arc<Hist>,

    // ---- event-loop tier (DESIGN.md §16), all additive ----
    /// Compute requests admitted and not yet completed — THE
    /// backpressure gauge ([`Metrics::try_admit`] bounds it).
    pending: Arc<Gauge>,
    /// Sheds: global pending queue at capacity.
    rejected_queue: Arc<Counter>,
    /// Sheds: one connection exceeded its in-flight cap.
    rejected_conn: Arc<Counter>,
    /// Whole connections refused at accept (fd budget).
    refused_conns: Arc<Counter>,
    /// Slow clients dropped for an over-cap write buffer.
    shed_slow_clients: Arc<Counter>,
    /// Connections closed for stalling mid-request-line (slowloris).
    idle_timeouts: Arc<Counter>,
    conns_accepted: Arc<Counter>,
    conns_closed: Arc<Counter>,
    /// Open connections per reactor (gauges; sized at startup).
    reactor_conns: Vec<Arc<Gauge>>,
    /// Peer point fetches attempted / answered by the owner /
    /// fallen back to a local solve (DESIGN.md §16).
    peer_fetches: Arc<Counter>,
    peer_fetch_hits: Arc<Counter>,
    peer_fetch_misses: Arc<Counter>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::with_reactors(0)
    }

    /// A metrics block with `reactors` per-reactor connection gauges,
    /// on a fresh private registry (test/process isolation).
    pub fn with_reactors(reactors: usize) -> Metrics {
        Metrics::on_registry(Arc::new(Registry::new()), reactors)
    }

    /// A metrics block whose series live in `reg` — the server passes
    /// `obs::registry::global()` here so serve counters and the
    /// cross-layer session/MC/kernel series share one snapshot.
    pub fn on_registry(reg: Arc<Registry>, reactors: usize) -> Metrics {
        let c = |name: &str| reg.counter(name);
        let g = |name: &str| reg.gauge(name);
        let h = |name: &str, n: usize| reg.hist(name, n);
        Metrics {
            start: Instant::now(),
            requests: [
                c("serve.requests.point"),
                c("serve.requests.infer"),
                c("serve.requests.stats"),
                c("serve.requests.shutdown"),
                c("serve.requests.peer_point"),
            ],
            errors: c("serve.errors"),
            infer_samples: c("serve.infer.samples"),
            micro_batches: c("serve.infer.micro_batches"),
            batched_requests: c("serve.infer.batched_requests"),
            max_batch: g("serve.infer.max_batch_requests"),
            batch_hist: h("serve.infer.batch_size", 12),
            point_latency_us: h("serve.latency.point_us", 28),
            infer_latency_us: h("serve.latency.infer_us", 28),
            phase_queue_us: h("serve.phase.queue_us", 28),
            phase_batch_wait_us: h("serve.phase.batch_wait_us", 28),
            phase_forward_us: h("serve.phase.forward_us", 28),
            phase_solve_us: h("serve.phase.solve_us", 28),
            pending: g("serve.pending"),
            rejected_queue: c("serve.admission.rejected_queue"),
            rejected_conn: c("serve.admission.rejected_conn"),
            refused_conns: c("serve.admission.refused_conns"),
            shed_slow_clients: c("serve.shed_slow_clients"),
            idle_timeouts: c("serve.idle_timeouts"),
            conns_accepted: c("serve.conns.accepted"),
            conns_closed: c("serve.conns.closed"),
            reactor_conns: (0..reactors)
                .map(|i| g(&format!("serve.reactor.{i}.conns")))
                .collect(),
            peer_fetches: c("serve.peer.fetches"),
            peer_fetch_hits: c("serve.peer.hits"),
            peer_fetch_misses: c("serve.peer.misses"),
            reg,
        }
    }

    /// The registry backing this block (for `Stats`/prom exposition).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.reg
    }

    pub fn inc(&self, kind: Kind) {
        self.requests[kind as usize].inc();
    }

    pub fn inc_error(&self) {
        self.errors.inc();
    }

    pub fn count(&self, kind: Kind) -> u64 {
        self.requests[kind as usize].get()
    }

    pub fn errors(&self) -> u64 {
        self.errors.get()
    }

    /// Admit one compute request against the bounded pending queue:
    /// increments the gauge and returns `true`, or leaves it untouched
    /// and returns `false` when `cap` is reached — the caller then
    /// sheds with a structured `overloaded` reply. Lock-free CAS so
    /// the bound is exact, never approximate.
    pub fn try_admit(&self, cap: usize) -> bool {
        self.pending.try_raise(cap as i64)
    }

    /// One admitted request completed (reply handed to its reactor).
    pub fn pending_dec(&self) {
        self.pending.dec();
    }

    pub fn queue_depth(&self) -> u64 {
        self.pending.get().max(0) as u64
    }

    pub fn shed_queue(&self) {
        self.rejected_queue.inc();
    }

    pub fn shed_conn_cap(&self) {
        self.rejected_conn.inc();
    }

    pub fn refuse_conn(&self) {
        self.refused_conns.inc();
    }

    pub fn shed_slow_client(&self) {
        self.shed_slow_clients.inc();
    }

    pub fn idle_timeout(&self) {
        self.idle_timeouts.inc();
    }

    pub fn rejected_total(&self) -> u64 {
        self.rejected_queue.get() + self.rejected_conn.get()
    }

    pub fn conn_opened(&self, reactor: usize) {
        self.conns_accepted.inc();
        if let Some(g) = self.reactor_conns.get(reactor) {
            g.inc();
        }
    }

    pub fn conn_closed(&self, reactor: usize) {
        self.conns_closed.inc();
        if let Some(g) = self.reactor_conns.get(reactor) {
            g.dec();
        }
    }

    pub fn open_conns(&self) -> u64 {
        self.reactor_conns
            .iter()
            .map(|g| g.get().max(0) as u64)
            .sum()
    }

    /// Record the outcome of one peer point fetch: `hit` when the
    /// owning shard answered, miss when the requester fell back to a
    /// local solve.
    pub fn peer_fetch(&self, hit: bool) {
        self.peer_fetches.inc();
        if hit {
            self.peer_fetch_hits.inc();
        } else {
            self.peer_fetch_misses.inc();
        }
    }

    pub fn peer_fetch_hits(&self) -> u64 {
        self.peer_fetch_hits.get()
    }

    /// Record one executed micro-batch of `reqs` requests covering
    /// `samples` samples.
    pub fn record_batch(&self, reqs: usize, samples: usize) {
        self.micro_batches.inc();
        self.infer_samples.add(samples as u64);
        self.batch_hist.record(reqs as u64);
        if reqs > 1 {
            self.batched_requests.add(reqs as u64);
        }
        self.max_batch.set_max(reqs as i64);
    }

    pub fn max_batch(&self) -> u64 {
        self.max_batch.get().max(0) as u64
    }

    pub fn batched_requests(&self) -> u64 {
        self.batched_requests.get()
    }

    /// The `Stats` payload (merged with the server's static info by
    /// the reactor). Every pre-§17 field keeps its exact shape; the
    /// `registry` section is additive and mirrors the full backing
    /// registry, cross-layer series included.
    pub fn to_json(&self) -> Json {
        let lat = |h: &Hist| {
            obj(vec![
                ("count", Json::Num(h.count() as f64)),
                ("p50_us_le", Json::Num(h.quantile(0.5) as f64)),
                ("p99_us_le", Json::Num(h.quantile(0.99) as f64)),
            ])
        };
        let n = |c: &Counter| Json::Num(c.get() as f64);
        obj(vec![
            (
                "uptime_s",
                Json::Num(self.start.elapsed().as_secs_f64()),
            ),
            (
                "requests",
                obj(KINDS
                    .iter()
                    .map(|&(name, kind)| {
                        (name, Json::Num(self.count(kind) as f64))
                    })
                    .collect()),
            ),
            ("errors", Json::Num(self.errors() as f64)),
            (
                "infer",
                obj(vec![
                    ("samples", n(&self.infer_samples)),
                    ("micro_batches", n(&self.micro_batches)),
                    (
                        "batched_requests",
                        Json::Num(self.batched_requests() as f64),
                    ),
                    (
                        "max_batch_requests",
                        Json::Num(self.max_batch() as f64),
                    ),
                    ("batch_hist", self.batch_hist.to_json()),
                ]),
            ),
            (
                "latency",
                obj(vec![
                    ("point", lat(&self.point_latency_us)),
                    ("infer", lat(&self.infer_latency_us)),
                ]),
            ),
            // event-loop tier (additive; DESIGN.md §16)
            (
                "serving",
                obj(vec![
                    (
                        "queue_depth",
                        Json::Num(self.queue_depth() as f64),
                    ),
                    (
                        "admission",
                        obj(vec![
                            ("rejected_queue", n(&self.rejected_queue)),
                            ("rejected_conn", n(&self.rejected_conn)),
                            ("refused_conns", n(&self.refused_conns)),
                        ]),
                    ),
                    (
                        "conns",
                        obj(vec![
                            (
                                "open",
                                Json::Num(self.open_conns() as f64),
                            ),
                            ("accepted", n(&self.conns_accepted)),
                            ("closed", n(&self.conns_closed)),
                            (
                                "per_reactor",
                                Json::Arr(
                                    self.reactor_conns
                                        .iter()
                                        .map(|g| {
                                            Json::Num(
                                                g.get().max(0) as f64,
                                            )
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    ),
                    (
                        "shed_slow_clients",
                        n(&self.shed_slow_clients),
                    ),
                    ("idle_timeouts", n(&self.idle_timeouts)),
                    (
                        "peer",
                        obj(vec![
                            ("fetches", n(&self.peer_fetches)),
                            ("hits", n(&self.peer_fetch_hits)),
                            ("misses", n(&self.peer_fetch_misses)),
                        ]),
                    ),
                ]),
            ),
            // cross-layer registry snapshot (additive; DESIGN.md §17)
            ("registry", self.reg.snapshot_json()),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_batches_add_up() {
        let m = Metrics::new();
        m.inc(Kind::Point);
        m.inc(Kind::Infer);
        m.inc(Kind::Infer);
        m.inc_error();
        m.record_batch(1, 4);
        m.record_batch(2, 2);
        assert_eq!(m.count(Kind::Infer), 2);
        assert_eq!(m.count(Kind::Point), 1);
        assert_eq!(m.count(Kind::Shutdown), 0);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.max_batch(), 2);
        assert_eq!(m.batched_requests(), 2);
        let j = m.to_json();
        assert_eq!(
            j.req("requests").req("infer").as_f64(),
            2.0
        );
        assert_eq!(j.req("infer").req("samples").as_f64(), 6.0);
        assert_eq!(j.req("infer").req("micro_batches").as_f64(), 2.0);
    }

    #[test]
    fn admission_bound_is_exact() {
        let m = Metrics::new();
        assert!(m.try_admit(2));
        assert!(m.try_admit(2));
        assert!(!m.try_admit(2), "cap 2 admitted a third request");
        assert_eq!(m.queue_depth(), 2);
        m.pending_dec();
        assert!(m.try_admit(2));
        m.shed_queue();
        m.shed_conn_cap();
        assert_eq!(m.rejected_total(), 2);
        let j = m.to_json();
        let serving = j.req("serving");
        assert_eq!(serving.req("queue_depth").as_f64(), 2.0);
        assert_eq!(
            serving.req("admission").req("rejected_queue").as_f64(),
            1.0
        );
    }

    #[test]
    fn reactor_conn_gauges_and_peer_counters() {
        let m = Metrics::with_reactors(2);
        m.conn_opened(0);
        m.conn_opened(1);
        m.conn_opened(1);
        m.conn_closed(1);
        assert_eq!(m.open_conns(), 2);
        m.peer_fetch(true);
        m.peer_fetch(false);
        assert_eq!(m.peer_fetch_hits(), 1);
        let j = m.to_json();
        let serving = j.req("serving");
        let per = serving.req("conns").req("per_reactor").as_arr();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].as_f64(), 1.0);
        assert_eq!(per[1].as_f64(), 1.0);
        assert_eq!(serving.req("conns").req("accepted").as_f64(), 3.0);
        assert_eq!(serving.req("peer").req("fetches").as_f64(), 2.0);
        assert_eq!(serving.req("peer").req("misses").as_f64(), 1.0);
    }

    #[test]
    fn fresh_instances_do_not_share_series() {
        let a = Metrics::new();
        let b = Metrics::new();
        a.inc(Kind::Point);
        assert_eq!(b.count(Kind::Point), 0);
    }

    #[test]
    fn registry_section_mirrors_serve_series() {
        let m = Metrics::new();
        m.inc(Kind::Infer);
        m.phase_queue_us.record(40);
        let j = m.to_json();
        let reg = j.req("registry");
        assert_eq!(reg.req("serve.requests.infer").as_f64(), 1.0);
        assert_eq!(
            reg.req("serve.phase.queue_us").req("count").as_f64(),
            1.0
        );
        let prom = m.registry().prom_text();
        assert!(prom.contains("capmin_serve_requests_infer 1"));
        assert!(prom.contains("capmin_serve_phase_queue_us_count 1"));
    }
}
