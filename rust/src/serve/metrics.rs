//! Serve-side observability: request counters, micro-batch sizes and
//! latency histograms, plus the event-loop tier's gauges — compute
//! queue depth, admission rejections, per-reactor connection counts
//! and peer-fetch hit/miss counters (DESIGN.md §12/§16). All lock-free
//! atomics so the request path never serializes on a metrics mutex.
//! Served to clients through the `Stats` request; every field added by
//! the reactor rewrite is additive, so pre-§16 clients keep parsing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::{obj, Json};

/// Request kinds tracked by the counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Point,
    Infer,
    Stats,
    Shutdown,
    PeerPoint,
}

const KINDS: [(&str, Kind); 5] = [
    ("point", Kind::Point),
    ("infer", Kind::Infer),
    ("stats", Kind::Stats),
    ("shutdown", Kind::Shutdown),
    ("peer_point", Kind::PeerPoint),
];

/// Power-of-two bucketed histogram: bucket `i` counts values in
/// `(2^(i-1), 2^i]` (bucket 0 counts zeros and ones). Quantiles
/// report the chosen bucket's upper bound `2^i` — coarse by design,
/// cheap to record, and honest about being an envelope (a p99 of
/// `4096` means "under 4.1 ms", not "exactly 4.096 ms").
pub struct Hist {
    buckets: Vec<AtomicU64>,
}

impl Hist {
    pub fn new(n_buckets: usize) -> Hist {
        Hist {
            buckets: (0..n_buckets).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Ceil-log2 bucket index: the smallest `i` with `v <= 2^i`
    /// (clamped into the last bucket).
    fn bucket_of(&self, v: u64) -> usize {
        let b = (64 - v.saturating_sub(1).leading_zeros()) as usize;
        b.min(self.buckets.len() - 1)
    }

    pub fn record(&self, v: u64) {
        self.buckets[self.bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Upper bound of the bucket holding the q-quantile (0 when
    /// empty).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << (self.buckets.len() - 1)
    }

    /// Raw bucket counts (trailing zero buckets trimmed).
    pub fn to_json(&self) -> Json {
        let mut counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        while counts.len() > 1 && counts.last() == Some(&0) {
            counts.pop();
        }
        Json::Arr(counts.into_iter().map(|c| Json::Num(c as f64)).collect())
    }
}

/// All serve counters; one instance shared by every thread via `Arc`.
pub struct Metrics {
    start: Instant,
    requests: [AtomicU64; 5],
    /// Requests answered with `ok: false` (parse errors included;
    /// admission sheds are counted separately below).
    errors: AtomicU64,
    /// Samples that went through the batcher.
    infer_samples: AtomicU64,
    /// `forward_many` entries executed.
    micro_batches: AtomicU64,
    /// Infer requests that shared their micro-batch with at least one
    /// other request — the coalescing the batcher exists for.
    batched_requests: AtomicU64,
    /// Largest micro-batch observed, in requests.
    max_batch: AtomicU64,
    /// Micro-batch size in requests.
    pub batch_hist: Hist,
    /// Point latency, microseconds (queue + solve + reply).
    pub point_latency_us: Hist,
    /// Infer latency, microseconds (queue + batch wait + forward).
    pub infer_latency_us: Hist,

    // ---- event-loop tier (DESIGN.md §16), all additive ----
    /// Compute requests admitted and not yet completed — THE
    /// backpressure gauge ([`Metrics::try_admit`] bounds it).
    pending: AtomicU64,
    /// Sheds: global pending queue at capacity.
    rejected_queue: AtomicU64,
    /// Sheds: one connection exceeded its in-flight cap.
    rejected_conn: AtomicU64,
    /// Whole connections refused at accept (fd budget).
    refused_conns: AtomicU64,
    /// Slow clients dropped for an over-cap write buffer.
    shed_slow_clients: AtomicU64,
    /// Connections closed for stalling mid-request-line (slowloris).
    idle_timeouts: AtomicU64,
    conns_accepted: AtomicU64,
    conns_closed: AtomicU64,
    /// Open connections per reactor (gauges; sized at startup).
    reactor_conns: Vec<AtomicU64>,
    /// Peer point fetches attempted / answered by the owner /
    /// fallen back to a local solve (DESIGN.md §16).
    peer_fetches: AtomicU64,
    peer_fetch_hits: AtomicU64,
    peer_fetch_misses: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::with_reactors(0)
    }

    /// A metrics block with `reactors` per-reactor connection gauges.
    pub fn with_reactors(reactors: usize) -> Metrics {
        Metrics {
            start: Instant::now(),
            requests: Default::default(),
            errors: AtomicU64::new(0),
            infer_samples: AtomicU64::new(0),
            micro_batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            batch_hist: Hist::new(12),
            point_latency_us: Hist::new(28),
            infer_latency_us: Hist::new(28),
            pending: AtomicU64::new(0),
            rejected_queue: AtomicU64::new(0),
            rejected_conn: AtomicU64::new(0),
            refused_conns: AtomicU64::new(0),
            shed_slow_clients: AtomicU64::new(0),
            idle_timeouts: AtomicU64::new(0),
            conns_accepted: AtomicU64::new(0),
            conns_closed: AtomicU64::new(0),
            reactor_conns: (0..reactors)
                .map(|_| AtomicU64::new(0))
                .collect(),
            peer_fetches: AtomicU64::new(0),
            peer_fetch_hits: AtomicU64::new(0),
            peer_fetch_misses: AtomicU64::new(0),
        }
    }

    pub fn inc(&self, kind: Kind) {
        self.requests[kind as usize].fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self, kind: Kind) -> u64 {
        self.requests[kind as usize].load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Admit one compute request against the bounded pending queue:
    /// increments the gauge and returns `true`, or leaves it untouched
    /// and returns `false` when `cap` is reached — the caller then
    /// sheds with a structured `overloaded` reply. Lock-free CAS so
    /// the bound is exact, never approximate.
    pub fn try_admit(&self, cap: usize) -> bool {
        let mut cur = self.pending.load(Ordering::Relaxed);
        loop {
            if cur >= cap as u64 {
                return false;
            }
            match self.pending.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// One admitted request completed (reply handed to its reactor).
    pub fn pending_dec(&self) {
        self.pending.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn queue_depth(&self) -> u64 {
        self.pending.load(Ordering::Relaxed)
    }

    pub fn shed_queue(&self) {
        self.rejected_queue.fetch_add(1, Ordering::Relaxed);
    }

    pub fn shed_conn_cap(&self) {
        self.rejected_conn.fetch_add(1, Ordering::Relaxed);
    }

    pub fn refuse_conn(&self) {
        self.refused_conns.fetch_add(1, Ordering::Relaxed);
    }

    pub fn shed_slow_client(&self) {
        self.shed_slow_clients.fetch_add(1, Ordering::Relaxed);
    }

    pub fn idle_timeout(&self) {
        self.idle_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn rejected_total(&self) -> u64 {
        self.rejected_queue.load(Ordering::Relaxed)
            + self.rejected_conn.load(Ordering::Relaxed)
    }

    pub fn conn_opened(&self, reactor: usize) {
        self.conns_accepted.fetch_add(1, Ordering::Relaxed);
        if let Some(g) = self.reactor_conns.get(reactor) {
            g.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn conn_closed(&self, reactor: usize) {
        self.conns_closed.fetch_add(1, Ordering::Relaxed);
        if let Some(g) = self.reactor_conns.get(reactor) {
            g.fetch_sub(1, Ordering::Relaxed);
        }
    }

    pub fn open_conns(&self) -> u64 {
        self.reactor_conns
            .iter()
            .map(|g| g.load(Ordering::Relaxed))
            .sum()
    }

    /// Record the outcome of one peer point fetch: `hit` when the
    /// owning shard answered, miss when the requester fell back to a
    /// local solve.
    pub fn peer_fetch(&self, hit: bool) {
        self.peer_fetches.fetch_add(1, Ordering::Relaxed);
        if hit {
            self.peer_fetch_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.peer_fetch_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn peer_fetch_hits(&self) -> u64 {
        self.peer_fetch_hits.load(Ordering::Relaxed)
    }

    /// Record one executed micro-batch of `reqs` requests covering
    /// `samples` samples.
    pub fn record_batch(&self, reqs: usize, samples: usize) {
        self.micro_batches.fetch_add(1, Ordering::Relaxed);
        self.infer_samples
            .fetch_add(samples as u64, Ordering::Relaxed);
        self.batch_hist.record(reqs as u64);
        if reqs > 1 {
            self.batched_requests
                .fetch_add(reqs as u64, Ordering::Relaxed);
        }
        self.max_batch.fetch_max(reqs as u64, Ordering::Relaxed);
    }

    pub fn max_batch(&self) -> u64 {
        self.max_batch.load(Ordering::Relaxed)
    }

    pub fn batched_requests(&self) -> u64 {
        self.batched_requests.load(Ordering::Relaxed)
    }

    /// The `Stats` payload (merged with the server's static info by
    /// the reactor).
    pub fn to_json(&self) -> Json {
        let lat = |h: &Hist| {
            obj(vec![
                ("count", Json::Num(h.count() as f64)),
                ("p50_us_le", Json::Num(h.quantile(0.5) as f64)),
                ("p99_us_le", Json::Num(h.quantile(0.99) as f64)),
            ])
        };
        let n = |v: &AtomicU64| Json::Num(v.load(Ordering::Relaxed) as f64);
        obj(vec![
            (
                "uptime_s",
                Json::Num(self.start.elapsed().as_secs_f64()),
            ),
            (
                "requests",
                obj(KINDS
                    .iter()
                    .map(|&(name, kind)| {
                        (name, Json::Num(self.count(kind) as f64))
                    })
                    .collect()),
            ),
            ("errors", Json::Num(self.errors() as f64)),
            (
                "infer",
                obj(vec![
                    ("samples", n(&self.infer_samples)),
                    ("micro_batches", n(&self.micro_batches)),
                    (
                        "batched_requests",
                        Json::Num(self.batched_requests() as f64),
                    ),
                    (
                        "max_batch_requests",
                        Json::Num(self.max_batch() as f64),
                    ),
                    ("batch_hist", self.batch_hist.to_json()),
                ]),
            ),
            (
                "latency",
                obj(vec![
                    ("point", lat(&self.point_latency_us)),
                    ("infer", lat(&self.infer_latency_us)),
                ]),
            ),
            // event-loop tier (additive; DESIGN.md §16)
            (
                "serving",
                obj(vec![
                    ("queue_depth", n(&self.pending)),
                    (
                        "admission",
                        obj(vec![
                            ("rejected_queue", n(&self.rejected_queue)),
                            ("rejected_conn", n(&self.rejected_conn)),
                            ("refused_conns", n(&self.refused_conns)),
                        ]),
                    ),
                    (
                        "conns",
                        obj(vec![
                            (
                                "open",
                                Json::Num(self.open_conns() as f64),
                            ),
                            ("accepted", n(&self.conns_accepted)),
                            ("closed", n(&self.conns_closed)),
                            (
                                "per_reactor",
                                Json::Arr(
                                    self.reactor_conns
                                        .iter()
                                        .map(|g| {
                                            Json::Num(g.load(
                                                Ordering::Relaxed,
                                            )
                                                as f64)
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    ),
                    (
                        "shed_slow_clients",
                        n(&self.shed_slow_clients),
                    ),
                    ("idle_timeouts", n(&self.idle_timeouts)),
                    (
                        "peer",
                        obj(vec![
                            ("fetches", n(&self.peer_fetches)),
                            ("hits", n(&self.peer_fetch_hits)),
                            ("misses", n(&self.peer_fetch_misses)),
                        ]),
                    ),
                ]),
            ),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_and_quantiles_envelope() {
        let h = Hist::new(12);
        for v in [1u64, 1, 1, 2, 3, 900] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        // p50 of {1,1,1,2,3,900}: 3rd value = 1 -> bucket upper 1
        assert_eq!(h.quantile(0.5), 1);
        // the outlier lands in [512,1024) -> upper bound 1024
        assert_eq!(h.quantile(1.0), 1024);
        assert_eq!(h.quantile(0.99), 1024);
        // zero treated as the smallest bucket, values beyond the last
        // bucket clamp into it
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 8);
    }

    #[test]
    fn counters_and_batches_add_up() {
        let m = Metrics::new();
        m.inc(Kind::Point);
        m.inc(Kind::Infer);
        m.inc(Kind::Infer);
        m.inc_error();
        m.record_batch(1, 4);
        m.record_batch(2, 2);
        assert_eq!(m.count(Kind::Infer), 2);
        assert_eq!(m.count(Kind::Point), 1);
        assert_eq!(m.count(Kind::Shutdown), 0);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.max_batch(), 2);
        assert_eq!(m.batched_requests(), 2);
        let j = m.to_json();
        assert_eq!(
            j.req("requests").req("infer").as_f64(),
            2.0
        );
        assert_eq!(j.req("infer").req("samples").as_f64(), 6.0);
        assert_eq!(j.req("infer").req("micro_batches").as_f64(), 2.0);
    }

    #[test]
    fn admission_bound_is_exact() {
        let m = Metrics::new();
        assert!(m.try_admit(2));
        assert!(m.try_admit(2));
        assert!(!m.try_admit(2), "cap 2 admitted a third request");
        assert_eq!(m.queue_depth(), 2);
        m.pending_dec();
        assert!(m.try_admit(2));
        m.shed_queue();
        m.shed_conn_cap();
        assert_eq!(m.rejected_total(), 2);
        let j = m.to_json();
        let serving = j.req("serving");
        assert_eq!(serving.req("queue_depth").as_f64(), 2.0);
        assert_eq!(
            serving.req("admission").req("rejected_queue").as_f64(),
            1.0
        );
    }

    #[test]
    fn reactor_conn_gauges_and_peer_counters() {
        let m = Metrics::with_reactors(2);
        m.conn_opened(0);
        m.conn_opened(1);
        m.conn_opened(1);
        m.conn_closed(1);
        assert_eq!(m.open_conns(), 2);
        m.peer_fetch(true);
        m.peer_fetch(false);
        assert_eq!(m.peer_fetch_hits(), 1);
        let j = m.to_json();
        let serving = j.req("serving");
        let per = serving.req("conns").req("per_reactor").as_arr();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].as_f64(), 1.0);
        assert_eq!(per[1].as_f64(), 1.0);
        assert_eq!(serving.req("conns").req("accepted").as_f64(), 3.0);
        assert_eq!(serving.req("peer").req("fetches").as_f64(), 2.0);
        assert_eq!(serving.req("peer").req("misses").as_f64(), 1.0);
    }
}
