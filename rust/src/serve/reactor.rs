//! The serving event loop (DESIGN.md §16): each reactor thread owns a
//! [`Poller`] and a slab of non-blocking connections, frames NDJSON
//! request lines, runs admission control, and hands compute work to
//! the session/batcher tier over a channel. Completed replies come
//! back through a [`ReplySink`] — a mutex inbox plus [`Waker`] — so
//! compute threads never touch a socket and a reactor is never blocked
//! on one.
//!
//! Invariants the tests pin:
//! - **Ordered replies.** Every non-empty request line gets exactly
//!   one reply line, in arrival order per connection, even though
//!   point and infer completions finish on different threads
//!   ([`Sequencer`] parks early completions).
//! - **Bounded memory.** A request line without a newline beyond
//!   `max_line` gets a structured error and the connection is closed
//!   after the reply flushes — never an unbounded buffer. A client
//!   that stops reading its replies is shed at `wbuf_cap`.
//! - **Bounded queue.** Compute admission goes through
//!   [`Metrics::try_admit`]; a full queue or a connection over its
//!   in-flight cap sheds with [`protocol::overloaded_response`], it
//!   never queues unboundedly.
//! - **Slowloris containment.** A connection stalled mid-line longer
//!   than `idle_timeout` is closed (timer runs from the *start* of the
//!   partial line, so trickling one byte per second does not reset
//!   it). Fully idle connections — no partial line — cost nothing and
//!   are never reaped; cheap idle connections are the point of the
//!   reactor.
//! - **Stale-completion safety.** Slots are reused under a
//!   generation counter; a completion for a connection that died
//!   mid-request is discarded, never delivered to the slot's new
//!   tenant.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::evloop::{fd_of, would_block, Event, Interest, Poller, Waker};
use crate::util::json::Json;

use super::metrics::{Kind, Metrics};
use super::protocol::{self, InferReq, PointReq, Request};

/// Poller token reserved for the cross-thread waker; connection slots
/// use their index.
const WAKE: u64 = u64::MAX;

/// Hard cap on a request line (bytes) before the reactor replies with
/// a structured error and closes: an oversized line must cost one
/// buffer, not the heap. Generous — the largest legal request (a
/// 64-sample infer on the widest dataset) is well under 1 MiB.
pub const DEFAULT_MAX_LINE: usize = 4 << 20;
/// Unflushed reply bytes tolerated per connection before the client
/// is shed as too slow.
pub const DEFAULT_WBUF_CAP: usize = 4 << 20;
/// Per-connection cap on admitted-but-unanswered compute requests.
pub const DEFAULT_INFLIGHT_CAP: u64 = 32;
/// `retry_after_ms` hint carried on shed replies.
pub const DEFAULT_RETRY_AFTER_MS: u64 = 25;

/// Compute work a reactor hands to the session thread. Everything
/// protocol-validated; `sink` is where the (serialized) reply goes.
pub enum Work {
    Point {
        req: PointReq,
        /// `true` for a shard-to-shard `peer_point` fetch — always
        /// solved locally, never re-forwarded (DESIGN.md §16).
        peer: bool,
        sink: ReplySink,
        t0: Instant,
        /// Request-scoped trace id allocated at admission
        /// (DESIGN.md §17): carried through batcher → session →
        /// solver → kernels and echoed on the reply.
        trace: u64,
    },
    Infer {
        req: InferReq,
        sink: ReplySink,
        t0: Instant,
        /// See [`Work::Point::trace`].
        trace: u64,
    },
}

/// One finished reply heading back to its reactor.
pub struct Completion {
    slot: usize,
    gen: u64,
    seq: u64,
    line: String,
}

#[derive(Default)]
struct Inbox {
    conns: Vec<TcpStream>,
    completions: Vec<Completion>,
}

/// The cross-thread half of a reactor: the acceptor pushes fresh
/// connections, compute threads push completions; both wake the loop.
pub struct ReactorShared {
    inbox: Mutex<Inbox>,
    waker: Waker,
}

impl ReactorShared {
    /// Hand a freshly accepted connection to this reactor.
    pub fn push_conn(&self, stream: TcpStream) {
        self.inbox.lock().unwrap().conns.push(stream);
        self.waker.wake();
    }

    fn push_completion(&self, c: Completion) {
        self.inbox.lock().unwrap().completions.push(c);
        self.waker.wake();
    }
}

enum SinkTarget {
    Reactor {
        shared: Arc<ReactorShared>,
        slot: usize,
        gen: u64,
        seq: u64,
    },
    /// Test/bench harness: the serialized reply line goes to a plain
    /// channel instead of a reactor (lets the batcher run without any
    /// sockets).
    Channel(Sender<String>),
}

/// Single-use reply address for one admitted compute request.
/// Delivering it — by [`ReplySink::send`], or by the `Drop` backstop
/// if a compute thread panics mid-request — decrements the global
/// pending gauge, so the bounded queue accounts every admitted
/// request exactly once and the admission budget can never leak.
pub struct ReplySink {
    target: Option<SinkTarget>,
    pending: Option<Arc<Metrics>>,
}

impl ReplySink {
    fn to_reactor(
        shared: Arc<ReactorShared>,
        slot: usize,
        gen: u64,
        seq: u64,
        metrics: Arc<Metrics>,
    ) -> ReplySink {
        ReplySink {
            target: Some(SinkTarget::Reactor {
                shared,
                slot,
                gen,
                seq,
            }),
            pending: Some(metrics),
        }
    }

    /// A sink that forwards the serialized reply line to `tx` (unit
    /// tests and the batcher's own tests).
    pub fn to_channel(tx: Sender<String>) -> ReplySink {
        ReplySink {
            target: Some(SinkTarget::Channel(tx)),
            pending: None,
        }
    }

    /// Deliver the reply. Infallible from the caller's view: a dead
    /// reactor or dropped test receiver just discards the line (the
    /// connection it was for is gone anyway).
    pub fn send(mut self, reply: &Json) {
        self.deliver(reply.to_string());
    }

    fn deliver(&mut self, line: String) {
        if let Some(m) = self.pending.take() {
            m.pending_dec();
        }
        match self.target.take() {
            Some(SinkTarget::Reactor {
                shared,
                slot,
                gen,
                seq,
            }) => shared.push_completion(Completion {
                slot,
                gen,
                seq,
                line,
            }),
            Some(SinkTarget::Channel(tx)) => {
                let _ = tx.send(line);
            }
            None => {}
        }
    }
}

impl Drop for ReplySink {
    fn drop(&mut self) {
        if self.target.is_some() || self.pending.is_some() {
            // dropped without send — a compute thread panicked (or a
            // queue was torn down) with this request admitted. Two
            // things must not leak: the global pending gauge (or the
            // admission budget shrinks forever) and this sequence slot
            // (or every later reply on the connection parks behind it)
            self.deliver(
                protocol::error_response(
                    None,
                    "request dropped by server",
                )
                .to_string(),
            );
        }
    }
}

/// Per-connection reply ordering: every non-empty request line is
/// allocated the next sequence number on arrival; replies are released
/// strictly in that order, parking any that finish early.
pub struct Sequencer {
    next_alloc: u64,
    next_deliver: u64,
    parked: Vec<(u64, String)>,
}

impl Sequencer {
    pub fn new() -> Sequencer {
        Sequencer {
            next_alloc: 0,
            next_deliver: 0,
            parked: Vec::new(),
        }
    }

    pub fn alloc(&mut self) -> u64 {
        let s = self.next_alloc;
        self.next_alloc += 1;
        s
    }

    /// Accept the reply for `seq`; returns every line now ready to
    /// write, in order (empty if `seq` is still ahead of the stream).
    pub fn accept(&mut self, seq: u64, line: String) -> Vec<String> {
        if seq != self.next_deliver {
            self.parked.push((seq, line));
            return Vec::new();
        }
        let mut out = vec![line];
        self.next_deliver += 1;
        while let Some(i) = self
            .parked
            .iter()
            .position(|(s, _)| *s == self.next_deliver)
        {
            out.push(self.parked.swap_remove(i).1);
            self.next_deliver += 1;
        }
        out
    }
}

impl Default for Sequencer {
    fn default() -> Self {
        Sequencer::new()
    }
}

struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    seq: Sequencer,
    /// Admitted compute requests not yet answered.
    inflight: u64,
    /// When the current partial (newline-less) request line started;
    /// `None` while the read buffer is empty.
    partial_since: Option<Instant>,
    /// Flush the write buffer, then close; stop reading now.
    draining: bool,
    /// The peer closed its write side (EOF). No more requests will
    /// arrive, but buffered lines and in-flight completions still owe
    /// replies — the connection drains instead of closing.
    read_closed: bool,
    /// What the poller registration currently asks for; kept exact so
    /// a half-closed or fully-quiet socket is never level-polled in a
    /// busy loop. `readable && writable == false` means deregistered.
    interest: Interest,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            seq: Sequencer::new(),
            inflight: 0,
            partial_since: None,
            draining: false,
            read_closed: false,
            interest: Interest::READ,
        }
    }

    fn flushed(&self) -> bool {
        self.wpos >= self.wbuf.len()
    }
}

/// Everything a reactor thread needs; built by the server.
pub struct ReactorCfg {
    /// This reactor's index (metrics gauge slot).
    pub index: usize,
    /// Global bound on admitted-but-unanswered compute requests
    /// (shared via [`Metrics::try_admit`]).
    pub queue_cap: usize,
    pub inflight_cap: u64,
    pub max_line: usize,
    pub wbuf_cap: usize,
    pub idle_timeout: Duration,
    pub retry_after_ms: u64,
    pub shutdown: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
    /// Static server info merged into every stats reply.
    pub info: Json,
    pub work_tx: Sender<Work>,
}

/// Spawn one reactor thread; returns its cross-thread handle and the
/// join handle (joins once shutdown is flagged and its connections
/// have drained).
pub fn spawn(
    cfg: ReactorCfg,
) -> io::Result<(Arc<ReactorShared>, JoinHandle<()>)> {
    let poller = Poller::new()?;
    let waker = Waker::new(&poller, WAKE)?;
    let shared = Arc::new(ReactorShared {
        inbox: Mutex::new(Inbox::default()),
        waker,
    });
    let name = format!("serve-reactor-{}", cfg.index);
    let sh = shared.clone();
    let handle = std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            Reactor {
                cfg,
                poller,
                shared: sh,
                conns: Vec::new(),
                gens: Vec::new(),
                free: Vec::new(),
            }
            .run()
        })
        .map_err(io::Error::other)?;
    Ok((shared, handle))
}

struct Reactor {
    cfg: ReactorCfg,
    poller: Poller,
    shared: Arc<ReactorShared>,
    conns: Vec<Option<Conn>>,
    /// Per-slot generation, bumped on close so stale completions are
    /// discarded (lives outside `Conn` to survive slot reuse).
    gens: Vec<u64>,
    free: Vec<usize>,
}

impl Reactor {
    fn run(mut self) {
        let tick = self
            .cfg
            .idle_timeout
            .min(Duration::from_millis(100))
            .max(Duration::from_millis(5));
        let mut events: Vec<Event> = Vec::new();
        let mut drain_since: Option<Instant> = None;
        loop {
            if let Err(e) = self.poller.wait(&mut events, Some(tick)) {
                crate::log_error!(
                    "serve.reactor",
                    "reactor {} poller failed: {e}",
                    self.cfg.index
                );
                return;
            }
            // IO first, inbox second: a slot freed by an IO close must
            // not be re-tenanted before this batch's (now stale)
            // events for it are done.
            let mut woke = false;
            for ev in &events {
                if ev.token == WAKE {
                    woke = true;
                }
            }
            let batch: Vec<Event> = events
                .iter()
                .filter(|e| e.token != WAKE)
                .copied()
                .collect();
            for ev in batch {
                let slot = ev.token as usize;
                if slot < self.conns.len() {
                    self.handle_io(
                        slot,
                        ev.readable,
                        ev.writable,
                        ev.hangup,
                    );
                }
            }
            if woke {
                self.drain_inbox();
            }
            self.sweep_stalled(Instant::now());
            if self.cfg.shutdown.load(Ordering::SeqCst) {
                let since =
                    *drain_since.get_or_insert_with(Instant::now);
                // hard backstop: a shed-proof client that never reads
                // its last replies cannot wedge shutdown forever
                if since.elapsed() > Duration::from_secs(30) {
                    for slot in 0..self.conns.len() {
                        self.close(slot);
                    }
                }
                if self.drain_step() {
                    return;
                }
            }
        }
    }

    fn handle_io(
        &mut self,
        slot: usize,
        readable: bool,
        writable: bool,
        hangup: bool,
    ) {
        if readable || hangup {
            if self.read_phase(slot).is_err() {
                self.close(slot);
                return;
            }
            self.process_lines(slot);
            self.finish_read_closed(slot);
        }
        if writable || readable || hangup {
            self.flush(slot);
        }
    }

    /// Drain the socket into the read buffer. `Err` means the
    /// connection is dead (hard error); EOF is NOT death — a
    /// pipelined client may half-close its write side and still be
    /// owed every reply.
    fn read_phase(&mut self, slot: usize) -> Result<(), ()> {
        let Some(conn) = self.conns[slot].as_mut() else {
            return Ok(());
        };
        if conn.read_closed {
            return Ok(());
        }
        let mut buf = [0u8; 16 * 1024];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.read_closed = true;
                    return Ok(());
                }
                Ok(n) => {
                    if conn.draining {
                        continue; // discard: reply is on its way out
                    }
                    conn.rbuf.extend_from_slice(&buf[..n]);
                    // stop pulling once far past the line cap; the
                    // oversized-line error path takes it from here
                    if conn.rbuf.len() > self.cfg.max_line {
                        return Ok(());
                    }
                }
                Err(ref e) if would_block(e) => return Ok(()),
                Err(_) => return Err(()),
            }
        }
    }

    /// Frame and handle every complete request line buffered on
    /// `slot`, then update the partial-line stall timer.
    fn process_lines(&mut self, slot: usize) {
        let mut progressed = false;
        loop {
            let Some(conn) = self.conns[slot].as_mut() else {
                return;
            };
            if conn.draining {
                conn.rbuf.clear();
                break;
            }
            match conn.rbuf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    let raw: Vec<u8> =
                        conn.rbuf.drain(..=pos).collect();
                    let line = String::from_utf8_lossy(&raw[..pos])
                        .into_owned();
                    progressed = true;
                    self.handle_line(slot, &line);
                }
                None => {
                    if conn.rbuf.len() > self.cfg.max_line {
                        // structured refusal, then close once the
                        // reply has flushed — bounded memory, not OOM
                        let seq = conn.seq.alloc();
                        conn.draining = true;
                        conn.rbuf = Vec::new(); // free, not retain
                        self.cfg.metrics.inc_error();
                        let reply = protocol::error_response(
                            None,
                            &format!(
                                "request line exceeds {} bytes \
                                 (closing)",
                                self.cfg.max_line
                            ),
                        );
                        self.deliver(slot, seq, &reply);
                    }
                    break;
                }
            }
        }
        if let Some(conn) = self.conns[slot].as_mut() {
            if conn.rbuf.is_empty() {
                conn.partial_since = None;
            } else if progressed || conn.partial_since.is_none() {
                // a fresh partial line starts its stall clock; an
                // unfinished one keeps its original start so a
                // byte-trickling client cannot reset it
                conn.partial_since = Some(Instant::now());
            }
        }
    }

    /// After EOF every buffered complete line has been handled above;
    /// whatever is admitted or unflushed still owes a reply. Switch
    /// the connection to draining — flush, then close once in-flight
    /// completions land — so a client that `shutdown(SHUT_WR)`s after
    /// pipelining requests still receives every reply. A connection
    /// with nothing owed closes on the very next `flush`.
    fn finish_read_closed(&mut self, slot: usize) {
        let Some(conn) = self.conns[slot].as_mut() else {
            return;
        };
        if conn.read_closed && !conn.draining {
            conn.draining = true;
            conn.rbuf = Vec::new(); // a partial line can never finish
            conn.partial_since = None;
        }
    }

    fn handle_line(&mut self, slot: usize, line: &str) {
        let line = line.trim();
        if line.is_empty() {
            return; // keep-alive blank lines get no seq and no reply
        }
        let seq = match self.conns[slot].as_mut() {
            Some(conn) => conn.seq.alloc(),
            None => return,
        };
        let m = self.cfg.metrics.clone();
        match Request::parse(line) {
            Err((id, msg)) => {
                m.inc_error();
                let reply = protocol::error_response(id, &msg);
                self.deliver(slot, seq, &reply);
            }
            Ok(Request::Stats { id, prom }) => {
                m.inc(Kind::Stats);
                let stats = merge_stats(&self.cfg.info, m.to_json());
                let text =
                    prom.then(|| m.registry().prom_text());
                let reply = protocol::stats_response(id, stats, text);
                self.deliver(slot, seq, &reply);
            }
            Ok(Request::Shutdown { id }) => {
                m.inc(Kind::Shutdown);
                let reply = protocol::shutdown_response(id);
                self.deliver(slot, seq, &reply);
                // reply first, then flag: the drain pass below must
                // find this reply already queued on the socket
                self.cfg.shutdown.store(true, Ordering::SeqCst);
            }
            Ok(req) => self.admit(slot, seq, req),
        }
    }

    /// Admission control for compute requests (DESIGN.md §16): per-
    /// connection in-flight cap first, then the global bounded queue.
    /// Sheds answer inline with a structured `overloaded` reply — in
    /// sequence, like any other reply.
    fn admit(&mut self, slot: usize, seq: u64, req: Request) {
        let m = self.cfg.metrics.clone();
        let (id, kind) = match &req {
            Request::Point(p) => (p.id, Kind::Point),
            Request::PeerPoint(p) => (p.id, Kind::PeerPoint),
            Request::Infer(q) => (q.id, Kind::Infer),
            _ => unreachable!("admit() only sees compute requests"),
        };
        let inflight = match self.conns[slot].as_ref() {
            Some(c) => c.inflight,
            None => return,
        };
        if inflight >= self.cfg.inflight_cap {
            m.shed_conn_cap();
            let reply = protocol::overloaded_response(
                Some(id),
                &format!(
                    "connection in-flight cap ({}) reached",
                    self.cfg.inflight_cap
                ),
                self.cfg.retry_after_ms,
            );
            self.deliver(slot, seq, &reply);
            return;
        }
        if !m.try_admit(self.cfg.queue_cap) {
            m.shed_queue();
            let reply = protocol::overloaded_response(
                Some(id),
                &format!(
                    "compute queue full ({} pending)",
                    self.cfg.queue_cap
                ),
                self.cfg.retry_after_ms,
            );
            self.deliver(slot, seq, &reply);
            return;
        }
        m.inc(kind);
        let sink = ReplySink::to_reactor(
            self.shared.clone(),
            slot,
            self.gens[slot],
            seq,
            m,
        );
        let t0 = Instant::now();
        // allocated unconditionally (cheap: one atomic) so the reply's
        // trace echo works even when tracing is off
        let trace = crate::obs::new_trace_id();
        let work = match req {
            Request::Point(p) => Work::Point {
                req: p,
                peer: false,
                sink,
                t0,
                trace,
            },
            Request::PeerPoint(p) => Work::Point {
                req: p,
                peer: true,
                sink,
                t0,
                trace,
            },
            Request::Infer(q) => Work::Infer {
                req: q,
                sink,
                t0,
                trace,
            },
            _ => unreachable!(),
        };
        if let Some(conn) = self.conns[slot].as_mut() {
            conn.inflight += 1;
        }
        if let Err(lost) = self.cfg.work_tx.send(work) {
            // session thread already gone (drain race): answer here.
            // The sink routes through our own inbox, so the normal
            // completion path still delivers it in order.
            let sink = match lost.0 {
                Work::Point { sink, .. } | Work::Infer { sink, .. } => {
                    sink
                }
            };
            sink.send(&protocol::error_response(
                Some(id),
                "server is draining",
            ));
        }
    }

    /// Queue one serialized reply line in per-connection order.
    fn deliver(&mut self, slot: usize, seq: u64, reply: &Json) {
        let Some(conn) = self.conns[slot].as_mut() else {
            return;
        };
        for line in conn.seq.accept(seq, reply.to_string()) {
            conn.wbuf.extend_from_slice(line.as_bytes());
            conn.wbuf.push(b'\n');
        }
    }

    /// Write out as much of `slot`'s buffer as the socket takes;
    /// manage poller interest; shed over-cap slow clients; finish
    /// drain-closes.
    fn flush(&mut self, slot: usize) {
        enum After {
            Keep,
            Close,
        }
        let after = {
            let Some(conn) = self.conns[slot].as_mut() else {
                return;
            };
            let mut verdict = None;
            while conn.wpos < conn.wbuf.len() {
                match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                    Ok(0) => {
                        verdict = Some(After::Close);
                        break;
                    }
                    Ok(n) => conn.wpos += n,
                    Err(ref e) if would_block(e) => {
                        verdict = Some(After::Keep);
                        break;
                    }
                    Err(_) => {
                        verdict = Some(After::Close);
                        break;
                    }
                }
            }
            verdict.unwrap_or_else(|| {
                conn.wbuf.clear();
                conn.wpos = 0;
                if conn.draining && conn.inflight == 0 {
                    After::Close
                } else {
                    After::Keep
                }
            })
        };
        match after {
            After::Close => self.close(slot),
            After::Keep => {
                let conn = self.conns[slot].as_mut().unwrap();
                if conn.wbuf.len() - conn.wpos > self.cfg.wbuf_cap {
                    // client not reading its replies: shed it rather
                    // than buffer without bound
                    self.cfg.metrics.shed_slow_client();
                    self.close(slot);
                    return;
                }
                self.update_interest(slot);
            }
        }
    }

    /// Re-derive the poller registration from connection state: read
    /// interest while the peer can still send requests, write
    /// interest while there are unflushed bytes. A connection wanting
    /// neither (half-closed, waiting only on compute completions) is
    /// deregistered entirely — the inbox waker re-arms it — so a
    /// level-triggered poller never busy-spins on its EOF.
    fn update_interest(&mut self, slot: usize) {
        let (want, cur, fd) = {
            let Some(conn) = self.conns[slot].as_ref() else {
                return;
            };
            let want = Interest {
                readable: !conn.read_closed,
                writable: !conn.flushed(),
            };
            (want, conn.interest, fd_of(&conn.stream))
        };
        if want == cur {
            return;
        }
        let none =
            |i: Interest| !i.readable && !i.writable;
        let r = if none(want) {
            self.poller.deregister(fd)
        } else if none(cur) {
            self.poller.register(fd, slot as u64, want)
        } else {
            self.poller.modify(fd, slot as u64, want)
        };
        match r {
            Ok(()) => {
                if let Some(conn) = self.conns[slot].as_mut() {
                    conn.interest = want;
                }
            }
            // a registration we cannot track is a connection we
            // cannot serve correctly
            Err(_) => self.close(slot),
        }
    }

    /// Register freshly accepted connections and apply completions
    /// pushed by the compute tier.
    fn drain_inbox(&mut self) {
        self.shared.waker.drain();
        let (new_conns, completions) = {
            let mut inbox = self.shared.inbox.lock().unwrap();
            (
                std::mem::take(&mut inbox.conns),
                std::mem::take(&mut inbox.completions),
            )
        };
        for stream in new_conns {
            self.add_conn(stream);
        }
        let mut touched = Vec::new();
        for c in completions {
            if c.slot >= self.conns.len()
                || self.gens[c.slot] != c.gen
            {
                continue; // connection died; its slot may be reused
            }
            let Some(conn) = self.conns[c.slot].as_mut() else {
                continue;
            };
            conn.inflight = conn.inflight.saturating_sub(1);
            for line in conn.seq.accept(c.seq, c.line) {
                conn.wbuf.extend_from_slice(line.as_bytes());
                conn.wbuf.push(b'\n');
            }
            if !touched.contains(&c.slot) {
                touched.push(c.slot);
            }
        }
        for slot in touched {
            self.flush(slot);
        }
    }

    fn add_conn(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return; // fd already dead; drop it
        }
        let _ = stream.set_nodelay(true);
        let slot = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.gens.push(0);
            self.conns.len() - 1
        });
        if self
            .poller
            .register(fd_of(&stream), slot as u64, Interest::READ)
            .is_err()
        {
            self.free.push(slot);
            return;
        }
        self.conns[slot] = Some(Conn::new(stream));
        self.cfg.metrics.conn_opened(self.cfg.index);
    }

    /// Close connections stalled mid-request-line past the idle
    /// timeout (slowloris containment; truly idle connections are
    /// untouched).
    fn sweep_stalled(&mut self, now: Instant) {
        let stalled: Vec<usize> = self
            .conns
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let conn = c.as_ref()?;
                let since = conn.partial_since?;
                (now.duration_since(since) > self.cfg.idle_timeout)
                    .then_some(i)
            })
            .collect();
        for slot in stalled {
            self.cfg.metrics.idle_timeout();
            self.close(slot);
        }
    }

    /// One shutdown-drain pass: stop reading everywhere, close every
    /// connection with nothing left to answer or flush. `true` when
    /// the reactor is empty and may exit.
    fn drain_step(&mut self) -> bool {
        let mut closable = Vec::new();
        for (i, c) in self.conns.iter_mut().enumerate() {
            if let Some(conn) = c {
                conn.draining = true;
                conn.rbuf.clear();
                if conn.inflight == 0 && conn.flushed() {
                    closable.push(i);
                }
            }
        }
        for slot in closable {
            self.close(slot);
        }
        self.conns.iter().all(|c| c.is_none())
    }

    fn close(&mut self, slot: usize) {
        if let Some(conn) = self.conns[slot].take() {
            let _ = self.poller.deregister(fd_of(&conn.stream));
            self.gens[slot] += 1;
            self.free.push(slot);
            self.cfg.metrics.conn_closed(self.cfg.index);
            // `conn.stream` drops here, closing the fd
        }
    }
}

/// Live metrics with the static server info under `"server"` — the
/// exact shape the pre-§16 stats reply had, so existing clients keep
/// parsing.
fn merge_stats(info: &Json, metrics: Json) -> Json {
    let mut map = match metrics {
        Json::Obj(m) => m,
        _ => Default::default(),
    };
    map.insert("server".into(), info.clone());
    Json::Obj(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;
    use std::io::{BufRead, BufReader, Write as _};
    use std::net::TcpListener;
    use std::sync::mpsc;

    #[test]
    fn sequencer_releases_in_alloc_order() {
        let mut s = Sequencer::new();
        let (a, b, c) = (s.alloc(), s.alloc(), s.alloc());
        assert_eq!((a, b, c), (0, 1, 2));
        // c and b finish before a: both park
        assert!(s.accept(c, "C".into()).is_empty());
        assert!(s.accept(b, "B".into()).is_empty());
        // a releases everything, in order
        assert_eq!(
            s.accept(a, "A".into()),
            vec!["A".to_string(), "B".into(), "C".into()]
        );
        // the stream continues where it left off
        let d = s.alloc();
        assert_eq!(s.accept(d, "D".into()), vec!["D".to_string()]);
    }

    #[test]
    fn channel_sink_decrements_nothing_and_delivers() {
        let (tx, rx) = mpsc::channel();
        let sink = ReplySink::to_channel(tx);
        sink.send(&protocol::error_response(Some(1.0), "x"));
        let line = rx.recv().unwrap();
        assert!(line.contains("\"ok\":false") || line.contains("x"));
    }

    /// A sink dropped without `send` (compute-thread panic path) must
    /// restore the admission budget and still deliver a structured
    /// error, exactly once; a sent sink's drop must do nothing.
    #[test]
    fn dropped_sink_restores_pending_and_answers() {
        let metrics = Arc::new(Metrics::with_reactors(1));
        assert!(metrics.try_admit(4));
        let (tx, rx) = mpsc::channel();
        let sink = ReplySink {
            target: Some(SinkTarget::Channel(tx)),
            pending: Some(metrics.clone()),
        };
        assert_eq!(metrics.queue_depth(), 1);
        drop(sink);
        assert_eq!(
            metrics.queue_depth(),
            0,
            "dropped sink leaked the admission budget"
        );
        let line = rx.recv().unwrap();
        assert!(line.contains("dropped"), "no backstop reply: {line}");

        // the send path pays the budget back exactly once
        assert!(metrics.try_admit(4));
        let (tx, rx) = mpsc::channel();
        let sink = ReplySink {
            target: Some(SinkTarget::Channel(tx)),
            pending: Some(metrics.clone()),
        };
        sink.send(&protocol::error_response(Some(1.0), "x"));
        assert_eq!(metrics.queue_depth(), 0);
        assert_eq!(
            rx.try_iter().count(),
            1,
            "send-then-drop must deliver exactly one line"
        );
    }

    /// End-to-end through a real reactor with a fake compute tier:
    /// pipelined requests get their replies strictly in order even
    /// when the compute reply for the first arrives late.
    #[test]
    fn reactor_orders_pipelined_replies() {
        let metrics = Arc::new(Metrics::with_reactors(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (work_tx, work_rx) = mpsc::channel::<Work>();
        let cfg = ReactorCfg {
            index: 0,
            queue_cap: 16,
            inflight_cap: 8,
            max_line: 1 << 20,
            wbuf_cap: 1 << 20,
            idle_timeout: Duration::from_secs(5),
            retry_after_ms: 10,
            shutdown: shutdown.clone(),
            metrics: metrics.clone(),
            info: obj(vec![("backend", Json::Str("test".into()))]),
            work_tx,
        };
        let (shared, handle) = spawn(cfg).unwrap();
        // fake session: sits on the first job for a beat, then
        // answers — the stats reply (handled inline, instantly) must
        // still come second on the wire
        let fake = std::thread::spawn(move || {
            while let Ok(w) = work_rx.recv() {
                std::thread::sleep(Duration::from_millis(80));
                match w {
                    Work::Point { req, sink, .. } => sink.send(
                        &protocol::error_response(
                            Some(req.id),
                            "fake point",
                        ),
                    ),
                    Work::Infer { req, sink, .. } => sink.send(
                        &protocol::error_response(
                            Some(req.id),
                            "fake infer",
                        ),
                    ),
                }
            }
        });

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        shared.push_conn(server_side);

        let mut w = client.try_clone().unwrap();
        w.write_all(
            b"{\"v\":1,\"id\":1,\"type\":\"point\",\
              \"dataset\":\"fashion_syn\",\"k\":14}\n\
              {\"v\":1,\"id\":2,\"type\":\"stats\"}\n",
        )
        .unwrap();
        let mut r = BufReader::new(client);
        let mut first = String::new();
        let mut second = String::new();
        r.read_line(&mut first).unwrap();
        r.read_line(&mut second).unwrap();
        let first = Json::parse(&first).unwrap();
        let second = Json::parse(&second).unwrap();
        assert_eq!(
            first.req("id").as_f64(),
            1.0,
            "slow compute reply must still come first"
        );
        assert_eq!(second.req("id").as_f64(), 2.0);
        assert_eq!(second.req("type").as_str(), "stats");
        assert_eq!(
            second
                .req("stats")
                .req("server")
                .req("backend")
                .as_str(),
            "test"
        );
        assert_eq!(metrics.queue_depth(), 0, "pending leaked");

        // drain: flag + wake, reactor exits once the conn closes
        drop(r);
        shutdown.store(true, Ordering::SeqCst);
        handle.join().unwrap();
        drop(fake);
    }

    /// A pipelined client that half-closes its write side
    /// (`shutdown(SHUT_WR)`) right after sending must still receive
    /// every reply — EOF drains the connection, it does not kill it.
    #[test]
    fn half_closed_client_still_receives_pipelined_replies() {
        let metrics = Arc::new(Metrics::with_reactors(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (work_tx, work_rx) = mpsc::channel::<Work>();
        let cfg = ReactorCfg {
            index: 0,
            queue_cap: 16,
            inflight_cap: 8,
            max_line: 1 << 20,
            wbuf_cap: 1 << 20,
            idle_timeout: Duration::from_secs(5),
            retry_after_ms: 10,
            shutdown: shutdown.clone(),
            metrics: metrics.clone(),
            info: obj(vec![("backend", Json::Str("test".into()))]),
            work_tx,
        };
        let (shared, handle) = spawn(cfg).unwrap();
        // the compute reply lands well after the EOF reaches the
        // reactor — the drain has to hold the connection open for it
        let fake = std::thread::spawn(move || {
            while let Ok(w) = work_rx.recv() {
                std::thread::sleep(Duration::from_millis(80));
                match w {
                    Work::Point { req, sink, .. } => sink.send(
                        &protocol::error_response(
                            Some(req.id),
                            "fake point",
                        ),
                    ),
                    Work::Infer { req, sink, .. } => sink.send(
                        &protocol::error_response(
                            Some(req.id),
                            "fake infer",
                        ),
                    ),
                }
            }
        });

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        shared.push_conn(server_side);

        let mut w = client.try_clone().unwrap();
        w.write_all(
            b"{\"v\":1,\"id\":1,\"type\":\"point\",\
              \"dataset\":\"fashion_syn\",\"k\":14}\n\
              {\"v\":1,\"id\":2,\"type\":\"stats\"}\n",
        )
        .unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let mut r = BufReader::new(client);
        let mut first = String::new();
        let mut second = String::new();
        r.read_line(&mut first).unwrap();
        r.read_line(&mut second).unwrap();
        assert_eq!(
            Json::parse(&first).unwrap().req("id").as_f64(),
            1.0,
            "half-close lost the in-flight compute reply"
        );
        assert_eq!(
            Json::parse(&second).unwrap().req("id").as_f64(),
            2.0
        );
        // with everything owed delivered, the server closes its side
        let mut rest = String::new();
        assert_eq!(
            r.read_line(&mut rest).unwrap(),
            0,
            "drained connection must close"
        );
        assert_eq!(metrics.queue_depth(), 0, "pending leaked");

        shutdown.store(true, Ordering::SeqCst);
        handle.join().unwrap();
        drop(fake);
    }
}
