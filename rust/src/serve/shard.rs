//! Consistent hashing of operating-point cache keys across serving
//! shards (DESIGN.md §16).
//!
//! Every shard in a `--peers` ring builds the same [`HashRing`] from
//! the *ordered* peer list alone — ring points hash shard indices, not
//! addresses, so processes agree on ownership regardless of how each
//! one writes the others' addresses (`127.0.0.1` vs `localhost`), and
//! the ring never depends on DNS. Ownership of a spec is decided by
//! its content-addressed cache key
//! ([`crate::session::OperatingPointSpec::cache_key`]), which two
//! shards with identical config knobs compute identically — the
//! precondition for a peer-fetched point being bit-identical to a
//! local solve.
//!
//! `VNODES` virtual points per shard smooth the key distribution; with
//! a handful of shards the worst/best load ratio stays under ~2 (the
//! distribution test pins a looser bound).

use crate::util::hash::fnv1a;

/// Virtual ring points per shard.
pub const VNODES: usize = 64;

/// A consistent-hash ring over shard indices `0..n`.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// (ring position, shard index), sorted by position.
    points: Vec<(u64, usize)>,
    n: usize,
}

impl HashRing {
    /// A ring over `n` shards (`n = 0` is treated as standalone:
    /// every key is owned by shard 0).
    pub fn new(n: usize) -> HashRing {
        let n = n.max(1);
        let mut points = Vec::with_capacity(n * VNODES);
        for shard in 0..n {
            for v in 0..VNODES {
                points.push((
                    fnv1a(format!("shard{shard}#{v}").as_bytes()),
                    shard,
                ));
            }
        }
        points.sort_unstable();
        HashRing { points, n }
    }

    pub fn shards(&self) -> usize {
        self.n
    }

    /// The shard owning `key`: the first ring point at or after the
    /// key's hash, wrapping at the top.
    pub fn owner(&self, key: &str) -> usize {
        if self.n <= 1 {
            return 0;
        }
        let h = fnv1a(key.as_bytes());
        let i = self
            .points
            .partition_point(|&(pos, _)| pos < h);
        self.points[i % self.points.len()].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("16charhexkey{i:04x}")).collect()
    }

    #[test]
    fn ownership_is_deterministic_and_total() {
        let a = HashRing::new(4);
        let b = HashRing::new(4);
        for k in keys(500) {
            let o = a.owner(&k);
            assert!(o < 4);
            assert_eq!(o, b.owner(&k), "rings disagree on {k}");
        }
    }

    #[test]
    fn standalone_and_single_shard_own_everything() {
        for ring in [HashRing::new(0), HashRing::new(1)] {
            for k in keys(50) {
                assert_eq!(ring.owner(&k), 0);
            }
        }
    }

    #[test]
    fn distribution_is_roughly_balanced() {
        let ring = HashRing::new(4);
        let mut counts = [0usize; 4];
        for k in keys(4000) {
            counts[ring.owner(&k)] += 1;
        }
        let (min, max) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(min > 0, "a shard owns nothing: {counts:?}");
        assert!(
            (max as f64) < 3.0 * min as f64,
            "wildly unbalanced: {counts:?}"
        );
    }

    #[test]
    fn growing_the_ring_moves_only_a_fraction_of_keys() {
        let four = HashRing::new(4);
        let five = HashRing::new(5);
        let ks = keys(4000);
        let moved = ks
            .iter()
            .filter(|k| four.owner(k) != five.owner(k))
            .count();
        // consistent hashing: adding one shard to four should move
        // about 1/5 of the keys, not rehash the world
        assert!(
            moved < ks.len() / 2,
            "{moved}/{} keys moved going 4 -> 5 shards",
            ks.len()
        );
    }
}
