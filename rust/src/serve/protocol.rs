//! The serve wire protocol: one JSON object per line, both directions
//! (DESIGN.md §12).
//!
//! Every request carries `"v": 1` (the protocol version — anything
//! else is rejected with a structured error so old clients fail loud,
//! not weird), a client-chosen numeric `"id"` echoed on the reply, and
//! a `"type"`. Replies carry `"ok": true` plus type-specific fields,
//! or `"ok": false` with a human-readable `"error"` (and the request
//! id when one could be parsed). Requests are validated here — axis
//! ranges, dataset names, sample shapes — so the compute threads only
//! ever see well-formed work.

use crate::data::synth::Dataset;
use crate::session::OperatingPoint;
use crate::util::json::{obj, Json};

/// Wire protocol version; bump on any incompatible change.
pub const PROTOCOL_VERSION: u64 = 1;

/// Upper bound on samples per `Infer` request: keeps a single request
/// from monopolizing the batcher (batch *across* requests instead).
pub const MAX_INFER_SAMPLES: usize = 64;

/// An operating-point solve request: the serve twin of
/// `capmin point`.
#[derive(Clone, Debug, PartialEq)]
pub struct PointReq {
    pub id: f64,
    pub dataset: Dataset,
    pub k: usize,
    pub sigma: f64,
    pub phi: usize,
    /// Accuracy-evaluate the point (one seed) instead of a pure
    /// hardware solve.
    pub eval: bool,
}

/// A native-backend inference request: `n` samples of
/// `dataset.spec().pixels()` +-1 values each, evaluated at the
/// operating point (k, sigma, phi) under `seed`. The whole request is
/// one forward batch, so its reply is a pure function of the request
/// alone — micro-batching with other clients cannot change it.
#[derive(Clone, Debug, PartialEq)]
pub struct InferReq {
    pub id: f64,
    pub dataset: Dataset,
    pub k: usize,
    pub sigma: f64,
    pub phi: usize,
    pub seed: u32,
    /// Row-major samples, `n * pixels` values.
    pub x: Vec<f32>,
    pub n: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Point(PointReq),
    /// A shard-to-shard point fetch (DESIGN.md §16): parsed and
    /// validated exactly like `Point`, but always solved *locally* by
    /// the receiving shard — never re-forwarded, so a misconfigured
    /// ring can produce an extra solve but never a forwarding loop.
    PeerPoint(PointReq),
    Infer(InferReq),
    Stats {
        id: f64,
        /// Also include the Prometheus text exposition of the global
        /// metrics registry in the reply (`"prom"` field,
        /// DESIGN.md §17).
        prom: bool,
    },
    Shutdown { id: f64 },
}

/// A parse/validation failure: the id to echo (when one was readable)
/// and the message for the structured error reply.
pub type ParseError = (Option<f64>, String);

impl Request {
    /// Parse and validate one request line.
    pub fn parse(line: &str) -> Result<Request, ParseError> {
        let j = Json::parse(line.trim())
            .map_err(|e| (None, format!("bad JSON: {e}")))?;
        // pull the id first so even version errors can echo it
        let id = match j.get("id") {
            Some(Json::Num(n)) => Some(*n),
            Some(other) => {
                return Err((
                    None,
                    format!("bad `id`: expected a number, got {other:?}"),
                ))
            }
            None => None,
        };
        let fail = |msg: String| (id, msg);
        match j.get("v") {
            Some(Json::Num(n)) if *n == PROTOCOL_VERSION as f64 => {}
            Some(Json::Num(n)) => {
                return Err(fail(format!(
                    "unsupported protocol version {n} (this server \
                     speaks v{PROTOCOL_VERSION})"
                )))
            }
            _ => {
                return Err(fail(format!(
                    "missing `v`: requests must declare the protocol \
                     version (this server speaks v{PROTOCOL_VERSION})"
                )))
            }
        }
        let id = id.ok_or_else(|| {
            (None, "missing `id`: replies echo it".to_string())
        })?;
        let ty = match j.get("type") {
            Some(Json::Str(s)) => s.clone(),
            _ => return Err(fail("missing `type`".into())),
        };
        match ty.as_str() {
            "stats" => {
                let prom = match j.get("prom") {
                    Some(Json::Bool(b)) => *b,
                    None => false,
                    Some(other) => {
                        return Err(fail(format!(
                            "bad `prom`: expected a bool, got {other:?}"
                        )))
                    }
                };
                Ok(Request::Stats { id, prom })
            }
            "shutdown" => Ok(Request::Shutdown { id }),
            "point" | "peer_point" | "infer" => {
                let dataset = match j.get("dataset") {
                    Some(Json::Str(s)) => {
                        Dataset::from_name(s).ok_or_else(|| {
                            fail(format!(
                                "unknown dataset `{s}` (valid: {})",
                                Dataset::all()
                                    .iter()
                                    .map(|d| d.spec().name)
                                    .collect::<Vec<_>>()
                                    .join(", ")
                            ))
                        })?
                    }
                    _ => return Err(fail("missing `dataset`".into())),
                };
                let num_or = |key: &str, default: f64| match j.get(key) {
                    Some(Json::Num(n)) => Ok(*n),
                    None => Ok(default),
                    Some(other) => Err(fail(format!(
                        "bad `{key}`: expected a number, got {other:?}"
                    ))),
                };
                // integer axes reject fractions instead of silently
                // truncating (14.7 must not serve as 14)
                let int_or = |key: &str, default: usize| match j
                    .get(key)
                {
                    Some(Json::Num(n))
                        if n.fract() == 0.0 && *n >= 0.0 =>
                    {
                        Ok(*n as usize)
                    }
                    None => Ok(default),
                    Some(other) => Err(fail(format!(
                        "bad `{key}`: expected a non-negative \
                         integer, got {other:?}"
                    ))),
                };
                let k = match j.get("k") {
                    Some(Json::Num(n)) if n.fract() == 0.0 => {
                        *n as usize
                    }
                    Some(other) => {
                        return Err(fail(format!(
                            "bad `k`: expected an integer, got \
                             {other:?}"
                        )))
                    }
                    None => return Err(fail("missing `k`".into())),
                };
                if !(1..=32).contains(&k) {
                    return Err(fail(format!(
                        "bad `k` {k}: CapMin k must be in 1..=32"
                    )));
                }
                let sigma = num_or("sigma", 0.0)?;
                if sigma.is_nan() || sigma < 0.0 || sigma > 1.0 {
                    return Err(fail(format!(
                        "bad `sigma` {sigma}: expected 0.0..=1.0"
                    )));
                }
                let phi = int_or("phi", 0)?;
                if phi >= k {
                    return Err(fail(format!(
                        "bad `phi` {phi}: CapMin-V merges must leave at \
                         least one spike time (phi < k)"
                    )));
                }
                if ty != "infer" {
                    let eval = match j.get("eval") {
                        Some(Json::Bool(b)) => *b,
                        None => false,
                        Some(other) => {
                            return Err(fail(format!(
                                "bad `eval`: expected a bool, got \
                                 {other:?}"
                            )))
                        }
                    };
                    let p = PointReq {
                        id,
                        dataset,
                        k,
                        sigma,
                        phi,
                        eval,
                    };
                    return Ok(if ty == "point" {
                        Request::Point(p)
                    } else {
                        Request::PeerPoint(p)
                    });
                }
                let seed = int_or("seed", 1)? as u32;
                let pixels = dataset.spec().pixels();
                let rows = match j.get("x") {
                    Some(Json::Arr(rows)) if !rows.is_empty() => rows,
                    Some(Json::Arr(_)) => {
                        return Err(fail(
                            "bad `x`: need at least one sample".into(),
                        ))
                    }
                    _ => {
                        return Err(fail(
                            "missing `x`: array of sample rows".into(),
                        ))
                    }
                };
                if rows.len() > MAX_INFER_SAMPLES {
                    return Err(fail(format!(
                        "too many samples: {} (limit \
                         {MAX_INFER_SAMPLES} per request — split, the \
                         batcher coalesces)",
                        rows.len()
                    )));
                }
                let mut x = Vec::with_capacity(rows.len() * pixels);
                for (ri, row) in rows.iter().enumerate() {
                    let vals = match row {
                        Json::Arr(v) => v,
                        _ => {
                            return Err(fail(format!(
                                "bad `x[{ri}]`: expected an array of \
                                 numbers"
                            )))
                        }
                    };
                    if vals.len() != pixels {
                        return Err(fail(format!(
                            "bad `x[{ri}]`: {} values, {} needs {pixels} \
                             per sample",
                            vals.len(),
                            dataset.spec().name
                        )));
                    }
                    for v in vals {
                        match v {
                            Json::Num(n) => x.push(*n as f32),
                            other => {
                                return Err(fail(format!(
                                    "bad `x[{ri}]` entry: {other:?}"
                                )))
                            }
                        }
                    }
                }
                Ok(Request::Infer(InferReq {
                    id,
                    dataset,
                    k,
                    sigma,
                    phi,
                    seed,
                    n: rows.len(),
                    x,
                }))
            }
            other => Err(fail(format!(
                "unknown request type `{other}` (valid: point, infer, \
                 peer_point, stats, shutdown)"
            ))),
        }
    }
}

fn reply_head(id: f64, ty: &str) -> Vec<(&'static str, Json)> {
    vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("id", Json::Num(id)),
        ("ok", Json::Bool(true)),
        ("type", Json::Str(ty.to_string())),
    ]
}

/// Reply to a `Point` request: the operating point's headline numbers
/// plus its cache key (clients can find the full JSON under
/// `<run-dir>/points/<key>.json`), its hardware cost vector
/// (DESIGN.md §13) and its Monte-Carlo provenance (DESIGN.md §15) —
/// additive fields, so older clients keep working untouched.
pub fn point_response(id: f64, key: &str, p: &OperatingPoint) -> Json {
    let w = p.peak_window();
    let mut fields = reply_head(id, "point");
    fields.extend([
        ("key", Json::Str(key.to_string())),
        ("dataset", Json::Str(p.spec.dataset.spec().name.into())),
        ("k", Json::Num(p.spec.k as f64)),
        ("sigma", Json::Num(p.spec.sigma)),
        ("phi", Json::Num(p.spec.phi as f64)),
        ("c", Json::Num(p.c)),
        ("grt", Json::Num(p.grt)),
        (
            "window",
            obj(vec![
                ("q_lo", Json::Num(w.q_lo as f64)),
                ("q_hi", Json::Num(w.q_hi as f64)),
                ("coverage", Json::Num(w.coverage)),
            ]),
        ),
        (
            "accuracy",
            match p.accuracy {
                Some(a) => Json::Num(a),
                None => Json::Null,
            },
        ),
        ("cost", p.cost.to_json()),
        (
            "mc",
            obj(vec![
                ("mode", Json::Str(p.meta.mc_mode.clone())),
                ("draws", Json::Num(p.meta.mc_draws as f64)),
            ]),
        ),
    ]);
    obj(fields)
}

/// Reply to an `Infer` request: per-sample logits rows and argmax
/// classes.
pub fn infer_response(
    id: f64,
    logits: &[f32],
    n: usize,
    n_classes: usize,
) -> Json {
    let mut rows = Vec::with_capacity(n);
    let mut classes = Vec::with_capacity(n);
    for i in 0..n {
        let row = &logits[i * n_classes..(i + 1) * n_classes];
        rows.push(Json::Arr(
            row.iter().map(|&v| Json::Num(v as f64)).collect(),
        ));
        classes
            .push(Json::Num(crate::util::stats::argmax(row) as f64));
    }
    let mut fields = reply_head(id, "infer");
    fields.extend([
        ("classes", Json::Arr(classes)),
        ("logits", Json::Arr(rows)),
    ]);
    obj(fields)
}

/// Reply to a `Stats` request; `stats` comes from
/// [`super::metrics::Metrics::to_json`] merged with the server's
/// static info. `prom` (from a `"prom": true` request) carries the
/// registry's Prometheus text exposition verbatim.
pub fn stats_response(id: f64, stats: Json, prom: Option<String>)
    -> Json {
    let mut fields = reply_head(id, "stats");
    fields.push(("stats", stats));
    if let Some(text) = prom {
        fields.push(("prom", Json::Str(text)));
    }
    obj(fields)
}

/// Tag a reply with the request's trace id (lowercase hex,
/// DESIGN.md §17) — an additive field old clients ignore. Trace id 0
/// (untraced internal paths) leaves the reply untouched.
pub fn with_trace(reply: Json, trace: u64) -> Json {
    if trace == 0 {
        return reply;
    }
    match reply {
        Json::Obj(mut m) => {
            m.insert(
                "trace".to_string(),
                Json::Str(format!("{trace:x}")),
            );
            Json::Obj(m)
        }
        other => other,
    }
}

/// Reply to a `Shutdown` request, sent before the drain begins.
pub fn shutdown_response(id: f64) -> Json {
    let mut fields = reply_head(id, "shutdown");
    fields.push(("draining", Json::Bool(true)));
    obj(fields)
}

/// A structured error reply; `id` when the request's id was readable.
pub fn error_response(id: Option<f64>, error: &str) -> Json {
    obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        (
            "id",
            match id {
                Some(i) => Json::Num(i),
                None => Json::Null,
            },
        ),
        ("ok", Json::Bool(false)),
        ("error", Json::Str(error.to_string())),
    ])
}

/// The admission-control shed reply (DESIGN.md §16): an ordinary
/// `ok: false` error — old clients parse and surface it untouched —
/// plus two additive fields new clients use to back off:
/// `"overloaded": true` (machine-checkable: *this* failure is
/// load, not a bad request) and a `retry_after_ms` hint.
pub fn overloaded_response(
    id: Option<f64>,
    why: &str,
    retry_after_ms: u64,
) -> Json {
    obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        (
            "id",
            match id {
                Some(i) => Json::Num(i),
                None => Json::Null,
            },
        ),
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::Str(format!("overloaded: {why} — retry with backoff")),
        ),
        ("overloaded", Json::Bool(true)),
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_point_and_infer() {
        let r = Request::parse(
            r#"{"v":1,"id":3,"type":"point","dataset":"fashion_syn",
                "k":14,"sigma":0.02,"phi":2,"eval":true}"#,
        )
        .unwrap();
        match r {
            Request::Point(p) => {
                assert_eq!(p.dataset, Dataset::FashionSyn);
                assert_eq!((p.k, p.phi), (14, 2));
                assert!(p.eval);
                assert_eq!(p.id, 3.0);
            }
            other => panic!("{other:?}"),
        }
        let px = Dataset::FashionSyn.spec().pixels();
        let row: Vec<String> =
            (0..px).map(|i| if i % 2 == 0 { "1" } else { "-1" }.into())
                .collect();
        let line = format!(
            r#"{{"v":1,"id":4,"type":"infer","dataset":"fashion_syn",
                "k":14,"seed":9,"x":[[{}]]}}"#,
            row.join(",")
        );
        match Request::parse(&line).unwrap() {
            Request::Infer(q) => {
                assert_eq!(q.n, 1);
                assert_eq!(q.x.len(), px);
                assert_eq!(q.seed, 9);
                assert_eq!(q.sigma, 0.0); // default
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn version_and_type_are_enforced() {
        let e = Request::parse(r#"{"id":1,"type":"stats"}"#).unwrap_err();
        assert_eq!(e.0, Some(1.0));
        assert!(e.1.contains("version"), "{}", e.1);
        let e = Request::parse(r#"{"v":2,"id":1,"type":"stats"}"#)
            .unwrap_err();
        assert!(e.1.contains("unsupported"), "{}", e.1);
        let e = Request::parse(r#"{"v":1,"id":1,"type":"frobnicate"}"#)
            .unwrap_err();
        assert!(e.1.contains("frobnicate"), "{}", e.1);
        let e = Request::parse("not json at all").unwrap_err();
        assert_eq!(e.0, None);
        assert!(e.1.contains("bad JSON"), "{}", e.1);
    }

    #[test]
    fn axis_validation_matches_the_cli_rules() {
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"point","dataset":"fashion_syn",
                "k":40}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("1..=32"), "{}", e.1);
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"point","dataset":"fashion_syn",
                "k":4,"phi":4}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("phi < k"), "{}", e.1);
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"point","dataset":"nope","k":4}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("unknown dataset"), "{}", e.1);
        // fractional axes are rejected, never truncated
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"point","dataset":"fashion_syn","k":14.7}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("integer"), "{}", e.1);
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"point","dataset":"fashion_syn","k":14,"phi":1.5}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("integer"), "{}", e.1);
    }

    #[test]
    fn infer_sample_shape_is_validated() {
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"infer","dataset":"fashion_syn",
                "k":14,"x":[[1,-1]]}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("per sample"), "{}", e.1);
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"infer","dataset":"fashion_syn",
                "k":14,"x":[]}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("at least one"), "{}", e.1);
    }

    #[test]
    fn peer_point_parses_like_point_but_is_marked() {
        let line = r#"{"v":1,"id":8,"type":"peer_point",
            "dataset":"fashion_syn","k":14,"sigma":0.02,"phi":2}"#;
        match Request::parse(line).unwrap() {
            Request::PeerPoint(p) => {
                assert_eq!(p.dataset, Dataset::FashionSyn);
                assert_eq!((p.k, p.phi), (14, 2));
                assert!(!p.eval);
            }
            other => panic!("{other:?}"),
        }
        // same validation rules as point
        let e = Request::parse(
            r#"{"v":1,"id":8,"type":"peer_point",
                "dataset":"fashion_syn","k":99}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("1..=32"), "{}", e.1);
    }

    #[test]
    fn stats_prom_flag_parses_and_defaults_off() {
        match Request::parse(r#"{"v":1,"id":1,"type":"stats"}"#).unwrap()
        {
            Request::Stats { prom, .. } => assert!(!prom),
            other => panic!("{other:?}"),
        }
        match Request::parse(
            r#"{"v":1,"id":1,"type":"stats","prom":true}"#,
        )
        .unwrap()
        {
            Request::Stats { prom, .. } => assert!(prom),
            other => panic!("{other:?}"),
        }
        let e = Request::parse(
            r#"{"v":1,"id":1,"type":"stats","prom":"yes"}"#,
        )
        .unwrap_err();
        assert!(e.1.contains("prom"), "{}", e.1);
    }

    #[test]
    fn with_trace_tags_replies_additively() {
        let j = with_trace(shutdown_response(1.0), 0xabc123);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.req("trace").as_str(), "abc123");
        assert!(back.req("ok").as_bool());
        // trace 0 (untraced) leaves the reply untouched
        let j = with_trace(shutdown_response(1.0), 0);
        assert!(Json::parse(&j.to_string())
            .unwrap()
            .get("trace")
            .is_none());
    }

    #[test]
    fn overloaded_reply_is_a_parsable_error_plus_markers() {
        let j = overloaded_response(Some(4.0), "queue full", 25);
        let back = Json::parse(&j.to_string()).unwrap();
        // an old client sees a plain structured error
        assert!(!back.req("ok").as_bool());
        assert!(back.req("error").as_str().contains("overloaded"));
        assert_eq!(back.req("id").as_f64(), 4.0);
        // a new client can detect shed-vs-bad-request and back off
        assert!(back.req("overloaded").as_bool());
        assert_eq!(back.req("retry_after_ms").as_f64(), 25.0);
    }

    #[test]
    fn responses_are_single_lines_with_echoed_ids() {
        let j = error_response(Some(7.0), "boom");
        let s = j.to_string();
        assert!(!s.contains('\n'));
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.req("id").as_f64(), 7.0);
        assert!(!back.req("ok").as_bool());
        assert_eq!(back.req("error").as_str(), "boom");
        let s = shutdown_response(9.0).to_string();
        let back = Json::parse(&s).unwrap();
        assert!(back.req("ok").as_bool());
        assert_eq!(back.req("type").as_str(), "shutdown");
    }
}
