//! The micro-batching queue (DESIGN.md §12): one thread owning the
//! serving [`NativeBackend`], coalescing concurrent `Infer` jobs into
//! a single [`NativeBackend::forward_many`] entry.
//!
//! Timing: the batcher blocks until a first job arrives, then keeps
//! collecting until it holds `max_batch` jobs or `max_wait` has
//! elapsed since the first one — the classic latency/throughput knob
//! pair (`--max-batch` / `--max-wait-ms`). Each job is executed
//! exactly as it would be alone (its own batch, seed and error
//! models), so replies are **bit-identical** to sequential execution —
//! coalescing only changes where the work runs, never what it
//! computes (`tests/serve.rs` pins this). With `max_batch = 1` the
//! batcher degenerates to a plain serial executor whose lone request
//! gets the whole kernel pool.
//!
//! Since the reactor rewrite (DESIGN.md §16) the batcher builds the
//! wire reply itself and pushes it into the job's [`ReplySink`] — the
//! reactor delivers it without any compute thread ever touching a
//! socket. Disconnected clients cost one discarded completion, never
//! a panic.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::native::{ForwardReq, NativeBackend};
use crate::bnn::ErrorModel;
use crate::coordinator::store::NamedTensor;

use super::metrics::Metrics;
use super::protocol;
use super::reactor::ReplySink;

/// One queued inference job: everything the forward needs, resolved
/// by the session thread before enqueueing, so the batcher itself
/// never blocks on solves or model folding.
pub struct InferJob {
    pub model: &'static str,
    pub n_classes: usize,
    pub folded: Arc<Vec<NamedTensor>>,
    pub ems: Arc<Vec<ErrorModel>>,
    pub seed: u32,
    /// Row-major samples, `batch * pixels` values.
    pub x: Vec<f32>,
    pub batch: usize,
    /// Request id echoed on the reply line.
    pub id: f64,
    /// Where the serialized reply goes (a reactor in production, a
    /// plain channel in tests).
    pub reply: ReplySink,
    /// Enqueue time, for the end-to-end latency histogram.
    pub t0: Instant,
    /// Request-scoped trace id (DESIGN.md §17); 0 in tests that don't
    /// exercise tracing.
    pub trace: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Most requests coalesced into one `forward_many` entry.
    pub max_batch: usize,
    /// Longest a ready job waits for company.
    pub max_wait: Duration,
}

/// The batcher thread body: runs until every job sender is dropped
/// (server drain), finishing all queued jobs first — shutdown never
/// abandons an accepted request.
pub fn run(
    rx: Receiver<InferJob>,
    backend: NativeBackend,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let max_batch = policy.max_batch.max(1);
    loop {
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // all senders gone and queue empty
        };
        let mut jobs = vec![first];
        let t_first = Instant::now();
        let deadline = t_first + policy.max_wait;
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // how long the first job waited for company before compute
        metrics
            .phase_batch_wait_us
            .record(t_first.elapsed().as_micros() as u64);
        execute(&backend, &metrics, jobs);
    }
}

/// Run one micro-batch and push each job's serialized reply into its
/// sink.
pub fn execute(
    backend: &NativeBackend,
    metrics: &Metrics,
    jobs: Vec<InferJob>,
) {
    // the batch span is homed on the first job's trace (a micro-batch
    // serves many traces but an event names one); forward and reply
    // spans parent under it so every member trace links into it
    let _batch_ctx = crate::obs::TraceCtx {
        trace_id: jobs.first().map(|j| j.trace).unwrap_or(0),
        span: 0,
    }
    .attach();
    let _batch_span = crate::span!("serve.batch");
    let batch_span = _batch_span.id();
    for j in &jobs {
        // queue wait (admission -> compute start), as the root span of
        // the job's own trace and in the phase histogram
        let _ctx = crate::obs::TraceCtx {
            trace_id: j.trace,
            span: 0,
        }
        .attach();
        crate::span_since!("serve.queue", j.t0);
        metrics
            .phase_queue_us
            .record(j.t0.elapsed().as_micros() as u64);
    }
    let reqs: Vec<ForwardReq<'_>> = jobs
        .iter()
        .map(|j| ForwardReq {
            model: j.model,
            folded: &j.folded,
            ems: &j.ems,
            seed: j.seed,
            x: &j.x,
            batch: j.batch,
            trace: j.trace,
        })
        .collect();
    let t_fwd = Instant::now();
    let outs = backend.forward_many(&reqs);
    metrics
        .phase_forward_us
        .record(t_fwd.elapsed().as_micros() as u64);
    metrics.record_batch(
        jobs.len(),
        jobs.iter().map(|j| j.batch).sum(),
    );
    for (job, out) in jobs.into_iter().zip(outs) {
        let _ctx = crate::obs::TraceCtx {
            trace_id: job.trace,
            span: batch_span,
        }
        .attach();
        let t_reply = Instant::now();
        let reply = match out {
            Ok(logits) => protocol::infer_response(
                job.id,
                &logits,
                job.batch,
                job.n_classes,
            ),
            Err(e) => {
                metrics.inc_error();
                crate::log_warn!(
                    "serve.batcher",
                    "infer id {} failed: {e}",
                    job.id
                );
                protocol::error_response(
                    Some(job.id),
                    &format!("infer failed: {e}"),
                )
            }
        };
        let reply = protocol::with_trace(reply, job.trace);
        metrics
            .infer_latency_us
            .record(job.t0.elapsed().as_micros() as u64);
        job.reply.send(&reply);
        crate::span_since!("serve.reply", t_reply);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::arch;
    use crate::backend::native::init_folded;
    use crate::util::json::Json;
    use std::sync::mpsc;

    fn mk_job(
        folded: &Arc<Vec<NamedTensor>>,
        ems: &Arc<Vec<ErrorModel>>,
        seed: u32,
        px: usize,
    ) -> (InferJob, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        let mut rng = crate::util::rng::Rng::new(seed as u64 + 77);
        let x: Vec<f32> = (0..px).map(|_| rng.pm1(0.5)).collect();
        (
            InferJob {
                model: "vgg3_tiny",
                n_classes: arch::model_meta("vgg3_tiny")
                    .unwrap()
                    .n_classes,
                folded: folded.clone(),
                ems: ems.clone(),
                seed,
                x,
                batch: 1,
                id: seed as f64,
                reply: ReplySink::to_channel(tx),
                t0: Instant::now(),
                trace: 0,
            },
            rx,
        )
    }

    #[test]
    fn batcher_coalesces_and_replies_bit_identically() {
        let meta = arch::model_meta("vgg3_tiny").unwrap();
        let folded = Arc::new(init_folded("vgg3_tiny").unwrap());
        let ems = Arc::new(
            (0..meta.n_matmuls())
                .map(|_| ErrorModel::identity())
                .collect::<Vec<_>>(),
        );
        let px: usize = meta.in_shape.iter().product();

        // reference: each job alone through a max_batch=1 executor
        let solo_backend = NativeBackend::new(2);
        let mut solo = vec![];
        for seed in 0..5u32 {
            let (job, rx) = mk_job(&folded, &ems, seed, px);
            execute(&solo_backend, &Metrics::new(), vec![job]);
            solo.push(rx.recv().unwrap());
        }

        // the same five jobs coalesced through a running batcher;
        // the serialized reply lines (ids, logits, argmaxes — all of
        // it) must be byte-identical to the solo runs
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy {
            max_batch: 5,
            max_wait: Duration::from_millis(2000),
        };
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run(rx, NativeBackend::new(2), policy, m2)
        });
        let replies: Vec<_> = (0..5u32)
            .map(|seed| {
                let (job, reply_rx) = mk_job(&folded, &ems, seed, px);
                tx.send(job).unwrap();
                reply_rx
            })
            .collect();
        for (seed, reply_rx) in replies.into_iter().enumerate() {
            let got = reply_rx.recv().unwrap();
            assert_eq!(
                got, solo[seed],
                "seed {seed} changed under micro-batching"
            );
            let back = Json::parse(&got).unwrap();
            assert!(back.req("ok").as_bool());
            assert_eq!(back.req("id").as_f64(), seed as f64);
        }
        drop(tx); // drain: batcher exits once the queue is empty
        h.join().unwrap();
        // all five landed in micro-batches; with a 2 s window at least
        // one batch held two or more
        assert!(metrics.max_batch() >= 2, "nothing coalesced");
    }

    #[test]
    fn batcher_drains_queued_jobs_on_disconnect() {
        let meta = arch::model_meta("vgg3_tiny").unwrap();
        let folded = Arc::new(init_folded("vgg3_tiny").unwrap());
        let ems = Arc::new(
            (0..meta.n_matmuls())
                .map(|_| ErrorModel::identity())
                .collect::<Vec<_>>(),
        );
        let px: usize = meta.in_shape.iter().product();
        let (tx, rx) = mpsc::channel();
        let mut reply_rxs = vec![];
        for seed in 0..4u32 {
            let (job, reply_rx) = mk_job(&folded, &ems, seed, px);
            tx.send(job).unwrap();
            reply_rxs.push(reply_rx);
        }
        // every sender is gone *before* the batcher starts: it must
        // still answer all queued jobs, then exit
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(5),
        };
        let h = std::thread::spawn(move || {
            run(rx, NativeBackend::new(1), policy, Arc::new(Metrics::new()))
        });
        for reply_rx in reply_rxs {
            let line = reply_rx.recv().unwrap();
            assert!(
                Json::parse(&line).unwrap().req("ok").as_bool()
            );
        }
        h.join().unwrap();
    }
}
