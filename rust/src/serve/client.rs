//! Blocking line-protocol client for `capmin serve` (DESIGN.md §12):
//! one request per call, replies matched by construction (the protocol
//! answers in order per connection). Shared by the loopback tests, the
//! loadgen bench and `examples/serve_client.rs` — and small enough to
//! be the reference for writing one in any other language.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::analog::cost::CostVector;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::protocol::PROTOCOL_VERSION;

/// Typed shed error: the server answered with an `overloaded: true`
/// reply (admission control, DESIGN.md §16) — the request was *not*
/// bad, the server was full. Detectable through an `anyhow` chain
/// with [`retriable`], carrying the server's `retry_after_ms` hint.
#[derive(Debug, Clone)]
pub struct Overloaded {
    pub retry_after_ms: u64,
    pub message: String,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} (retry_after_ms {})",
            self.message, self.retry_after_ms
        )
    }
}

impl std::error::Error for Overloaded {}

/// `true` when `err` is worth retrying with backoff: a shed
/// ([`Overloaded`]) or a transient connection-level IO failure.
/// Protocol errors (bad request, unknown dataset…) are not — retrying
/// them can only fail identically.
pub fn retriable(err: &anyhow::Error) -> bool {
    if err.downcast_ref::<Overloaded>().is_some() {
        return true;
    }
    err.chain().any(|cause| {
        cause
            .downcast_ref::<std::io::Error>()
            .map(|io| {
                matches!(
                    io.kind(),
                    std::io::ErrorKind::ConnectionRefused
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::BrokenPipe
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::UnexpectedEof
                )
            })
            .unwrap_or(false)
    })
}

/// `true` when `err` is a timed-out socket operation (a connect,
/// read or write that ran into [`Client::set_io_timeout`] /
/// [`Client::connect_within`] — `TimedOut` on connect, `WouldBlock`
/// on a timed-out read under Linux's `SO_RCVTIMEO`). Callers with a
/// local fallback use this to stop retrying: a second identical wait
/// against a wedged server only doubles the stall.
pub fn timed_out(err: &anyhow::Error) -> bool {
    err.chain().any(|cause| {
        cause
            .downcast_ref::<std::io::Error>()
            .map(|io| {
                matches!(
                    io.kind(),
                    std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::WouldBlock
                )
            })
            .unwrap_or(false)
    })
}

/// Bounded jittered exponential backoff, shared by every caller that
/// retries against a serve endpoint (tests, benches, examples, the
/// shard peer links). Delays double from `base_ms` up to `cap_ms`,
/// each jittered to `[delay/2, delay]` so a thousand shed clients do
/// not re-arrive in lockstep.
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    /// Total attempts (the first try included).
    pub attempts: u32,
    pub base_ms: u64,
    pub cap_ms: u64,
}

impl Default for Backoff {
    fn default() -> Backoff {
        Backoff {
            attempts: 6,
            base_ms: 20,
            cap_ms: 2000,
        }
    }
}

impl Backoff {
    /// Run `op` until it succeeds, the error stops being
    /// [`retriable`], or the attempts run out (returning the last
    /// error). `seed` decorrelates the jitter across callers.
    pub fn retry<T>(
        &self,
        seed: u64,
        mut op: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let attempts = self.attempts.max(1);
        let mut rng = Rng::new(seed ^ 0x6261_636b_6f66_66);
        let mut delay = self.base_ms.max(1);
        let mut last = None;
        for attempt in 0..attempts {
            match op() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if !retriable(&e) || attempt + 1 == attempts {
                        return Err(e);
                    }
                    // a shed reply's hint floors the wait: the server
                    // told us when it is worth coming back
                    let hint = e
                        .downcast_ref::<Overloaded>()
                        .map(|o| o.retry_after_ms)
                        .unwrap_or(0);
                    let d = delay.max(hint).min(self.cap_ms.max(1));
                    let jittered = d / 2 + rng.below(d / 2 + 1);
                    std::thread::sleep(Duration::from_millis(
                        jittered,
                    ));
                    delay = (delay * 2).min(self.cap_ms.max(1));
                    last = Some(e);
                }
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("retry exhausted")))
    }
}

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting {addr}"))?;
        Client::from_stream(stream)
    }

    /// [`Client::connect`] with a bound on the connect itself — for
    /// callers (the shard peer links) that must never block a serving
    /// thread on an unreachable host.
    pub fn connect_within(
        addr: SocketAddr,
        timeout: Duration,
    ) -> Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .with_context(|| format!("connecting {addr}"))?;
        Client::from_stream(stream)
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Bound every subsequent read/write on this connection (`None`
    /// blocks forever, the default). A timed-out call surfaces as a
    /// [`retriable`] IO error; the connection should be dropped, not
    /// reused, since a late reply would desynchronize the line
    /// protocol.
    pub fn set_io_timeout(
        &self,
        timeout: Option<Duration>,
    ) -> Result<()> {
        // reader and writer share one socket (try_clone dups the fd),
        // so setting the options once covers both directions
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Retry `connect` until `timeout` elapses — for drivers that
    /// race a just-spawned server (the CI smoke does).
    pub fn connect_retry(
        addr: SocketAddr,
        timeout: Duration,
    ) -> Result<Client> {
        let deadline = Instant::now() + timeout;
        loop {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) if Instant::now() >= deadline => {
                    return Err(e.context("server never came up"))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    /// [`Client::connect`] under a [`Backoff`] policy: retries
    /// connection-refused/reset with jittered exponential delays.
    pub fn connect_backoff(
        addr: SocketAddr,
        policy: Backoff,
    ) -> Result<Client> {
        policy.retry(addr.port() as u64, || Client::connect(addr))
    }

    fn fresh_id(&mut self) -> f64 {
        let id = self.next_id;
        self.next_id += 1;
        id as f64
    }

    /// Send one raw line and read one reply line (tests use this to
    /// probe malformed input; the reply may be an `ok: false` error).
    pub fn send_raw(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Json::parse(&reply)
            .map_err(|e| anyhow!("bad reply line: {e} in {reply:?}"))
    }

    /// Send a typed request object (v and id filled in), returning the
    /// reply after checking `ok` and the echoed id.
    fn request(
        &mut self,
        ty: &str,
        mut fields: Vec<(&str, Json)>,
    ) -> Result<Json> {
        let id = self.fresh_id();
        let mut all = vec![
            ("v", Json::Num(PROTOCOL_VERSION as f64)),
            ("id", Json::Num(id)),
            ("type", Json::Str(ty.to_string())),
        ];
        all.append(&mut fields);
        let reply = self.send_raw(&obj(all).to_string())?;
        match reply.get("ok") {
            Some(Json::Bool(true)) => {}
            _ => {
                let msg = reply
                    .get("error")
                    .map(|e| e.as_str().to_string())
                    .unwrap_or_else(|| reply.to_string());
                // a shed is a typed, retriable error — not a protocol
                // failure (DESIGN.md §16)
                if let Some(Json::Bool(true)) =
                    reply.get("overloaded")
                {
                    let retry_after_ms = reply
                        .get("retry_after_ms")
                        .map(|j| j.as_f64() as u64)
                        .unwrap_or(0);
                    return Err(anyhow::Error::new(Overloaded {
                        retry_after_ms,
                        message: msg,
                    }));
                }
                bail!("server error: {msg}");
            }
        }
        let echoed = reply
            .get("id")
            .map(|j| j.as_f64())
            .unwrap_or(f64::NAN);
        if echoed != id {
            bail!("reply id {echoed} does not match request id {id}");
        }
        Ok(reply)
    }

    /// Solve (or replay) an operating point.
    pub fn point(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        eval: bool,
    ) -> Result<Json> {
        self.request(
            "point",
            vec![
                ("dataset", Json::Str(dataset.to_string())),
                ("k", Json::Num(k as f64)),
                ("sigma", Json::Num(sigma)),
                ("phi", Json::Num(phi as f64)),
                ("eval", Json::Bool(eval)),
            ],
        )
    }

    /// The shard-to-shard twin of [`Client::point`]: `peer_point` is
    /// validated identically but ALWAYS solved locally by the
    /// receiving shard, never re-forwarded (DESIGN.md §16).
    pub fn peer_point(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        eval: bool,
    ) -> Result<Json> {
        self.request(
            "peer_point",
            vec![
                ("dataset", Json::Str(dataset.to_string())),
                ("k", Json::Num(k as f64)),
                ("sigma", Json::Num(sigma)),
                ("phi", Json::Num(phi as f64)),
                ("eval", Json::Bool(eval)),
            ],
        )
    }

    /// [`Client::point`], returning the reply plus its typed hardware
    /// cost vector (DESIGN.md §13) — the design-space explorer's
    /// client entry (see `examples/pareto_explore.rs`).
    pub fn point_cost(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        eval: bool,
    ) -> Result<(Json, CostVector)> {
        let reply = self.point(dataset, k, sigma, phi, eval)?;
        let cost_j = reply.get("cost").ok_or_else(|| {
            anyhow!(
                "reply has no `cost` field (server predates the \
                 cost vector?)"
            )
        })?;
        let cost = CostVector::from_json(cost_j)?;
        Ok((reply, cost))
    }

    /// Native inference on `samples` (each `pixels` +-1 values) at the
    /// operating point (k, sigma, phi); returns the full reply.
    #[allow(clippy::too_many_arguments)]
    pub fn infer(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        seed: u32,
        samples: &[Vec<f32>],
    ) -> Result<Json> {
        let rows = Json::Arr(
            samples
                .iter()
                .map(|row| {
                    Json::Arr(
                        row.iter()
                            .map(|&v| Json::Num(v as f64))
                            .collect(),
                    )
                })
                .collect(),
        );
        self.request(
            "infer",
            vec![
                ("dataset", Json::Str(dataset.to_string())),
                ("k", Json::Num(k as f64)),
                ("sigma", Json::Num(sigma)),
                ("phi", Json::Num(phi as f64)),
                ("seed", Json::Num(seed as f64)),
                ("x", rows),
            ],
        )
    }

    /// [`Client::infer`], unpacked into per-sample logits rows.
    #[allow(clippy::too_many_arguments)]
    pub fn infer_logits(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        seed: u32,
        samples: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        let reply =
            self.infer(dataset, k, sigma, phi, seed, samples)?;
        let rows = match reply.get("logits") {
            Some(Json::Arr(rows)) => rows,
            other => bail!("reply missing logits: {other:?}"),
        };
        Ok(rows
            .iter()
            .map(|row| {
                row.as_arr()
                    .iter()
                    .map(|v| v.as_f64() as f32)
                    .collect()
            })
            .collect())
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.request("stats", vec![])
    }

    /// [`Client::stats`] with `prom: true`: the reply additionally
    /// carries the registry's Prometheus text exposition under
    /// `"prom"` (DESIGN.md §17). Returns `(reply, prom_text)`.
    pub fn stats_prom(&mut self) -> Result<(Json, String)> {
        let reply =
            self.request("stats", vec![("prom", Json::Bool(true))])?;
        let text = match reply.get("prom") {
            Some(Json::Str(s)) => s.clone(),
            other => bail!("reply missing prom text: {other:?}"),
        };
        Ok((reply, text))
    }

    /// Ask the server to drain and exit; the reply confirms the drain
    /// started.
    pub fn shutdown(&mut self) -> Result<Json> {
        self.request("shutdown", vec![])
    }
}
