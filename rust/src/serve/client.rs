//! Blocking line-protocol client for `capmin serve` (DESIGN.md §12):
//! one request per call, replies matched by construction (the protocol
//! answers in order per connection). Shared by the loopback tests, the
//! loadgen bench and `examples/serve_client.rs` — and small enough to
//! be the reference for writing one in any other language.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::analog::cost::CostVector;
use crate::util::json::{obj, Json};

use super::protocol::PROTOCOL_VERSION;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Retry `connect` until `timeout` elapses — for drivers that
    /// race a just-spawned server (the CI smoke does).
    pub fn connect_retry(
        addr: SocketAddr,
        timeout: Duration,
    ) -> Result<Client> {
        let deadline = Instant::now() + timeout;
        loop {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) if Instant::now() >= deadline => {
                    return Err(e.context("server never came up"))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    fn fresh_id(&mut self) -> f64 {
        let id = self.next_id;
        self.next_id += 1;
        id as f64
    }

    /// Send one raw line and read one reply line (tests use this to
    /// probe malformed input; the reply may be an `ok: false` error).
    pub fn send_raw(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Json::parse(&reply)
            .map_err(|e| anyhow!("bad reply line: {e} in {reply:?}"))
    }

    /// Send a typed request object (v and id filled in), returning the
    /// reply after checking `ok` and the echoed id.
    fn request(
        &mut self,
        ty: &str,
        mut fields: Vec<(&str, Json)>,
    ) -> Result<Json> {
        let id = self.fresh_id();
        let mut all = vec![
            ("v", Json::Num(PROTOCOL_VERSION as f64)),
            ("id", Json::Num(id)),
            ("type", Json::Str(ty.to_string())),
        ];
        all.append(&mut fields);
        let reply = self.send_raw(&obj(all).to_string())?;
        match reply.get("ok") {
            Some(Json::Bool(true)) => {}
            _ => bail!(
                "server error: {}",
                reply
                    .get("error")
                    .map(|e| e.as_str().to_string())
                    .unwrap_or_else(|| reply.to_string())
            ),
        }
        let echoed = reply
            .get("id")
            .map(|j| j.as_f64())
            .unwrap_or(f64::NAN);
        if echoed != id {
            bail!("reply id {echoed} does not match request id {id}");
        }
        Ok(reply)
    }

    /// Solve (or replay) an operating point.
    pub fn point(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        eval: bool,
    ) -> Result<Json> {
        self.request(
            "point",
            vec![
                ("dataset", Json::Str(dataset.to_string())),
                ("k", Json::Num(k as f64)),
                ("sigma", Json::Num(sigma)),
                ("phi", Json::Num(phi as f64)),
                ("eval", Json::Bool(eval)),
            ],
        )
    }

    /// [`Client::point`], returning the reply plus its typed hardware
    /// cost vector (DESIGN.md §13) — the design-space explorer's
    /// client entry (see `examples/pareto_explore.rs`).
    pub fn point_cost(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        eval: bool,
    ) -> Result<(Json, CostVector)> {
        let reply = self.point(dataset, k, sigma, phi, eval)?;
        let cost_j = reply.get("cost").ok_or_else(|| {
            anyhow!(
                "reply has no `cost` field (server predates the \
                 cost vector?)"
            )
        })?;
        let cost = CostVector::from_json(cost_j)?;
        Ok((reply, cost))
    }

    /// Native inference on `samples` (each `pixels` +-1 values) at the
    /// operating point (k, sigma, phi); returns the full reply.
    #[allow(clippy::too_many_arguments)]
    pub fn infer(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        seed: u32,
        samples: &[Vec<f32>],
    ) -> Result<Json> {
        let rows = Json::Arr(
            samples
                .iter()
                .map(|row| {
                    Json::Arr(
                        row.iter()
                            .map(|&v| Json::Num(v as f64))
                            .collect(),
                    )
                })
                .collect(),
        );
        self.request(
            "infer",
            vec![
                ("dataset", Json::Str(dataset.to_string())),
                ("k", Json::Num(k as f64)),
                ("sigma", Json::Num(sigma)),
                ("phi", Json::Num(phi as f64)),
                ("seed", Json::Num(seed as f64)),
                ("x", rows),
            ],
        )
    }

    /// [`Client::infer`], unpacked into per-sample logits rows.
    #[allow(clippy::too_many_arguments)]
    pub fn infer_logits(
        &mut self,
        dataset: &str,
        k: usize,
        sigma: f64,
        phi: usize,
        seed: u32,
        samples: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        let reply =
            self.infer(dataset, k, sigma, phi, seed, samples)?;
        let rows = match reply.get("logits") {
            Some(Json::Arr(rows)) => rows,
            other => bail!("reply missing logits: {other:?}"),
        };
        Ok(rows
            .iter()
            .map(|row| {
                row.as_arr()
                    .iter()
                    .map(|v| v.as_f64() as f32)
                    .collect()
            })
            .collect())
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.request("stats", vec![])
    }

    /// Ask the server to drain and exit; the reply confirms the drain
    /// started.
    pub fn shutdown(&mut self) -> Result<Json> {
        self.request("shutdown", vec![])
    }
}
