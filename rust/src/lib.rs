//! CapMin / CapMin-V — HW/SW codesign for robust and efficient binarized
//! SNNs by capacitor minimization (CS.AR 2023 reproduction).
//!
//! Three-layer architecture (DESIGN.md §2):
//!  * L3 (this crate): the codesign framework — analog IF-SNN circuit
//!    substrate, CapMin/CapMin-V algorithms, data pipeline, experiment
//!    coordinator, PJRT runtime.
//!  * L2: JAX BNN graphs, AOT-lowered once to `artifacts/*.hlo.txt`.
//!  * L1: the Pallas sub-MAC kernel inside those graphs.
//!
//! Python never runs on the request path: the `capmin` binary drives
//! everything from Rust, through one of two interchangeable inference
//! backends (DESIGN.md §9) — the XLA-free [`backend::NativeBackend`]
//! (default on machines without the vendored bridge) or the PJRT
//! artifact path behind the `xla` cargo feature.
//!
//! The public entry point is [`session::DesignSession`] (DESIGN.md §3):
//! a typed, memoized operating-point service. Experiment drivers, the
//! CLI, benches and examples all issue
//! [`session::OperatingPointSpec`] queries against it; the training /
//! F_MAC stage graph behind it is crate-internal.
//!
//! Experiments themselves are declarative [`plan::ExperimentPlan`]s
//! (DESIGN.md §10): each declares its operating-point grid and a pure
//! reduction to a typed report; [`plan::planner::Planner`] dedupes
//! the grids across every selected plan, solves the union in one
//! `query_many` batch, and renders/emits/resumes through one
//! reporter (`capmin suite`).
//!
//! For long-running, multi-client use, [`serve`] (DESIGN.md §12)
//! keeps one warm session — point cache, folded models, packed
//! weights, scratch arenas — behind a newline-delimited-JSON TCP
//! protocol (`capmin serve`), micro-batching concurrent inference
//! requests with replies bit-identical to solo execution.
//!
//! Telemetry — tracing spans over per-thread ring buffers, the
//! cross-layer metrics registry, Chrome-trace export and leveled
//! logging — lives in [`obs`] (DESIGN.md §17) and is threaded through
//! every layer above; it is off by default and allocation-free on the
//! hot path.

pub mod analog;
pub mod backend;
pub mod bnn;
pub mod capmin;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod obs;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod util;
