//! `capmin` — L3 coordinator CLI over the `DesignSession` query service.
//!
//! Everything executes from Rust through one memoizing
//! [`DesignSession`] (DESIGN.md §3), on whichever inference backend
//! `--backend` resolves to (DESIGN.md §9): the XLA-free native sub-MAC
//! engine, or — on builds with the `xla` feature and `make artifacts`
//! run — the compiled PJRT artifacts.

use anyhow::Result;

use capmin::backend::InferenceBackend;
use capmin::coordinator::config::ExperimentConfig;
use capmin::experiments;
use capmin::plan;
use capmin::plan::planner::{Planner, SuiteOptions};
use capmin::plan::report::{Emit, EMIT_CHOICES};
use capmin::session::{DesignSession, OperatingPointSpec};
use capmin::util::cli::Args;
use capmin::util::table::si;

/// Every `--key value` option any command understands; anything else
/// errors with this list (util::cli::Args::reject_unknown).
const KNOWN_OPTS: &[&str] = &[
    "dataset", "steps", "lr", "lr-halve-every", "train-limit",
    "eval-limit", "hist-limit", "sigma", "mc-samples", "mc", "mc-tol",
    "seeds", "ks", "k", "phi", "engine", "backend", "threads", "kernel",
    "tile", "run-dir", "seed", "emit", "plans", "suite-id", "addr",
    "max-batch", "max-wait-ms", "reactors", "queue-cap",
    "idle-timeout-ms", "shards", "peers", "shard",
    "peer-timeout-ms", "trace", "log-level",
];

/// Every bare `--flag`. `trace` appears in both lists: bare it picks
/// the default export path, with a value it pins one.
const KNOWN_FLAGS: &[&str] = &[
    "help", "quick", "paper-scale", "no-point-cache", "no-eval",
    "no-resume", "trace", "prom",
];

const HELP: &str = "\
capmin — CapMin / CapMin-V reproduction (CS.AR 2023)

USAGE: capmin <command> [options]

Every command runs against one DesignSession: a typed, memoized
operating-point service. Queries (dataset, k, sigma, phi) resolve from
memory, then from the runs/points/ JSON cache, and only then recompute
(training, F_MAC extraction and Monte-Carlo maps are all cached in the
run directory, so figure commands compose without retraining).

experiment commands (paper artifacts; each is a declared plan —
DESIGN.md §10):
  table1          Table I  — datasets
  table2          Table II — BNN architectures
  fig1            F_MAC histograms per benchmark
  fig3            capacitor charging curves + quantized spike times
  fig5            CapMin window borders over the combined histogram
  fig6            variation vs decision intervals (r_i analysis)
  fig8            accuracy over k (CapMin / +variation / CapMin-V)
  fig9            capacitor size & latency comparison
  headline        summary of the paper's headline claims (shares the
                  fig8 grid — free under suite, cached standalone)
  ablation        design-choice ablations (window placement, merge rule)
  sigma-sweep     variation-tolerance curve (CapMin vs CapMin-V)
  pareto          design-space explorer (DESIGN.md §13): prices the
                  fig8 grid through the hardware cost model and emits
                  the CapMin-vs-CapMin-V accuracy/energy/area/latency
                  Pareto frontiers (shares fig8's solves under suite)
  suite           run every plan above as ONE deduplicated batch: specs
                  shared across figures solve once, progress streams
                  per plan, and a killed run resumes from
                  <run-dir>/suite/<id>/manifest.json
                  (--plans fig8,table2,...  --emit json,csv,md
                   --suite-id ID  --no-resume)
  all             alias for suite (kept for muscle memory)

session commands:
  point           answer one codesign query and print the operating
                  point (--k N --phi N --no-eval; sigma from --sigma);
                  the JSON lands in <run-dir>/points/<key>.json
  serve           long-running operating-point + inference server
                  (DESIGN.md §12): one warm DesignSession (point
                  cache, folded models, packed weights) behind a
                  newline-delimited JSON TCP protocol; concurrent
                  infer requests are micro-batched with replies
                  bit-identical to solo execution, and all worker
                  threads/pools are spawned once at startup
                  (--addr HOST:PORT  --max-batch N  --max-wait-ms N;
                   --dataset pre-warms; shut down with a {"type":
                   "shutdown"} request — in-flight work drains first)
  stats           query a running server's Stats endpoint and print
                  the reply (--addr HOST:PORT; --prom prints the
                  unified metrics registry as Prometheus text
                  exposition instead — DESIGN.md §17)
  trace-summary   aggregate an exported trace file into a per-phase
                  count/total/self table (--trace PATH, default: the
                  newest <run-dir>/trace/*.trace.json)
  train           train a model on a dataset (cached in runs/; needs
                  the xla build — native builds fall back to a flagged
                  untrained init)
  hist            extract F_MAC for a dataset
  verify          cross-check engine determinism + backend wiring
  info            backend / model registry / runtime info

common options:
  --dataset <name|all>     (fashion_syn kmnist_syn svhn_syn cifar_syn
                            imagenette_syn)
  --quick                  smoke-test scale (seconds)
  --paper-scale            full Table I splits (hours)
  --steps N --lr F --train-limit N --eval-limit N --hist-limit N
  --sigma F --mc-samples N --seeds N --ks 32,28,...
  --k N --phi N --no-eval  (point command)
  --mc paper|fast|analytic Monte-Carlo solve mode (DESIGN.md §15):
                           paper (default) draws --mc-samples i.i.d.
                           samples per level (Sec. IV-C); fast uses
                           stratified antithetic draws with per-level
                           early stopping — typically >=3x fewer draws
                           at equal map accuracy; analytic evaluates
                           the closed-form normal-CDF oracle with zero
                           draws. Modes agree statistically (TV
                           distance under tolerance), not bitwise, so
                           the mode is part of the point cache key;
                           the mode + draws actually used land in
                           point meta
  --mc-tol F               fast-mode stopping tolerance: target 95%
                           Wilson half-width per bucket probability
                           (default 0.01; smaller = more draws)
  --backend native|xla|auto  inference backend (DESIGN.md §9): native =
                           host sub-MAC engine, no XLA required; xla =
                           AOT artifacts via PJRT (needs the xla cargo
                           feature + make artifacts); auto (default)
                           picks xla when available, else native
  --threads N              worker threads for solves, Monte-Carlo and
                           native kernels (0 = all cores via
                           available_parallelism; results are
                           bit-identical at any setting; the resolved
                           count is recorded in point meta)
  --kernel scalar|auto     native sub-MAC microkernel tier (DESIGN.md
                           §11): auto (default) runtime-detects the
                           CPU (AVX-512 VPOPCNTQ, then AVX2+POPCNT on
                           x86_64, NEON on aarch64), scalar forces the
                           portable kernel; results are bit-identical
                           either way and the resolved tier lands in
                           point meta (explicit avx2/avx512/neon
                           accepted when the CPU has them)
  --tile auto|MRxNR        register-blocking tile of the exact matmul
                           microkernels (DESIGN.md §14): auto
                           (default) benchmarks candidate tiles once
                           per machine and caches the winner in
                           <run-dir>/autotune.json; an explicit
                           MRxNR[kKB] (e.g. 4x8 or 4x8k32) pins the
                           tile; scalar-safe is the escape hatch that
                           bypasses the blocked path entirely and runs
                           the per-word kernels. Results are
                           bit-identical for every choice; the
                           resolved tile lands in point meta, never in
                           cache keys
  --engine eval|evalp      jnp engine or Pallas-kernel engine artifact
                           (xla backend only)
  --run-dir DIR            cache directory (default runs/)
  --no-point-cache         keep operating points in memory only

telemetry options (DESIGN.md §17):
  --trace [PATH]           record structured spans (session solves,
                           MC maps, kernel forwards, serve phases)
                           into lock-free per-thread rings and export
                           them as Chrome/Perfetto trace JSON on
                           exit: bare picks the default path
                           <run-dir>/trace/<ts>.trace.json, a value
                           pins one; open the file in ui.perfetto.dev
                           or chrome://tracing, or aggregate it with
                           `capmin trace-summary`. Off by default:
                           disabled instrumentation costs one relaxed
                           atomic load per span (benches/obs.rs gates
                           this)
  --log-level LVL          error|warn|info|debug (default info); gates
                           the leveled stderr log lines the serve tier
                           emits (replacing its raw prints)

serve options:
  --addr HOST:PORT         bind address (default 127.0.0.1:7878;
                           port 0 picks a free port and prints it)
  --max-batch N            most infer requests coalesced into one
                           native forward entry (default 8; 1 = no
                           batching)
  --max-wait-ms N          longest a ready infer request waits for
                           company (default 2)
  --reactors N             event-loop threads owning the sockets
                           (default 2)
  --queue-cap N            bound on admitted-but-unanswered compute
                           requests; the excess sheds with structured
                           `overloaded` replies (default 256)
  --idle-timeout-ms N      close a connection stalled mid-request-line
                           this long; idle connections with no partial
                           line are never reaped (default 30000)
  --shards N               spawn an in-process consistent-hash ring of
                           N serving stacks: shard 0 on --addr, the
                           rest on ephemeral loopback ports
  --peers A:P,B:P,...      the full ordered shard ring, this server
                           included — every member must get the same
                           list; points owned by another shard are
                           fetched from it (peer_point) and fall back
                           to a local solve
  --shard I                this server's index into --peers
  --peer-timeout-ms N      bound on every peer-link socket operation;
                           a stalled owner costs at most this long
                           before the requester solves locally
                           (default 5000)

suite options:
  --plans a,b,c            subset of plans to run (default: all)
  --emit json,csv,md       extra artifact formats: under
                           <run-dir>/suite/<id>/ for `suite` (markdown
                           is always written there; `suite --emit
                           json` leaves <plan>.json next to
                           manifest.json), under <run-dir>/reports/
                           for single-figure commands
  --suite-id ID            pin the suite directory (default: hash of
                           plan set + config)
  --no-resume              ignore an existing manifest and re-run
                           every plan

Unknown or misspelled options/flags, and bad --emit/--dataset/--plans
values, are errors listing the valid set (a known option given to a
command that doesn't consume it is still accepted); the suite prints
aggregate session stats (hits, misses, hit rate) at exit so
cross-plan dedup is observable.

library use: see DESIGN.md §3 / examples/quickstart.rs —
`DesignSession::builder().config(cfg).build()?.query(&spec)?`.
";

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    if args.cmd == "help" || args.flag("help") {
        print!("{HELP}");
        return Ok(());
    }
    // typo'd or misplaced options error with the valid set up front,
    // instead of being silently ignored
    args.reject_unknown(KNOWN_OPTS, KNOWN_FLAGS)?;
    if let Some(l) =
        args.choice("log-level", &capmin::obs::LogLevel::CHOICES)?
    {
        capmin::obs::set_log_level(
            capmin::obs::LogLevel::parse(&l)
                .expect("validated choice"),
        );
    }
    // --trace turns span recording on for the whole command and
    // exports the rings on exit (DESIGN.md §17); for trace-summary
    // the same option names the *input* file instead
    let trace_out: Option<std::path::PathBuf> = if args.cmd
        != "trace-summary"
        && (args.flag("trace") || args.get("trace").is_some())
    {
        capmin::obs::set_tracing(true);
        Some(match args.get("trace") {
            Some(p) => std::path::PathBuf::from(p),
            None => capmin::obs::trace::default_trace_path(
                &args.str_or("run-dir", "runs"),
            ),
        })
    } else {
        None
    };
    // --emit is validated here even for commands that don't consume it
    let emit: Vec<Emit> = args
        .choice_list("emit", EMIT_CHOICES)?
        .iter()
        .map(|s| Emit::from_name(s).expect("validated choice"))
        .collect();
    let cfg = ExperimentConfig::from_args(&args)?;
    let session = DesignSession::builder().config(cfg).build()?;
    let datasets = experiments::selected_datasets(&args)?;

    match args.cmd.as_str() {
        "info" => {
            println!(
                "backend: {} (requested `{}`) | {} worker threads",
                session.backend_name(),
                session.config().backend,
                session.threads()
            );
            println!(
                "native kernel tier: {} (requested `{}`, detected {})",
                if session.kernel_name().is_empty() {
                    "-"
                } else {
                    session.kernel_name()
                },
                session.config().kernel,
                capmin::backend::kernels::KernelKind::detect().name()
            );
            let tile = session.tile_name();
            println!(
                "register-blocking tile: {} (requested `{}`; autotune \
                 cache {})",
                if tile.is_empty() { "-" } else { &tile },
                session.config().tile,
                session.store().path("autotune.json").display()
            );
            println!("native model registry:");
            for name in capmin::backend::arch::model_names() {
                let m = capmin::backend::arch::model_meta(name)?;
                println!(
                    "  {name}: {} | in {:?} | {} matmuls | {} binary \
                     weights",
                    m.describe(),
                    m.in_shape,
                    m.n_matmuls(),
                    m.n_weight_bits()
                );
            }
            #[cfg(feature = "xla")]
            if capmin::runtime::artifacts_dir()
                .join("manifest.json")
                .exists()
            {
                let rt = session.runtime()?;
                println!(
                    "platform: {} ({} devices)",
                    rt.client.platform_name(),
                    rt.client.device_count()
                );
                println!("artifacts: {}", rt.dir.display());
                for (name, m) in &rt.manifest.models {
                    println!(
                        "  {name}: {} | in {:?} | {} artifacts | {} \
                         params",
                        m.description,
                        m.in_shape,
                        m.artifacts.len(),
                        m.n_params
                    );
                }
            } else {
                println!(
                    "artifacts: none (native backend; `make artifacts` \
                     + the xla feature enable the PJRT path)"
                );
            }
            #[cfg(not(feature = "xla"))]
            println!(
                "built without the `xla` feature: PJRT runtime \
                 unavailable, native backend only"
            );
        }
        // every single-figure command is a registry plan: one batch,
        // markdown to stdout, --emit artifacts under
        // <run-dir>/reports/
        name if plan::PLAN_NAMES.contains(&name) => {
            let p = plan::build(name, &datasets)?;
            plan::planner::run_one(&session, p.as_ref(), &emit)?;
        }
        "suite" | "all" => {
            if args.cmd == "all" {
                println!(
                    "(`all` now runs the declarative suite engine — \
                     `capmin suite`, DESIGN.md §10)"
                );
            }
            let names: Vec<String> = match args.get("plans") {
                None => plan::PLAN_NAMES
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                Some(list) => list
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .collect(),
            };
            let mut planner = Planner::new(&session);
            for name in &names {
                planner.add(plan::build(name, &datasets)?);
            }
            let opts = SuiteOptions {
                emit,
                suite_id: args.get("suite-id").map(|s| s.to_string()),
                resume: !args.flag("no-resume"),
            };
            planner.run_suite(&opts)?;
        }
        "point" => {
            let cfg = session.config();
            let k = args.usize_or("k", 14);
            let phi = args.usize_or("phi", 0);
            anyhow::ensure!(
                (1..=32).contains(&k),
                "bad --k `{k}`: CapMin k must be in 1..=32"
            );
            anyhow::ensure!(
                phi < k,
                "bad --phi `{phi}`: CapMin-V merges must leave at least \
                 one spike time (phi < k)"
            );
            let (sigma, n_seeds) = (cfg.sigma_rel, cfg.n_seeds);
            for &ds in &datasets {
                let mut spec = OperatingPointSpec::new(ds, k, sigma, phi);
                if !args.flag("no-eval") {
                    spec = spec.with_eval(1, n_seeds);
                }
                let key = spec.cache_key(cfg);
                let point = session.query(&spec)?;
                let w = point.peak_window();
                println!(
                    "{}: k={k} sigma={sigma} phi={phi} -> C {} | GRT {} \
                     | peak window [{},{}] | accuracy {}",
                    ds.spec().name,
                    si(point.c, "F"),
                    si(point.grt, "s"),
                    w.q_lo,
                    w.q_hi,
                    point
                        .accuracy
                        .map(|a| format!("{:.1}%", 100.0 * a))
                        .unwrap_or_else(|| "-".into()),
                );
                // provenance (DESIGN.md §17): replays report the wall
                // time of the solve that minted the point, not 0
                println!(
                    "  timing: solve {:.1} ms | queue {:.2} ms",
                    point.meta.solve_ms, point.meta.queue_ms
                );
                if cfg.point_cache {
                    println!(
                        "  cached at {}",
                        session.store().path("points").join(
                            format!("{key}.json")
                        ).display()
                    );
                }
            }
            let s = session.stats();
            println!(
                "session stats: {} queries | {} memory hits | {} disk \
                 hits | {} solves | {} evals",
                s.queries, s.mem_hits, s.disk_hits, s.solves, s.evals
            );
        }
        "serve" => {
            anyhow::ensure!(
                session.backend_name() == "native",
                "capmin serve runs on the native backend (the PJRT \
                 client is single-process; drop --backend xla)"
            );
            let addr = args.addr("addr", "127.0.0.1:7878")?;
            let max_batch = args.usize_or("max-batch", 8);
            anyhow::ensure!(
                max_batch >= 1,
                "bad --max-batch `{max_batch}`: need at least 1"
            );
            let mut opts = capmin::serve::ServeOptions::new(addr);
            opts.max_batch = max_batch;
            opts.max_wait_ms =
                args.usize_or("max-wait-ms", 2) as u64;
            opts.reactors = args.usize_or("reactors", 2).max(1);
            opts.queue_cap = args.usize_or("queue-cap", 256).max(1);
            opts.idle_timeout_ms =
                args.usize_or("idle-timeout-ms", 30_000).max(1) as u64;
            opts.peer_timeout_ms =
                args.usize_or("peer-timeout-ms", 5_000).max(1) as u64;
            let shards = args.usize_or("shards", 1);
            if let Some(list) = args.get("peers") {
                anyhow::ensure!(
                    shards <= 1,
                    "--shards spawns an in-process ring; --peers \
                     joins an external one — pick one"
                );
                let peers: Vec<std::net::SocketAddr> = list
                    .split(',')
                    .map(|a| {
                        a.trim().parse().map_err(|e| {
                            anyhow::anyhow!(
                                "bad --peers entry `{a}`: {e}"
                            )
                        })
                    })
                    .collect::<Result<_>>()?;
                let shard = args.usize_or("shard", 0);
                anyhow::ensure!(
                    shard < peers.len(),
                    "--shard {shard} out of range for {} peers",
                    peers.len()
                );
                opts.peers = peers;
                opts.shard = shard;
            }
            // pre-warm only what was asked for; everything else warms
            // lazily on first request
            if args.get("dataset").is_some() {
                opts.warm = datasets.clone();
            }
            let cfg = session.config().clone();
            drop(session); // the server owns its own warm session
            capmin::log_info!(
                "serve",
                "capmin serve: binding {addr} (max-batch \
                 {max_batch}, max-wait {} ms, {} reactors, queue \
                 cap {}, native backend) — send \
                 {{\"v\":1,\"id\":1,\"type\":\"shutdown\"}} to \
                 drain and exit",
                opts.max_wait_ms,
                opts.reactors,
                opts.queue_cap
            );
            if shards > 1 {
                capmin::serve::server::run_sharded(
                    cfg, opts, shards,
                )?;
            } else {
                capmin::serve::server::run(cfg, opts)?;
            }
            capmin::log_info!(
                "serve",
                "capmin serve: drained and stopped"
            );
        }
        "stats" => {
            let addr = args.addr("addr", "127.0.0.1:7878")?;
            let mut c = capmin::serve::Client::connect(addr)?;
            if args.flag("prom") {
                let (_, text) = c.stats_prom()?;
                print!("{text}");
            } else {
                println!("{}", c.stats()?);
            }
        }
        "trace-summary" => {
            let path = match args.get("trace") {
                Some(p) => std::path::PathBuf::from(p),
                None => newest_trace(&session.config().run_dir)?,
            };
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!(
                    "reading trace {}: {e}",
                    path.display()
                ))?;
            let j = capmin::util::json::Json::parse(&text)?;
            let evs =
                capmin::obs::trace::parse_chrome_trace(&j)?;
            println!(
                "trace: {} ({} spans)",
                path.display(),
                evs.len()
            );
            let rows = capmin::obs::trace::summarize(&evs);
            print!(
                "{}",
                capmin::obs::trace::render_summary(&rows)
            );
        }
        "train" => {
            for ds in datasets {
                session.ensure_trained(ds)?;
            }
        }
        "hist" => {
            for ds in datasets {
                let (_, sum) = session.fmac(ds)?;
                println!(
                    "{}: {} sub-MACs, dynamic range {:.1e}",
                    ds.spec().name,
                    sum.total(),
                    sum.dynamic_range()
                );
            }
        }
        "verify" => verify(&session)?,
        other => {
            eprintln!("unknown command `{other}`\n\n{HELP}");
            std::process::exit(2);
        }
    }
    if let Some(path) = trace_out {
        capmin::obs::trace::write_trace(&path)?;
        println!("trace written to {}", path.display());
    }
    Ok(())
}

/// The newest `<run-dir>/trace/*.trace.json`, for a bare
/// `trace-summary` right after a `--trace` run.
fn newest_trace(run_dir: &str) -> Result<std::path::PathBuf> {
    let dir = std::path::Path::new(run_dir).join("trace");
    let mut best: Option<(std::time::SystemTime, std::path::PathBuf)> =
        None;
    for entry in std::fs::read_dir(&dir).map_err(|e| {
        anyhow::anyhow!(
            "no trace files under {} ({e}); run a command with \
             --trace first or pass --trace PATH",
            dir.display()
        )
    })? {
        let entry = entry?;
        let path = entry.path();
        if !path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.ends_with(".trace.json"))
            .unwrap_or(false)
        {
            continue;
        }
        let mtime = entry
            .metadata()?
            .modified()
            .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        if best.as_ref().map(|(t, _)| mtime > *t).unwrap_or(true) {
            best = Some((mtime, path));
        }
    }
    best.map(|(_, p)| p).ok_or_else(|| {
        anyhow::anyhow!(
            "no *.trace.json under {}; run a command with --trace \
             first or pass --trace PATH",
            dir.display()
        )
    })
}

/// Sanity pass over the full wiring on whatever backend the session
/// resolved: loads (or falls back for) the folded model, queries an
/// operating point, and checks both the bit-packed engine and the
/// backend's whole-model logits are deterministic. The bit-exact
/// cross-backend comparisons live in tests/backend.rs (and
/// tests/integration.rs for the artifact path).
fn verify(session: &DesignSession) -> Result<()> {
    use capmin::bnn::{BitMatrix, SubMacEngine};

    let ds = capmin::data::synth::Dataset::FashionSyn;
    let spec = ds.spec();
    println!(
        "verify: {} via {} backend",
        spec.model,
        session.backend_name()
    );

    let folded = session.folded(ds)?;
    anyhow::ensure!(folded[0].name == "wb0");
    let (o, kp) = (folded[0].shape[0], folded[0].shape[1]);
    let wb = &folded[0].data;
    let beta = 9; // first conv of a 1-channel 3x3 model
    let d = 37;
    let mut rng = capmin::util::rng::Rng::new(99);
    let x_rows: Vec<f32> = (0..d * kp).map(|_| rng.pm1(0.5)).collect();

    let point =
        session.query(&OperatingPointSpec::new(ds, 14, 0.03, 0))?;
    let em = point.ems[0].clone();

    let eng = SubMacEngine::new(o, kp, wb, beta);
    let xb = BitMatrix::pack(d, kp, &x_rows, false);
    let a = eng.matmul_error(&xb, &em, 7, 0);
    let b = eng.matmul_error(&xb, &em, 7, 0);
    anyhow::ensure!(a == b, "engine must be deterministic");
    println!(
        "engine OK: {} outputs, range [{}, {}]",
        a.len(),
        a.iter().cloned().fold(f32::INFINITY, f32::min),
        a.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    );

    // whole-model logits through the session's backend, twice (the
    // xla eval artifact is compiled for the model's eval batch)
    let be = session.backend()?;
    let px: usize = spec.pixels();
    let batch = capmin::backend::arch::model_meta(spec.model)?.eval_batch;
    let x: Vec<f32> = (0..batch * px).map(|_| rng.pm1(0.5)).collect();
    let la = be.logits(spec.model, &folded, &x, batch, &point.ems, 7)?;
    let lb = be.logits(spec.model, &folded, &x, batch, &point.ems, 7)?;
    anyhow::ensure!(la == lb, "backend logits must be deterministic");
    anyhow::ensure!(la.iter().all(|v| v.is_finite()));
    println!(
        "backend OK: {} logits over a batch of {batch} ({} backend, {} \
         threads)",
        la.len(),
        be.name(),
        session.threads()
    );
    println!("(bit-exact cross-backend checks: cargo test)");
    Ok(())
}
