//! `capmin` — L3 coordinator CLI.
//!
//! Python ran once (`make artifacts`); everything below executes from
//! Rust against the compiled PJRT artifacts.

use anyhow::Result;

use capmin::coordinator::config::ExperimentConfig;
use capmin::coordinator::pipeline::Pipeline;
use capmin::experiments;
use capmin::runtime::Runtime;
use capmin::util::cli::Args;

const HELP: &str = "\
capmin — CapMin / CapMin-V reproduction (CS.AR 2023)

USAGE: capmin <command> [options]

experiment commands (paper artifacts):
  table1          Table I  — datasets
  table2          Table II — BNN architectures
  fig1            F_MAC histograms per benchmark
  fig3            capacitor charging curves + quantized spike times
  fig5            CapMin window borders over the combined histogram
  fig6            variation vs decision intervals (r_i analysis)
  fig8            accuracy over k (CapMin / +variation / CapMin-V)
  fig9            capacitor size & latency comparison
  headline        summary of the paper's headline claims
  ablation        design-choice ablations (window placement, merge rule)
  sigma-sweep     variation-tolerance curve (CapMin vs CapMin-V)
  all             tables + all figures in order

pipeline commands:
  train           train a model on a dataset (cached in runs/)
  hist            extract F_MAC for a dataset
  verify          cross-check rust engine determinism + artifact wiring
  info            manifest / runtime info

common options:
  --dataset <name|all>     (fashion_syn kmnist_syn svhn_syn cifar_syn
                            imagenette_syn)
  --quick                  smoke-test scale (seconds)
  --paper-scale            full Table I splits (hours)
  --steps N --lr F --train-limit N --eval-limit N --hist-limit N
  --sigma F --mc-samples N --seeds N --ks 32,28,...
  --engine eval|evalp      jnp engine or Pallas-kernel engine artifact
  --run-dir DIR            cache directory (default runs/)
";

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    if args.cmd == "help" || args.flag("help") {
        print!("{HELP}");
        return Ok(());
    }
    let cfg = ExperimentConfig::from_args(&args);
    let rt = Runtime::new()?;
    let pipe = Pipeline::new(&rt, cfg)?;
    let datasets = experiments::selected_datasets(&args);

    match args.cmd.as_str() {
        "info" => {
            println!(
                "platform: {} ({} devices)",
                rt.client.platform_name(),
                rt.client.device_count()
            );
            println!("artifacts: {}", rt.dir.display());
            for (name, m) in &rt.manifest.models {
                println!(
                    "  {name}: {} | in {:?} | {} artifacts | {} params",
                    m.description,
                    m.in_shape,
                    m.artifacts.len(),
                    m.n_params
                );
            }
        }
        "table1" => experiments::tables::table1(&pipe)?,
        "table2" => experiments::tables::table2(&pipe)?,
        "fig1" => experiments::fig1::run(&pipe, &datasets)?,
        "fig3" => experiments::fig3::run(&pipe)?,
        "fig5" => experiments::fig5::run(&pipe, &datasets)?,
        "fig6" => experiments::fig6::run(&pipe)?,
        "fig8" => experiments::fig8::run(&pipe, &datasets)?,
        "fig9" => experiments::fig9::run(&pipe, &datasets)?,
        "headline" => experiments::headline::run(&pipe, &datasets)?,
        "all" => {
            experiments::tables::table1(&pipe)?;
            experiments::tables::table2(&pipe)?;
            experiments::fig1::run(&pipe, &datasets)?;
            experiments::fig3::run(&pipe)?;
            experiments::fig5::run(&pipe, &datasets)?;
            experiments::fig6::run(&pipe)?;
            experiments::fig8::run(&pipe, &datasets)?;
            experiments::fig9::run(&pipe, &datasets)?;
            experiments::headline::run(&pipe, &datasets)?;
        }
        "train" => {
            for ds in datasets {
                pipe.ensure_folded(ds)?;
            }
        }
        "hist" => {
            for ds in datasets {
                let (_, sum) = pipe.ensure_fmac(ds)?;
                println!(
                    "{}: {} sub-MACs, dynamic range {:.1e}",
                    ds.spec().name,
                    sum.total(),
                    sum.dynamic_range()
                );
            }
        }
        "ablation" => experiments::ablation::run(&pipe, &datasets)?,
        "sigma-sweep" => experiments::sigma_sweep::run(&pipe, &datasets)?,
        "verify" => verify(&pipe)?,
        other => {
            eprintln!("unknown command `{other}`\n\n{HELP}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Sanity pass over the full pipeline wiring: trains (or loads) the tiny
/// model's dataset, folds, builds an error model and checks the Rust
/// bit-packed engine is deterministic on the folded weights. The
/// bit-exact rust-vs-artifact comparison lives in tests/integration.rs.
fn verify(pipe: &Pipeline) -> Result<()> {
    use capmin::bnn::{BitMatrix, SubMacEngine};
    use capmin::runtime::to_f32;

    let rt = pipe.rt;
    let ds = capmin::data::synth::Dataset::FashionSyn;
    let model = rt.manifest.datasets["fashion_syn"].model.clone();
    let mi = rt.manifest.model(&model);
    println!("verify: {} via {} artifact", model, pipe.cfg.engine);

    let folded = pipe.ensure_folded(ds)?;
    let sig = &mi.artifacts["export"].outputs[0];
    anyhow::ensure!(sig.name == "wb0");
    let wb = to_f32(&folded[0])?;
    let (o, kp) = (sig.shape[0], sig.shape[1]);
    let beta = 9; // first conv of a 1-channel 3x3 model
    let d = 37;
    let mut rng = capmin::util::rng::Rng::new(99);
    let x_rows: Vec<f32> = (0..d * kp).map(|_| rng.pm1(0.5)).collect();

    let (per_fmac, _) = pipe.ensure_fmac(ds)?;
    let hw = pipe.hw_config(&per_fmac, 14, 0.03, 0);
    let em = hw.ems[0].clone();

    let eng = SubMacEngine::new(o, kp, &wb, beta);
    let xb = BitMatrix::pack(d, kp, &x_rows, false);
    let a = eng.matmul_error(&xb, &em, 7, 0);
    let b = eng.matmul_error(&xb, &em, 7, 0);
    anyhow::ensure!(a == b, "engine must be deterministic");
    println!(
        "verify OK: {} outputs, range [{}, {}]",
        a.len(),
        a.iter().cloned().fold(f32::INFINITY, f32::min),
        a.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    );
    println!("(bit-exact rust-vs-artifact check: cargo test)");
    Ok(())
}
