//! xoshiro256++ PRNG + distributions (no `rand` crate offline).
//!
//! Used by the data generators, the Monte-Carlo variation engine, and the
//! property-test driver. Deterministic from its seed; `split` derives
//! decorrelated child streams (SplitMix64 over the child index).

/// Standard normal CDF `Phi(x)` via the Abramowitz-Stegun 7.1.26
/// rational erf approximation (absolute error < 1.5e-7 — far below
/// every Monte-Carlo tolerance in this crate). Used by the analytic
/// P_map oracle (`analog::montecarlo`).
pub fn normal_cdf(x: f64) -> f64 {
    // Phi(x) = (1 + erf(x / sqrt(2))) / 2, erf odd
    let z = x / std::f64::consts::SQRT_2;
    let a = z.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * a);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741
                    + t * (-1.453_152_027 + t * 1.061_405_429))));
    let erf_abs = 1.0 - poly * (-a * a).exp();
    let erf = if z < 0.0 { -erf_abs } else { erf_abs };
    0.5 * (1.0 + erf)
}

/// Inverse standard normal CDF `Phi^-1(p)` (Acklam's rational
/// approximation, relative error < 1.15e-9). `p = 0` and `p = 1` map
/// to -inf / +inf; the stratified sampler feeds strictly interior
/// quantiles.
pub fn normal_inv_cdf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_690e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    const P_LOW: f64 = 0.024_25;
    if p <= 0.0 {
        return f64::NEG_INFINITY;
    }
    if p >= 1.0 {
        return f64::INFINITY;
    }
    if p < P_LOW {
        // lower tail
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q
            + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p > 1.0 - P_LOW {
        // upper tail: symmetry
        -normal_inv_cdf(1.0 - p)
    } else {
        // central region
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r
            + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4])
                * r
                + 1.0)
    }
}

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Decorrelated child stream `i` of this generator's seed state.
    pub fn split(&self, i: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ i.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift; bias negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller (polar form, both values used).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// N(mu, sigma).
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// +-1 with p(+1) = p.
    pub fn pm1(&mut self, p: f64) -> f32 {
        if self.f64() < p {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.f64();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn split_decorrelates() {
        let base = Rng::new(3);
        let mut a = base.split(1);
        let mut b = base.split(2);
        let n = 50_000;
        let mut dot = 0.0;
        for _ in 0..n {
            dot += (a.f64() - 0.5) * (b.f64() - 0.5);
        }
        assert!((dot / n as f64).abs() < 1e-3);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_cdf_matches_known_values() {
        // table values of Phi at 0, ±1, ±2, 1.96
        let cases = [
            (0.0, 0.5),
            (1.0, 0.841_344_746),
            (-1.0, 0.158_655_254),
            (2.0, 0.977_249_868),
            (-2.0, 0.022_750_132),
            (1.959_964, 0.975),
        ];
        for (x, want) in cases {
            let got = normal_cdf(x);
            assert!((got - want).abs() < 2e-7, "Phi({x}) = {got}");
        }
        assert_eq!(normal_cdf(f64::NEG_INFINITY), 0.0);
        assert_eq!(normal_cdf(f64::INFINITY), 1.0);
    }

    #[test]
    fn inv_cdf_roundtrips_through_cdf() {
        for i in 1..200 {
            let p = i as f64 / 200.0;
            let z = normal_inv_cdf(p);
            let back = normal_cdf(z);
            // limited by the cdf approximation, not Acklam
            assert!((back - p).abs() < 5e-7, "p={p} z={z} back={back}");
        }
        assert!(normal_inv_cdf(0.0).is_infinite());
        assert!(normal_inv_cdf(1.0).is_infinite());
        assert!((normal_inv_cdf(0.5)).abs() < 1e-12);
        // antithetic symmetry the stratified sampler relies on
        for p in [0.01, 0.1, 0.3, 0.45] {
            let a = normal_inv_cdf(p);
            let b = normal_inv_cdf(1.0 - p);
            assert!((a + b).abs() < 1e-9, "p={p}: {a} vs {b}");
        }
    }

    #[test]
    fn inv_cdf_is_monotone() {
        let mut prev = f64::NEG_INFINITY;
        for i in 1..1000 {
            let z = normal_inv_cdf(i as f64 / 1000.0);
            assert!(z > prev, "not monotone at {i}");
            prev = z;
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
