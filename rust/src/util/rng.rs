//! xoshiro256++ PRNG + distributions (no `rand` crate offline).
//!
//! Used by the data generators, the Monte-Carlo variation engine, and the
//! property-test driver. Deterministic from its seed; `split` derives
//! decorrelated child streams (SplitMix64 over the child index).

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Decorrelated child stream `i` of this generator's seed state.
    pub fn split(&self, i: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ i.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift; bias negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller (polar form, both values used).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// N(mu, sigma).
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// +-1 with p(+1) = p.
    pub fn pm1(&mut self, p: f64) -> f32 {
        if self.f64() < p {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.f64();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn split_decorrelates() {
        let base = Rng::new(3);
        let mut a = base.split(1);
        let mut b = base.split(2);
        let n = 50_000;
        let mut dot = 0.0;
        for _ in 0..n {
            dot += (a.f64() - 0.5) * (b.f64() - 0.5);
        }
        assert!((dot / n as f64).abs() < 1e-3);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
