//! Tiny content-addressing hash (FNV-1a; no std `Hasher` because its
//! output is not guaranteed stable across Rust versions, and these
//! hashes name files on disk — DESIGN.md §7/§10).
//!
//! Shared by the session's spec cache keys and the plan engine's suite
//! manifests, so a spec hashes identically whichever layer asks.

/// 64-bit FNV-1a over raw bytes.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The canonical 16-hex-digit rendering used for cache keys and
/// manifest spec hashes.
pub fn hex16(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_known_vector() {
        // FNV-1a test vector: empty input is the offset basis
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        // and the rendering is fixed-width lowercase hex
        assert_eq!(hex16(b"").len(), 16);
        assert_eq!(hex16(b"a"), hex16(b"a"));
        assert_ne!(hex16(b"a"), hex16(b"b"));
    }
}
