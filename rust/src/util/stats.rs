//! Scalar statistics helpers used by benches and the evaluator.
//!
//! The concurrent power-of-two histogram that used to live beside the
//! serve metrics is now `obs::registry::Hist` (DESIGN.md §17);
//! re-exported here for callers that think of it as a stats
//! primitive.

pub use crate::obs::registry::Hist;

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// p-quantile (nearest-rank) of a sorted copy.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Argmax over f32 logits; first index wins ties (matches jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
