//! Minimal JSON parser + writer (no serde offline; DESIGN.md §8).
//!
//! Parses the AOT `artifacts/manifest.json` and writes experiment result
//! files. Supports the full JSON value grammar minus exotic number forms;
//! strings handle the escapes Python's `json.dump` emits.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields (a malformed
    /// manifest is a build error, not a runtime condition).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}`"))
    }

    pub fn as_str(&self) -> &str {
        match self {
            Json::Str(s) => s,
            _ => panic!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> f64 {
        match self {
            Json::Num(n) => *n,
            Json::Null => f64::NAN, // writer emits null for NaN series
            _ => panic!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> usize {
        self.as_f64() as usize
    }

    pub fn as_bool(&self) -> bool {
        match self {
            Json::Bool(b) => *b,
            _ => panic!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> &[Json] {
        match self {
            Json::Arr(a) => a,
            _ => panic!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> &BTreeMap<String, Json> {
        match self {
            Json::Obj(m) => m,
            _ => panic!("not an object: {self:?}"),
        }
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/inf; emit null (readers map to NaN)
                    out.push_str("null");
                } else {
                    out.push_str(&fmt_num(*n));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Canonical finite-number rendering shared by the JSON writer and
/// the plan reporter's CSV cells: integral values print without a
/// fraction so the two artifact formats always agree.
pub fn fmt_num(n: f64) -> String {
    let mut out = String::new();
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
    out
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected `{}` at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| e.to_string())?;
                            // BMP only; surrogate pairs unused by our writer
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E'
                || c == b'+' || c == b'-'
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .unwrap()
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at {start}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3, "x\ny"], "c": {}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").as_f64(), 1.0);
        assert_eq!(v.req("b").as_arr().len(), 4);
        assert_eq!(v.req("b").as_arr()[3].as_str(), "x\ny");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn nan_roundtrips_as_null() {
        let v = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NAN)]);
        let s = v.to_string();
        assert_eq!(s, "[1,null]");
        let re = Json::parse(&s).unwrap();
        assert!(re.as_arr()[1].as_f64().is_nan());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), "é");
    }

    #[test]
    fn parses_nested_manifest_shape() {
        let src = r#"{"models": {"m": {"artifacts": [{"kind": "init",
            "inputs": [{"name": "key", "dtype": "u32", "shape": [2]}]}]}}}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.req("models").req("m").req("artifacts").as_arr()[0];
        assert_eq!(a.req("kind").as_str(), "init");
        assert_eq!(a.req("inputs").as_arr()[0].req("shape").as_arr()[0]
            .as_usize(), 2);
    }
}
