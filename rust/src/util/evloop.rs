//! Raw-syscall event-loop primitives (no mio/tokio offline —
//! DESIGN.md §8): a level-triggered readiness [`Poller`] over
//! `epoll(7)` on Linux and `kqueue(2)` on macOS, plus a [`Waker`] for
//! cross-thread wakeups (an `eventfd` under epoll, an `EVFILT_USER`
//! event under kqueue — no self-pipe, no spare fds).
//!
//! The syscalls are declared here as plain `extern "C"` bindings into
//! the libc every Rust binary already links — the crate's
//! zero-dependency rule holds (DESIGN.md §8). Only what the serve
//! reactor needs is wrapped: register/modify/deregister an fd with a
//! `u64` token, wait with a timeout, and wake. Readiness is
//! **level-triggered** everywhere: a socket with unread bytes (or
//! writable space) keeps reporting until the caller drains it, so a
//! reactor that stops mid-buffer is re-told, not deadlocked.
//!
//! Nothing in this module knows about connections or protocols; the
//! serve reactor (DESIGN.md §16) and the open-loop loadgen in
//! `benches/serve.rs` both build on exactly this surface.

use std::io;
use std::os::fd::RawFd;
use std::time::Duration;

/// Which readiness a registration asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const WRITE: Interest = Interest {
        readable: false,
        writable: true,
    };
    pub const BOTH: Interest = Interest {
        readable: true,
        writable: true,
    };
}

/// One readiness report: the registration's token plus what fired.
/// `hangup` folds in peer-close/error conditions — the owner should
/// read (to observe EOF/errno) and drop the connection.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

#[cfg(any(target_os = "linux", target_os = "android"))]
mod sys {
    use super::{Event, Interest};
    use std::io;
    use std::os::fd::RawFd;
    use std::os::raw::{c_int, c_void};
    use std::time::Duration;

    // x86 kernels lay epoll_event out packed; everything else pads.
    #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "x86")))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut EpollEvent,
        ) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn eventfd(initval: u32, flags: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        fn close(fd: c_int) -> c_int;
    }

    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EFD_NONBLOCK: c_int = 0o4000;
    const EFD_CLOEXEC: c_int = 0o2000000;

    fn cvt(r: c_int) -> io::Result<c_int> {
        if r < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(r)
        }
    }

    fn mask(interest: Interest) -> u32 {
        // RDHUP rides with read interest only: a write-only
        // registration is exactly what a reactor uses for a
        // half-closed connection still owed replies, and reporting
        // the (permanent, level-triggered) RDHUP there would busy-
        // wake the loop until the last reply flushed
        let mut m = 0;
        if interest.readable {
            m |= EPOLLIN | EPOLLRDHUP;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    pub struct Poller {
        epfd: RawFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd =
                cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Poller { epfd })
        }

        fn ctl(
            &self,
            op: c_int,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: mask(interest),
                data: token,
            };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            // the event argument is ignored for DEL on any kernel
            // this crate supports (>= 2.6.9)
            let mut ev = EpollEvent { events: 0, data: 0 };
            cvt(unsafe {
                epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev)
            })?;
            Ok(())
        }

        /// Block until readiness or `timeout` (None = forever),
        /// replacing `out` with the fired events.
        pub fn wait(
            &self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; 256];
            let ms: c_int = match timeout {
                None => -1,
                // round up so a 1 ns ask never busy-spins at 0
                Some(t) => t
                    .as_millis()
                    .max(if t.is_zero() { 0 } else { 1 })
                    .min(i32::MAX as u128)
                    as c_int,
            };
            let n = loop {
                let r = unsafe {
                    epoll_wait(
                        self.epfd,
                        buf.as_mut_ptr(),
                        buf.len() as c_int,
                        ms,
                    )
                };
                if r >= 0 {
                    break r as usize;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            };
            for ev in &buf[..n] {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP)
                        != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { close(self.epfd) };
        }
    }

    /// Cross-thread wakeup: an `eventfd` registered read-interest in
    /// the poller under the caller's token.
    pub struct Waker {
        efd: RawFd,
    }

    impl Waker {
        pub fn new(poller: &Poller, token: u64) -> io::Result<Waker> {
            let efd = cvt(unsafe {
                eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC)
            })?;
            poller.register(efd, token, Interest::READ)?;
            Ok(Waker { efd })
        }

        pub fn wake(&self) {
            let one: u64 = 1;
            // EAGAIN means the counter is already non-zero — the
            // sleeper is waking anyway
            unsafe {
                write(
                    self.efd,
                    &one as *const u64 as *const c_void,
                    8,
                )
            };
        }

        /// Reset after a wake-token event so level-triggered polling
        /// goes back to sleep.
        pub fn drain(&self) {
            let mut buf = [0u8; 8];
            unsafe {
                read(self.efd, buf.as_mut_ptr() as *mut c_void, 8)
            };
        }
    }

    impl Drop for Waker {
        fn drop(&mut self) {
            unsafe { close(self.efd) };
        }
    }

    // rlimit for the fd-hungry paths (1k-connection loadgen)
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: c_int, rlim: *mut Rlimit) -> c_int;
        fn setrlimit(resource: c_int, rlim: *const Rlimit) -> c_int;
    }
    const RLIMIT_NOFILE: c_int = 7;

    /// Best-effort: raise the soft fd limit toward `want` (capped at
    /// the hard limit); returns the resulting soft limit.
    pub fn raise_nofile_limit(want: u64) -> u64 {
        let mut lim = Rlimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 0;
        }
        if lim.cur < want {
            let new = Rlimit {
                cur: want.min(lim.max),
                max: lim.max,
            };
            if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
                return new.cur;
            }
        }
        lim.cur
    }
}

#[cfg(target_os = "macos")]
mod sys {
    use super::{Event, Interest};
    use std::io;
    use std::os::fd::RawFd;
    use std::os::raw::{c_int, c_void};
    use std::time::Duration;

    #[repr(C)]
    struct Kevent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: *mut c_void,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: isize,
        tv_nsec: isize,
    }

    extern "C" {
        fn kqueue() -> c_int;
        fn kevent(
            kq: c_int,
            changelist: *const Kevent,
            nchanges: c_int,
            eventlist: *mut Kevent,
            nevents: c_int,
            timeout: *const Timespec,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EVFILT_USER: i16 = -10;
    const EV_ADD: u16 = 0x1;
    const EV_DELETE: u16 = 0x2;
    const EV_CLEAR: u16 = 0x20;
    const EV_ERROR: u16 = 0x4000;
    const EV_EOF: u16 = 0x8000;
    const NOTE_TRIGGER: u32 = 0x0100_0000;

    fn kev(
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        token: u64,
    ) -> Kevent {
        Kevent {
            ident,
            filter,
            flags,
            fflags,
            data: 0,
            udata: token as usize as *mut c_void,
        }
    }

    pub struct Poller {
        kq: RawFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { kq })
        }

        fn apply(&self, changes: &[Kevent]) -> io::Result<()> {
            let r = unsafe {
                kevent(
                    self.kq,
                    changes.as_ptr(),
                    changes.len() as c_int,
                    std::ptr::null_mut(),
                    0,
                    std::ptr::null(),
                )
            };
            if r < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        fn set(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            // add what is wanted; delete what is not (ENOENT from a
            // delete of an absent filter is fine and not reported by
            // kevent unless EV_RECEIPT is used)
            let mut changes = vec![];
            let f = fd as usize;
            if interest.readable {
                changes.push(kev(f, EVFILT_READ, EV_ADD, 0, token));
            } else {
                changes.push(kev(f, EVFILT_READ, EV_DELETE, 0, token));
            }
            if interest.writable {
                changes.push(kev(f, EVFILT_WRITE, EV_ADD, 0, token));
            } else {
                changes
                    .push(kev(f, EVFILT_WRITE, EV_DELETE, 0, token));
            }
            // apply one at a time so a harmless ENOENT on the delete
            // half never masks the add half
            for c in changes {
                let _ = self.apply(std::slice::from_ref(&c));
            }
            Ok(())
        }

        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.set(fd, token, interest)
        }

        pub fn modify(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.set(fd, token, interest)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            let f = fd as usize;
            let _ = self
                .apply(&[kev(f, EVFILT_READ, EV_DELETE, 0, 0)]);
            let _ = self
                .apply(&[kev(f, EVFILT_WRITE, EV_DELETE, 0, 0)]);
            Ok(())
        }

        pub fn wait(
            &self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut buf: Vec<Kevent> = (0..256)
                .map(|_| kev(0, 0, 0, 0, 0))
                .collect();
            let ts = timeout.map(|t| Timespec {
                tv_sec: t.as_secs() as isize,
                tv_nsec: t.subsec_nanos() as isize,
            });
            let n = loop {
                let r = unsafe {
                    kevent(
                        self.kq,
                        std::ptr::null(),
                        0,
                        buf.as_mut_ptr(),
                        buf.len() as c_int,
                        ts.as_ref()
                            .map(|t| t as *const Timespec)
                            .unwrap_or(std::ptr::null()),
                    )
                };
                if r >= 0 {
                    break r as usize;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            };
            for ev in &buf[..n] {
                out.push(Event {
                    token: ev.udata as usize as u64,
                    readable: ev.filter == EVFILT_READ
                        || ev.filter == EVFILT_USER,
                    writable: ev.filter == EVFILT_WRITE,
                    hangup: ev.flags & (EV_EOF | EV_ERROR) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { close(self.kq) };
        }
    }

    /// Cross-thread wakeup via `EVFILT_USER` — no fd consumed.
    pub struct Waker {
        kq: RawFd,
        token: u64,
    }

    impl Waker {
        pub fn new(poller: &Poller, token: u64) -> io::Result<Waker> {
            let kq = poller.kq;
            let ev = kev(
                token as usize,
                EVFILT_USER,
                EV_ADD | EV_CLEAR,
                0,
                token,
            );
            poller.apply(std::slice::from_ref(&ev))?;
            Ok(Waker { kq, token })
        }

        pub fn wake(&self) {
            let ev = kev(
                self.token as usize,
                EVFILT_USER,
                0,
                NOTE_TRIGGER,
                self.token,
            );
            unsafe {
                kevent(
                    self.kq,
                    &ev,
                    1,
                    std::ptr::null_mut(),
                    0,
                    std::ptr::null(),
                )
            };
        }

        /// EV_CLEAR resets the trigger on delivery; nothing to drain.
        pub fn drain(&self) {}
    }

    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: c_int, rlim: *mut Rlimit) -> c_int;
        fn setrlimit(resource: c_int, rlim: *const Rlimit) -> c_int;
    }
    const RLIMIT_NOFILE: c_int = 8;

    pub fn raise_nofile_limit(want: u64) -> u64 {
        let mut lim = Rlimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 0;
        }
        if lim.cur < want {
            let new = Rlimit {
                cur: want.min(lim.max),
                max: lim.max,
            };
            if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
                return new.cur;
            }
        }
        lim.cur
    }
}

#[cfg(not(any(
    target_os = "linux",
    target_os = "android",
    target_os = "macos"
)))]
compile_error!(
    "util::evloop supports epoll (Linux/Android) and kqueue (macOS) \
     only; add a kqueue/poll backend for this target"
);

pub use sys::{raise_nofile_limit, Poller, Waker};

/// Shorthand: register a socket-like type that exposes `AsRawFd`.
pub fn fd_of<T: std::os::fd::AsRawFd>(sock: &T) -> RawFd {
    sock.as_raw_fd()
}

/// `true` for the error kinds a non-blocking IO loop treats as "come
/// back later" rather than failure.
pub fn would_block(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::{Duration, Instant};

    #[test]
    fn poller_reports_readability_and_tokens() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let poller = Poller::new().unwrap();
        listener.set_nonblocking(true).unwrap();
        poller
            .register(fd_of(&listener), 7, Interest::READ)
            .unwrap();

        let mut events = Vec::new();
        // nothing pending: timeout elapses empty
        poller
            .wait(&mut events, Some(Duration::from_millis(10)))
            .unwrap();
        assert!(events.is_empty());

        let mut client = TcpStream::connect(addr).unwrap();
        poller
            .wait(&mut events, Some(Duration::from_millis(2000)))
            .unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));

        // accepted socket: readable only once the client writes
        let (mut conn, _) = listener.accept().unwrap();
        conn.set_nonblocking(true).unwrap();
        poller
            .register(fd_of(&conn), 9, Interest::READ)
            .unwrap();
        poller
            .wait(&mut events, Some(Duration::from_millis(10)))
            .unwrap();
        assert!(!events.iter().any(|e| e.token == 9));
        client.write_all(b"hi").unwrap();
        poller
            .wait(&mut events, Some(Duration::from_millis(2000)))
            .unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.readable));
        let mut buf = [0u8; 8];
        assert_eq!(conn.read(&mut buf).unwrap(), 2);

        // peer close surfaces as readable and/or hangup (EOF read)
        drop(client);
        poller
            .wait(&mut events, Some(Duration::from_millis(2000)))
            .unwrap();
        assert!(events
            .iter()
            .any(|e| e.token == 9 && (e.readable || e.hangup)));
        poller.deregister(fd_of(&conn)).unwrap();
    }

    #[test]
    fn write_interest_fires_when_buffer_drains() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        client.set_nonblocking(true).unwrap();
        let poller = Poller::new().unwrap();
        poller
            .register(fd_of(&client), 1, Interest::BOTH)
            .unwrap();
        let mut events = Vec::new();
        poller
            .wait(&mut events, Some(Duration::from_millis(2000)))
            .unwrap();
        // a fresh connected socket is immediately writable
        assert!(events.iter().any(|e| e.token == 1 && e.writable));
    }

    #[test]
    fn waker_crosses_threads_and_drains() {
        let poller = Poller::new().unwrap();
        let waker =
            std::sync::Arc::new(Waker::new(&poller, 42).unwrap());
        let w2 = waker.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            w2.wake();
        });
        let mut events = Vec::new();
        let t0 = Instant::now();
        poller
            .wait(&mut events, Some(Duration::from_secs(10)))
            .unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(events.iter().any(|e| e.token == 42 && e.readable));
        waker.drain();
        // drained: the next wait sleeps its full (short) timeout
        poller
            .wait(&mut events, Some(Duration::from_millis(20)))
            .unwrap();
        assert!(!events.iter().any(|e| e.token == 42));
        h.join().unwrap();
    }

    #[test]
    fn nofile_limit_is_at_least_queryable() {
        let got = raise_nofile_limit(256);
        assert!(got >= 256 || got == 0, "soft limit {got}");
    }
}
