//! Small self-contained utilities.
//!
//! This environment has no network access and only the crates vendored for
//! the `xla` bridge, so the usual ecosystem picks (rand, serde, clap,
//! criterion, rayon) are hand-rolled here at the size this project needs
//! (DESIGN.md §8). Each has its own tests.

pub mod cli;
pub mod evloop;
pub mod hash;
pub mod json;
pub mod pareto;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod table;
