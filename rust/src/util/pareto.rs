//! Pure multi-objective frontier engine (DESIGN.md §13).
//!
//! Works on plain objective rows in *minimization space* — callers
//! negate maximizing objectives (see [`minimized`]) and may pick any
//! subset/order of objectives; the engine never knows what the axes
//! mean. Two operations: the non-dominated subset
//! ([`non_dominated`]) and the dominated hypervolume
//! ([`hypervolume`]), the scalar frontier-quality indicator the
//! pareto bench tracks across PRs.

/// Objective direction for [`minimized`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sense {
    Minimize,
    Maximize,
}

/// Map a row of raw objective values into minimization space
/// (maximizing axes are negated).
pub fn minimized(row: &[f64], senses: &[Sense]) -> Vec<f64> {
    assert_eq!(row.len(), senses.len());
    row.iter()
        .zip(senses)
        .map(|(&v, s)| match s {
            Sense::Minimize => v,
            Sense::Maximize => -v,
        })
        .collect()
}

/// Strict Pareto dominance in minimization space: `a` is no worse in
/// every objective and strictly better in at least one. NaN never
/// dominates and is never dominated.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strict = false;
    for (&x, &y) in a.iter().zip(b) {
        if !(x <= y) {
            return false; // worse somewhere, or NaN involved
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Indices of the non-dominated subset of `vals` (minimization
/// space), ascending. Duplicate rows are all kept (neither strictly
/// dominates the other); rows containing NaN are dropped.
///
/// Algorithm: full-lexicographic sort, then a single forward scan —
/// after the sort a later row can never dominate an earlier survivor
/// (at its first differing coordinate it is strictly worse), so each
/// row only needs checking against the survivors so far. With two
/// objectives the survivor with the smallest second coordinate is
/// always the last one, so one comparison suffices: O(n log n)
/// total. In higher dimensions the scan checks the whole survivor
/// list — O(n log n + n·f) for a frontier of size f.
pub fn non_dominated(vals: &[Vec<f64>]) -> Vec<usize> {
    if vals.is_empty() {
        return vec![];
    }
    let d = vals[0].len();
    assert!(d > 0, "need at least one objective");
    assert!(
        vals.iter().all(|v| v.len() == d),
        "ragged objective rows"
    );
    let mut order: Vec<usize> = (0..vals.len())
        .filter(|&i| vals[i].iter().all(|v| !v.is_nan()))
        .collect();
    order.sort_by(|&a, &b| {
        for j in 0..d {
            match vals[a][j].partial_cmp(&vals[b][j]).unwrap() {
                std::cmp::Ordering::Equal => continue,
                ord => return ord,
            }
        }
        a.cmp(&b) // deterministic tiebreak for identical rows
    });
    let mut front: Vec<usize> = vec![];
    for &i in &order {
        let dominated = if d == 2 {
            front
                .last()
                .is_some_and(|&f| dominates(&vals[f], &vals[i]))
        } else {
            front.iter().any(|&f| dominates(&vals[f], &vals[i]))
        };
        if !dominated {
            front.push(i);
        }
    }
    front.sort_unstable();
    front
}

/// Hypervolume dominated by `vals` against reference point `r`
/// (minimization space): the volume of the union of boxes
/// `[v, r)`. Rows not strictly better than `r` in *every* objective
/// contribute nothing (the standard convention — pick `r` strictly
/// worse than the whole frontier). Exact, by recursive slicing along
/// the first objective: O(n^2) per dimension, plenty for report-size
/// frontiers.
pub fn hypervolume(vals: &[Vec<f64>], r: &[f64]) -> f64 {
    let pts: Vec<Vec<f64>> = vals
        .iter()
        .filter(|v| {
            v.len() == r.len()
                && v.iter().zip(r).all(|(&a, &b)| a < b)
        })
        .cloned()
        .collect();
    hv_slices(pts, r)
}

fn hv_slices(mut pts: Vec<Vec<f64>>, r: &[f64]) -> f64 {
    if pts.is_empty() {
        return 0.0;
    }
    if r.len() == 1 {
        let best =
            pts.iter().map(|p| p[0]).fold(f64::INFINITY, f64::min);
        return r[0] - best;
    }
    pts.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
    // slab between x_i and the next distinct x (or r[0]) is covered by
    // exactly the points seen so far, projected to the tail objectives
    let mut total = 0.0;
    for i in 0..pts.len() {
        let x_hi = if i + 1 < pts.len() { pts[i + 1][0] } else { r[0] };
        let width = x_hi - pts[i][0];
        if width > 0.0 {
            let proj: Vec<Vec<f64>> =
                pts[..=i].iter().map(|p| p[1..].to_vec()).collect();
            total += width * hv_slices(proj, &r[1..]);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_is_strict() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(dominates(&[0.5, 2.0], &[1.0, 2.0]));
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0]), "equal");
        assert!(!dominates(&[0.5, 3.0], &[1.0, 2.0]), "trade-off");
        assert!(!dominates(&[f64::NAN, 1.0], &[1.0, 2.0]));
        assert!(!dominates(&[0.0, 1.0], &[f64::NAN, 2.0]));
    }

    #[test]
    fn front_of_known_2d_set() {
        let vals = vec![
            vec![1.0, 5.0], // front
            vec![2.0, 3.0], // front
            vec![3.0, 3.0], // dominated by [2,3]
            vec![4.0, 1.0], // front
            vec![4.0, 4.0], // dominated
            vec![2.0, 3.0], // duplicate of a front row: kept
        ];
        assert_eq!(non_dominated(&vals), vec![0, 1, 3, 5]);
    }

    #[test]
    fn front_in_higher_dimensions() {
        let vals = vec![
            vec![1.0, 9.0, 9.0],
            vec![9.0, 1.0, 9.0],
            vec![9.0, 9.0, 1.0],
            vec![5.0, 5.0, 5.0],
            vec![9.0, 9.0, 2.0], // dominated by [9,9,1]
        ];
        assert_eq!(non_dominated(&vals), vec![0, 1, 2, 3]);
    }

    #[test]
    fn nan_rows_are_dropped_single_objective_works() {
        let vals =
            vec![vec![2.0], vec![f64::NAN], vec![1.0], vec![3.0]];
        assert_eq!(non_dominated(&vals), vec![2]);
        assert!(non_dominated(&[]).is_empty());
    }

    #[test]
    fn minimized_flips_maximizing_axes() {
        let row = minimized(
            &[0.9, 3.0],
            &[Sense::Maximize, Sense::Minimize],
        );
        assert_eq!(row, vec![-0.9, 3.0]);
    }

    #[test]
    fn hypervolume_of_rectangles() {
        let r = [4.0, 4.0];
        // one point: a single box
        assert!(
            (hypervolume(&[vec![1.0, 1.0]], &r) - 9.0).abs() < 1e-12
        );
        // staircase: union, not sum (overlap counted once)
        let hv = hypervolume(&[vec![1.0, 2.0], vec![2.0, 1.0]], &r);
        assert!((hv - (6.0 + 6.0 - 4.0)).abs() < 1e-12, "{hv}");
        // a dominated point adds nothing
        let hv2 = hypervolume(
            &[vec![1.0, 2.0], vec![2.0, 1.0], vec![2.5, 2.5]],
            &r,
        );
        assert!((hv2 - hv).abs() < 1e-12);
        // points at or beyond the reference contribute nothing
        assert_eq!(hypervolume(&[vec![4.0, 0.0]], &r), 0.0);
    }

    #[test]
    fn hypervolume_3d_cube_union() {
        let r = [2.0, 2.0, 2.0];
        let hv = hypervolume(&[vec![0.0, 0.0, 0.0]], &r);
        assert!((hv - 8.0).abs() < 1e-12);
        // two overlapping boxes: 8 + 8 - overlap(1x2x2=4) = 12
        let hv = hypervolume(
            &[vec![0.0, 0.0, 0.0], vec![-2.0, 1.0, 1.0]],
            &[2.0, 2.0, 2.0],
        );
        // box2 = [−2,2)x[1,2)x[1,2) vol 4*1*1=4; overlap with box1
        // = [0,2)x[1,2)x[1,2) = 2 -> union 8 + 4 - 2 = 10
        assert!((hv - 10.0).abs() < 1e-12, "{hv}");
    }
}
