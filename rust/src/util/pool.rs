//! Hand-rolled thread pools (no rayon offline; DESIGN.md §8).
//!
//! One shared fan-out primitive for every data-parallel stage in the
//! crate: the native backend's tiled matmul kernels, the Monte-Carlo
//! level sweep, `DesignSession::query_many`'s solve batch, and the
//! serve batcher's per-request fan (DESIGN.md §12). A [`ScopedPool`]
//! comes in two flavours behind one API:
//!
//! * **scoped** (default): a pool is just a worker count —
//!   `std::thread::scope` supplies the actual threads per call, so
//!   borrowing from the caller's stack is safe and nothing outlives
//!   the call. Right for one-shot CLI runs.
//! * **persistent** ([`ScopedPool::persistent`]): a fixed crew of
//!   long-lived workers spawned once and reused by every subsequent
//!   `for_each`/`map` — no thread spawn/join on the request path,
//!   which is what a long-running server needs. The worker count
//!   never changes after construction ([`ScopedPool::spawned_workers`]
//!   is stable for the life of the pool; `capmin serve` asserts this
//!   through its `Stats` reply).
//!
//! Contract (both flavours): work items are indexed 0..n and must be
//! independent; `map` returns results in index order regardless of
//! scheduling, so a caller whose per-item computation is deterministic
//! gets bit-identical output at every thread count and in either
//! flavour (the backend-equivalence tests pin this).
//!
//! Re-entrancy: a persistent pool runs one fan-out at a time, and a
//! closure running *on* a persistent worker must not submit to the
//! same pool (the outer fan-out would never finish). Nesting a
//! *scoped* pool inside persistent workers is fine — the serve
//! batcher leans on exactly that (outer persistent fan over requests,
//! inner sequential kernels).

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

#[derive(Clone)]
pub struct ScopedPool {
    threads: usize,
    /// Long-lived workers (persistent flavour); `None` means
    /// `std::thread::scope` per call.
    engine: Option<Arc<PoolEngine>>,
}

impl fmt::Debug for ScopedPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ScopedPool")
            .field("threads", &self.threads)
            .field("persistent", &self.engine.is_some())
            .finish()
    }
}

impl ScopedPool {
    /// `threads = 0` means "all available parallelism".
    pub fn new(threads: usize) -> ScopedPool {
        ScopedPool {
            threads: resolve_threads(threads),
            engine: None,
        }
    }

    /// A pool that runs everything inline on the caller's thread.
    pub fn sequential() -> ScopedPool {
        ScopedPool {
            threads: 1,
            engine: None,
        }
    }

    /// A pool whose workers are spawned once, here, and reused by
    /// every later `for_each`/`map` (`threads = 0` = all cores).
    /// Clones share the same workers; the last clone dropped joins
    /// them.
    pub fn persistent(threads: usize) -> ScopedPool {
        let threads = resolve_threads(threads);
        let engine = if threads > 1 {
            Some(Arc::new(PoolEngine::spawn(threads)))
        } else {
            None // a one-worker pool runs inline either way
        };
        ScopedPool { threads, engine }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Workers this pool spawned at construction and holds for its
    /// lifetime: the persistent crew size, or 0 for the scoped
    /// flavour (whose threads live only inside a single call). A
    /// server asserting "no threads are created per request" pins
    /// this value across requests.
    pub fn spawned_workers(&self) -> usize {
        self.engine.as_ref().map(|e| e.workers).unwrap_or(0)
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing over an atomic
    /// counter. Runs inline when the pool is sequential or `n <= 1`.
    ///
    /// Workers inherit the submitting thread's span context
    /// ([`crate::obs::current_ctx`]), so spans opened inside `f` nest
    /// under the span that issued the fan-out — a no-op (one relaxed
    /// load per item) while tracing is disabled.
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.threads == 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let ctx = crate::obs::current_ctx();
        let f = move |i: usize| {
            let _ctx = ctx.attach();
            f(i);
        };
        if let Some(engine) = &self.engine {
            engine.run(n, &f);
            return;
        }
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(n);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                // handles are joined by the scope itself
                let _ = scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Map `f` over `0..n`, returning results in index order.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let results: Mutex<Vec<(usize, T)>> =
            Mutex::new(Vec::with_capacity(n));
        self.for_each(n, |i| {
            let r = f(i);
            results.lock().unwrap().push((i, r));
        });
        let mut out = results.into_inner().unwrap();
        out.sort_by_key(|&(i, _)| i);
        out.into_iter().map(|(_, r)| r).collect()
    }
}

fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// One fan-out handed to the persistent workers.
///
/// `f` is a type-erased borrow of the submitter's closure with its
/// lifetime transmuted away. Safety rests on two invariants, both
/// enforced by [`PoolEngine::run`]:
/// * `f` is only dereferenced for claimed indices `i < n`, and
/// * `run` does not return until `completed == n` — i.e. every
///   dereference has finished — so the borrow outlives all use.
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    completed: AtomicUsize,
    n: usize,
    /// Set when any index's closure panicked; the submitter re-raises
    /// after the job drains, matching the scoped flavour (where
    /// `std::thread::scope` propagates worker panics to the caller).
    panicked: AtomicBool,
}

// The raw closure pointer is only sent to workers that observe the
// invariants above; the closure itself is Sync by bound.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct EngineState {
    job: Option<Arc<Job>>,
    /// Bumped per submitted job so a worker never re-runs a job it has
    /// already drained (its claim loop ended on `next >= n`).
    generation: u64,
    shutdown: bool,
}

struct EngineShared {
    state: Mutex<EngineState>,
    /// Workers wait here for a new generation (or shutdown).
    work: Condvar,
    /// The submitter waits here for `completed == n`.
    done: Condvar,
}

/// The long-lived crew behind a persistent [`ScopedPool`]: `workers`
/// threads spawned exactly once, parked on a condvar between
/// fan-outs.
struct PoolEngine {
    workers: usize,
    shared: Arc<EngineShared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl PoolEngine {
    fn spawn(workers: usize) -> PoolEngine {
        let shared = Arc::new(EngineShared {
            state: Mutex::new(EngineState {
                job: None,
                generation: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        PoolEngine {
            workers,
            shared,
            handles: Mutex::new(handles),
        }
    }

    /// Execute `f(0..n)` on the crew, blocking until every index has
    /// run. One job at a time: a second submitter queues behind the
    /// first (in this crate submitters are already serialized — the
    /// guard just makes the engine safe on its own terms).
    fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        // erase the borrow's lifetime; see `Job` for why this is sound
        let f: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(f)
        };
        let job = Arc::new(Job {
            f,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            n,
            panicked: AtomicBool::new(false),
        });
        let mut st = self.shared.state.lock().unwrap();
        while st.job.is_some() {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = Some(job.clone());
        st.generation += 1;
        self.shared.work.notify_all();
        while job.completed.load(Ordering::Acquire) < n {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
        // wake any queued submitter (and nudge idle workers back to
        // their wait loop for the next generation)
        self.shared.done.notify_all();
        drop(st);
        if job.panicked.load(Ordering::Acquire) {
            // the workers survived (they catch the unwind so the crew
            // never shrinks silently); the submitter re-raises, like a
            // scoped pool would on join
            panic!("a closure panicked on a persistent pool worker");
        }
    }
}

impl Drop for PoolEngine {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &EngineShared) {
    let mut seen = 0u64;
    loop {
        // park until a generation this worker hasn't drained appears
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen {
                    if let Some(j) = &st.job {
                        seen = st.generation;
                        break j.clone();
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.n {
                break;
            }
            // i < n: in-bounds claim, the submitter is still inside
            // `run` (completed < n), so the closure borrow is alive.
            // A panicking closure must still count as completed or the
            // submitter waits forever — catch it, flag the job, and
            // let the submitter re-raise.
            let r = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| (unsafe { &*job.f })(i)),
            );
            if r.is_err() {
                job.panicked.store(true, Ordering::Release);
            }
            if job.completed.fetch_add(1, Ordering::Release) + 1 == job.n
            {
                // last index done: wake the submitter. Taking the lock
                // orders this notify after the submitter's wait.
                let _guard = shared.state.lock().unwrap();
                shared.done.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn map_preserves_index_order() {
        for threads in [1usize, 2, 4, 7] {
            let pool = ScopedPool::new(threads);
            let out = pool.map(100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn for_each_visits_every_index_once() {
        let pool = ScopedPool::new(4);
        let sum = AtomicU64::new(0);
        pool.for_each(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn zero_threads_means_available_parallelism() {
        let pool = ScopedPool::new(0);
        assert!(pool.threads() >= 1);
        assert!(pool.map(3, |i| i).len() == 3);
    }

    #[test]
    fn empty_and_single_item_run_inline() {
        let pool = ScopedPool::new(8);
        assert!(pool.map(0, |i| i).is_empty());
        assert_eq!(pool.map(1, |i| i + 1), vec![1]);
    }

    #[test]
    fn results_identical_across_thread_counts() {
        // deterministic per-item work -> bit-identical output
        let reference: Vec<u64> = (0..64u64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15))
            .collect();
        for threads in [1usize, 2, 3, 8] {
            let pool = ScopedPool::new(threads);
            let got =
                pool.map(64, |i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            assert_eq!(got, reference, "threads {threads}");
        }
    }

    #[test]
    fn persistent_matches_scoped_bit_for_bit() {
        let reference: Vec<u64> = (0..257u64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15))
            .collect();
        let pool = ScopedPool::persistent(3);
        for _ in 0..20 {
            let got = pool
                .map(257, |i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            assert_eq!(got, reference);
        }
    }

    #[test]
    fn persistent_workers_are_spawned_once_and_stable() {
        let pool = ScopedPool::persistent(4);
        assert_eq!(pool.spawned_workers(), 4);
        assert_eq!(pool.threads(), 4);
        for round in 0..50 {
            let sum = AtomicU64::new(0);
            pool.for_each(100, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
            // the crew never grows or shrinks across requests
            assert_eq!(pool.spawned_workers(), 4, "round {round}");
        }
        // scoped pools hold no long-lived workers at all
        assert_eq!(ScopedPool::new(4).spawned_workers(), 0);
        assert_eq!(ScopedPool::sequential().spawned_workers(), 0);
    }

    #[test]
    fn persistent_clones_share_one_crew() {
        let a = ScopedPool::persistent(2);
        let b = a.clone();
        assert_eq!(a.spawned_workers(), 2);
        assert_eq!(b.spawned_workers(), 2);
        let out_a = a.map(32, |i| i + 1);
        let out_b = b.map(32, |i| i + 1);
        assert_eq!(out_a, out_b);
        drop(a);
        // surviving clone still works after the original is gone
        assert_eq!(b.map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn persistent_pool_propagates_panics_and_survives() {
        let pool = ScopedPool::persistent(2);
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.for_each(8, |i| {
                    if i == 3 {
                        panic!("boom");
                    }
                });
            }),
        );
        assert!(r.is_err(), "submitter must re-raise worker panics");
        // the crew caught the unwind: same workers, next job fine
        assert_eq!(pool.spawned_workers(), 2);
        assert_eq!(pool.map(5, |i| i * 3), vec![0, 3, 6, 9, 12]);
    }

    #[test]
    fn persistent_single_thread_runs_inline() {
        let pool = ScopedPool::persistent(1);
        assert_eq!(pool.spawned_workers(), 0);
        assert_eq!(pool.map(4, |i| i * 2), vec![0, 2, 4, 6]);
    }

    #[test]
    fn persistent_pool_can_nest_scoped_fanouts() {
        // the serve batcher's shape: outer persistent fan over
        // requests, inner scoped/sequential kernels per request
        let outer = ScopedPool::persistent(3);
        let got = outer.map(6, |i| {
            let inner = ScopedPool::new(2);
            inner.map(8, |j| (i * 8 + j) as u64).iter().sum::<u64>()
        });
        let want: Vec<u64> = (0..6)
            .map(|i| (0..8).map(|j| (i * 8 + j) as u64).sum())
            .collect();
        assert_eq!(got, want);
    }
}
