//! Hand-rolled scoped thread pool (no rayon offline; DESIGN.md §8).
//!
//! One shared fan-out primitive for every data-parallel stage in the
//! crate: the native backend's tiled matmul kernels, the Monte-Carlo
//! level sweep, and `DesignSession::query_many`'s solve batch. A pool
//! is just a worker count — `std::thread::scope` supplies the actual
//! threads per call, so borrowing from the caller's stack is safe and
//! nothing outlives the call.
//!
//! Contract: work items are indexed 0..n and must be independent;
//! `map` returns results in index order regardless of scheduling, so a
//! caller whose per-item computation is deterministic gets bit-identical
//! output at every thread count (the backend-equivalence tests pin
//! this).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

#[derive(Clone, Debug)]
pub struct ScopedPool {
    threads: usize,
}

impl ScopedPool {
    /// `threads = 0` means "all available parallelism".
    pub fn new(threads: usize) -> ScopedPool {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        };
        ScopedPool { threads }
    }

    /// A pool that runs everything inline on the caller's thread.
    pub fn sequential() -> ScopedPool {
        ScopedPool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing over an atomic
    /// counter. Runs inline when the pool is sequential or `n <= 1`.
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.threads == 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(n);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                // handles are joined by the scope itself
                let _ = scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Map `f` over `0..n`, returning results in index order.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let results: Mutex<Vec<(usize, T)>> =
            Mutex::new(Vec::with_capacity(n));
        self.for_each(n, |i| {
            let r = f(i);
            results.lock().unwrap().push((i, r));
        });
        let mut out = results.into_inner().unwrap();
        out.sort_by_key(|&(i, _)| i);
        out.into_iter().map(|(_, r)| r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn map_preserves_index_order() {
        for threads in [1usize, 2, 4, 7] {
            let pool = ScopedPool::new(threads);
            let out = pool.map(100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn for_each_visits_every_index_once() {
        let pool = ScopedPool::new(4);
        let sum = AtomicU64::new(0);
        pool.for_each(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn zero_threads_means_available_parallelism() {
        let pool = ScopedPool::new(0);
        assert!(pool.threads() >= 1);
        assert!(pool.map(3, |i| i).len() == 3);
    }

    #[test]
    fn empty_and_single_item_run_inline() {
        let pool = ScopedPool::new(8);
        assert!(pool.map(0, |i| i).is_empty());
        assert_eq!(pool.map(1, |i| i + 1), vec![1]);
    }

    #[test]
    fn results_identical_across_thread_counts() {
        // deterministic per-item work -> bit-identical output
        let reference: Vec<u64> = (0..64u64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15))
            .collect();
        for threads in [1usize, 2, 3, 8] {
            let pool = ScopedPool::new(threads);
            let got =
                pool.map(64, |i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            assert_eq!(got, reference, "threads {threads}");
        }
    }
}
