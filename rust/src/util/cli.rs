//! Tiny CLI argument helper (no clap offline; DESIGN.md §8).
//!
//! `Args::parse` splits `--key value` / `--flag` pairs after a subcommand.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub cmd: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse<I: Iterator<Item = String>>(mut argv: I) -> Args {
        let cmd = argv.next().unwrap_or_else(|| "help".to_string());
        let rest: Vec<String> = argv.collect();
        let mut opts = BTreeMap::new();
        let mut flags = vec![];
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), rest[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                flags.push(a.clone());
                i += 1;
            }
        }
        Args { cmd, opts, flags }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{key}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{key}")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_opts_and_flags() {
        let a = parse(&["fig8", "--dataset", "cifar_syn", "--quick",
                        "--k", "14"]);
        assert_eq!(a.cmd, "fig8");
        assert_eq!(a.get("dataset"), Some("cifar_syn"));
        assert!(a.flag("quick"));
        assert_eq!(a.usize_or("k", 0), 14);
        assert_eq!(a.f64_or("sigma", 0.03), 0.03);
    }

    #[test]
    fn empty_is_help() {
        let a = parse(&[]);
        assert_eq!(a.cmd, "help");
    }
}
