//! Tiny CLI argument helper (no clap offline; DESIGN.md §8).
//!
//! `Args::parse` splits `--key value` / `--flag` pairs after a
//! subcommand. Parsing never fails; validation is a separate pass —
//! [`Args::choice`] / [`Args::choice_list`] check enumerated option
//! values and [`Args::reject_unknown`] turns typo'd or misplaced
//! options into errors listing the valid set (the `--dataset` error
//! style), instead of silently ignoring them.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub cmd: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse<I: Iterator<Item = String>>(mut argv: I) -> Args {
        let cmd = argv.next().unwrap_or_else(|| "help".to_string());
        let rest: Vec<String> = argv.collect();
        let mut opts = BTreeMap::new();
        let mut flags = vec![];
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), rest[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                flags.push(a.clone());
                i += 1;
            }
        }
        Args { cmd, opts, flags }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{key}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{key}")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// An option constrained to an enumerated set: `Ok(None)` when
    /// absent, an error naming the valid choices on a bad value.
    pub fn choice(&self, key: &str, valid: &[&str])
        -> Result<Option<String>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) if valid.contains(&v) => Ok(Some(v.to_string())),
            Some(v) => Err(anyhow!(
                "unknown --{key} `{v}` (valid choices: {})",
                valid.join(", ")
            )),
        }
    }

    /// A free-form option validated through a caller-supplied parser
    /// (e.g. `--tile 4x8k32`, whose value grammar is too open for
    /// [`Args::choice`]): `Ok(None)` when absent, the parser's error
    /// on a bad value.
    pub fn validated<T>(
        &self,
        key: &str,
        parse: impl FnOnce(&str) -> Result<T>,
    ) -> Result<Option<T>> {
        self.get(key).map(parse).transpose()
    }

    /// A comma-separated list option over an enumerated set (e.g.
    /// `--emit json,csv`); empty when absent, every entry validated.
    pub fn choice_list(&self, key: &str, valid: &[&str])
        -> Result<Vec<String>> {
        let Some(raw) = self.get(key) else {
            return Ok(vec![]);
        };
        let mut out = vec![];
        for entry in raw.split(',') {
            let entry = entry.trim();
            if !valid.contains(&entry) {
                return Err(anyhow!(
                    "unknown --{key} entry `{entry}` (valid choices: \
                     {})",
                    valid.join(", ")
                ));
            }
            out.push(entry.to_string());
        }
        Ok(out)
    }

    /// A `host:port` socket-address option (e.g. `--addr
    /// 127.0.0.1:7878`), resolved through the system resolver so
    /// `localhost:0` works too; `default` when absent. Malformed
    /// values error in the same style as the enumerated-choice
    /// options.
    pub fn addr(&self, key: &str, default: &str)
        -> Result<std::net::SocketAddr> {
        use std::net::ToSocketAddrs;
        let raw = self.get(key).unwrap_or(default);
        raw.to_socket_addrs()
            .ok()
            .and_then(|mut it| it.next())
            .ok_or_else(|| anyhow!(
                "bad --{key} `{raw}`: expected host:port (e.g. \
                 127.0.0.1:7878; port 0 picks a free port)"
            ))
    }

    /// Reject anything the caller did not declare: unknown `--opt
    /// value` pairs, unknown `--flag`s, and stray positional arguments
    /// all error with the valid set, in the same style as the
    /// `--dataset` error. A declared flag that accidentally captured a
    /// value (`--quick foo`) gets its own message.
    pub fn reject_unknown(&self, opts: &[&str], flags: &[&str])
        -> Result<()> {
        for (k, v) in &self.opts {
            if opts.contains(&k.as_str()) {
                continue;
            }
            if flags.contains(&k.as_str()) {
                return Err(anyhow!(
                    "flag `--{k}` takes no value (got `{v}`)"
                ));
            }
            return Err(anyhow!(
                "unknown option `--{k}` (valid options: --{})",
                opts.join(", --")
            ));
        }
        for f in &self.flags {
            if flags.contains(&f.as_str()) {
                continue;
            }
            if opts.contains(&f.as_str()) {
                return Err(anyhow!(
                    "option `--{f}` needs a value"
                ));
            }
            return Err(anyhow!(
                "unknown flag or argument `{f}` (valid flags: --{})",
                flags.join(", --")
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_opts_and_flags() {
        let a = parse(&["fig8", "--dataset", "cifar_syn", "--quick",
                        "--k", "14"]);
        assert_eq!(a.cmd, "fig8");
        assert_eq!(a.get("dataset"), Some("cifar_syn"));
        assert!(a.flag("quick"));
        assert_eq!(a.usize_or("k", 0), 14);
        assert_eq!(a.f64_or("sigma", 0.03), 0.03);
    }

    #[test]
    fn empty_is_help() {
        let a = parse(&[]);
        assert_eq!(a.cmd, "help");
    }

    #[test]
    fn choice_validates_against_the_set() {
        let a = parse(&["suite", "--emit", "json"]);
        assert_eq!(
            a.choice("emit", &["md", "json", "csv"]).unwrap(),
            Some("json".into())
        );
        assert_eq!(a.choice("backend", &["native"]).unwrap(), None);
        let e = parse(&["suite", "--emit", "yaml"])
            .choice("emit", &["md", "json", "csv"])
            .unwrap_err()
            .to_string();
        assert!(e.contains("yaml"), "{e}");
        assert!(e.contains("md, json, csv"), "{e}");
    }

    #[test]
    fn choice_list_splits_and_validates() {
        let a = parse(&["suite", "--emit", "json,csv"]);
        assert_eq!(
            a.choice_list("emit", &["md", "json", "csv"]).unwrap(),
            vec!["json".to_string(), "csv".to_string()]
        );
        assert!(parse(&["suite"])
            .choice_list("emit", &["md"])
            .unwrap()
            .is_empty());
        let e = parse(&["suite", "--emit", "json,tsv"])
            .choice_list("emit", &["md", "json", "csv"])
            .unwrap_err()
            .to_string();
        assert!(e.contains("tsv"), "{e}");
    }

    #[test]
    fn validated_applies_the_parser() {
        let a = parse(&["fig8", "--tile", "4x8"]);
        let got =
            a.validated("tile", |s| Ok::<_, anyhow::Error>(s.len()));
        assert_eq!(got.unwrap(), Some(3));
        let absent = parse(&["fig8"])
            .validated("tile", |_| Ok::<_, anyhow::Error>(0));
        assert_eq!(absent.unwrap(), None);
        let e = a
            .validated("tile", |s| {
                Err::<(), _>(anyhow!("bad tile `{s}`"))
            })
            .unwrap_err()
            .to_string();
        assert!(e.contains("4x8"), "{e}");
    }

    #[test]
    fn addr_parses_host_port_and_rejects_garbage() {
        let a = parse(&["serve", "--addr", "127.0.0.1:7878"]);
        let got = a.addr("addr", "127.0.0.1:0").unwrap();
        assert_eq!(got.port(), 7878);
        assert!(got.ip().is_loopback());
        // absent -> default (port 0 = pick a free port)
        let d = parse(&["serve"]).addr("addr", "127.0.0.1:0").unwrap();
        assert_eq!(d.port(), 0);
        for bad in ["7878", "127.0.0.1", "127.0.0.1:notaport"] {
            let e = parse(&["serve", "--addr", bad])
                .addr("addr", "127.0.0.1:0")
                .unwrap_err()
                .to_string();
            assert!(e.contains(bad), "{e}");
            assert!(e.contains("host:port"), "{e}");
        }
    }

    #[test]
    fn reject_unknown_names_the_valid_set() {
        let a = parse(&["fig8", "--dataset", "cifar_syn", "--quick"]);
        a.reject_unknown(&["dataset"], &["quick"]).unwrap();

        let e = parse(&["fig8", "--emitt", "json"])
            .reject_unknown(&["emit"], &[])
            .unwrap_err()
            .to_string();
        assert!(e.contains("emitt"), "{e}");
        assert!(e.contains("--emit"), "{e}");

        let e = parse(&["fig8", "bogus"])
            .reject_unknown(&[], &["quick"])
            .unwrap_err()
            .to_string();
        assert!(e.contains("bogus"), "{e}");

        // a flag that swallowed a positional is called out as such
        let e = parse(&["suite", "--quick", "fig8"])
            .reject_unknown(&["dataset"], &["quick"])
            .unwrap_err()
            .to_string();
        assert!(e.contains("takes no value"), "{e}");

        // an option used bare is called out as needing a value
        let e = parse(&["suite", "--dataset"])
            .reject_unknown(&["dataset"], &["quick"])
            .unwrap_err()
            .to_string();
        assert!(e.contains("needs a value"), "{e}");
    }
}
