//! ASCII table rendering for experiment reports (paper-style rows).

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Column headers, for structured (JSON/CSV) re-rendering by the
    /// plan reporter.
    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    /// Data rows in insertion order.
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// RFC-4180-style CSV: header row then data rows; cells containing
    /// a comma, quote or newline are quoted with `""` escapes.
    pub fn to_csv(&self) -> String {
        let esc = |cell: &str| -> String {
            if cell.contains(',')
                || cell.contains('"')
                || cell.contains('\n')
            {
                format!("\"{}\"", cell.replace('"', "\"\""))
            } else {
                cell.to_string()
            }
        };
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .map(|c| esc(c))
                .collect::<Vec<_>>()
                .join(",")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }
}

/// 3-sig-fig engineering formatting with SI prefix (e.g. 135.2e-12 F ->
/// "135.2 pF").
pub fn si(value: f64, unit: &str) -> String {
    if value == 0.0 {
        return format!("0 {unit}");
    }
    let prefixes: [(f64, &str); 7] = [
        (1e-15, "f"),
        (1e-12, "p"),
        (1e-9, "n"),
        (1e-6, "µ"),
        (1e-3, "m"),
        (1.0, ""),
        (1e3, "k"),
    ];
    let a = value.abs();
    let mut best = prefixes[prefixes.len() - 1];
    for &(scale, _) in prefixes.iter().rev() {
        if a >= scale {
            best = (scale, prefixes.iter().find(|p| p.0 == scale).unwrap().1);
        }
    }
    for &(scale, p) in &prefixes {
        if a >= scale && a < scale * 1e3 {
            best = (scale, p);
            break;
        }
    }
    format!("{:.4} {}{}", value / best.0, best.1, unit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["k", "C"]);
        t.row(vec!["32".into(), "135.2 pF".into()]);
        t.row(vec!["14".into(), "9.6 pF".into()]);
        let s = t.render();
        assert!(s.contains("| k  | C        |"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(135.2e-12, "F"), "135.2000 pF");
        assert_eq!(si(0.5e-9, "s"), "500.0000 ps");
        assert_eq!(si(72e-6, "A"), "72.0000 µA");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes_only_when_needed() {
        let mut t = Table::new(&["name", "note"]);
        t.row(vec!["plain".into(), "a,b".into()]);
        t.row(vec!["q\"q".into(), "fine".into()]);
        assert_eq!(
            t.to_csv(),
            "name,note\nplain,\"a,b\"\n\"q\"\"q\",fine\n"
        );
        assert_eq!(t.headers(), &["name".to_string(), "note".into()]);
        assert_eq!(t.rows().len(), 2);
    }
}
