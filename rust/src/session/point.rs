//! The typed result of a codesign query, with a stable JSON form
//! (DESIGN.md §3, §7): everything a downstream consumer (bench, plot
//! script, future HTTP front-end) needs without re-running the pipeline.

use anyhow::{anyhow, Result};

use super::solver::HwSolve;
use super::spec::OperatingPointSpec;
use crate::analog::cost::CostVector;
use crate::analog::params::AnalogParams;
use crate::bnn::ErrorModel;
use crate::capmin::{CapMinResult, N_LEVELS};
use crate::util::json::{obj, Json};

/// Provenance of an evaluated point: which inference backend produced
/// the accuracy, which native microkernel tier it dispatched to, and
/// how many worker threads the session fanned out over. Metadata only
/// — neither the thread count nor the kernel tier ever changes a
/// result (kernels are bit-identical at any fan-out and tier), so
/// both are deliberately *not* part of the cache key: cached
/// operating points replay reproducibly across machines while still
/// recording where they came from.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PointMeta {
    /// Resolved backend name ("native" or "xla"; empty for points
    /// written before the backend layer existed).
    pub backend: String,
    /// Resolved native kernel tier ("scalar"/"avx2"/"neon";
    /// empty for xla points and points written before kernel
    /// dispatch existed) — DESIGN.md §11.
    pub kernel: String,
    /// Session worker threads at solve/eval time, *resolved* (0 =
    /// unrecorded; `--threads 0` records the machine's available
    /// parallelism, never a literal 0).
    pub threads: usize,
    /// Resolved register-blocking tile (`"4x8k64"`, `"scalar-safe"`;
    /// empty for xla points and points written before the blocked
    /// kernels existed) — DESIGN.md §14. Like `kernel`, provenance
    /// only: every tile is bit-identical.
    pub tile: String,
    /// Monte-Carlo solve mode that produced the error models
    /// ("paper"/"fast"/"analytic"; empty for points written before
    /// the mode knob existed) — DESIGN.md §15. Unlike the other meta
    /// fields the mode *does* change results, but it is key material
    /// through the spec's hw material (v3), not through meta; here it
    /// is recorded for human readers of the point files.
    pub mc_mode: String,
    /// Normal draws the solve actually consumed (0 for analytic /
    /// sigma = 0 solves and for points written before draw
    /// accounting). Data-dependent under fast mode's early stopping —
    /// which is exactly why it is provenance and never key material.
    pub mc_draws: u64,
    /// Wall-clock milliseconds the hardware solve took when this
    /// point was first produced (0 for cache replays and for points
    /// written before timing provenance; DESIGN.md §17). Machine- and
    /// load-dependent, so like every meta field it is never part of a
    /// cache key.
    pub solve_ms: f64,
    /// Milliseconds the originating request waited between serve-tier
    /// admission and solve start, when the point was produced by
    /// `capmin serve` (0 for CLI solves, cache replays and legacy
    /// points).
    pub queue_ms: f64,
}

/// One hardware operating point: the answer to an
/// [`OperatingPointSpec`] query.
#[derive(Clone, Debug, PartialEq)]
pub struct OperatingPoint {
    pub spec: OperatingPointSpec,
    /// Shared membrane capacitance [F] (sized by the topmost window).
    pub c: f64,
    /// Guaranteed response time of the slowest window [s].
    pub grt: f64,
    /// CapMin window per matmul.
    pub windows: Vec<CapMinResult>,
    /// Read-out levels per matmul (post CapMin-V merging when phi > 0).
    pub levels: Vec<Vec<usize>>,
    /// Quantized spike time per read-out level, per matmul [s].
    pub times: Vec<Vec<f64>>,
    /// Error model per matmul (the eval artifacts' runtime input).
    pub ems: Vec<ErrorModel>,
    /// Mean test accuracy under the error models (None for hardware-only
    /// queries, `spec.eval = None`).
    pub accuracy: Option<f64>,
    /// Backend/threads provenance (DESIGN.md §9).
    pub meta: PointMeta,
    /// Multi-objective hardware price of the point (DESIGN.md §13).
    /// A pure function of `c` + `times`, so like `meta` it is never
    /// part of a cache key — and unlike `meta` it is *recomputed*
    /// whenever a point is parsed, keeping every cached file priced
    /// by the current model.
    pub cost: CostVector,
}

impl OperatingPoint {
    /// Price `c` + per-matmul spike times on the calibrated testbed
    /// constants (sigma enters an operating point through accuracy,
    /// never through the hardware price, so the pricing substrate is
    /// spec-independent and deterministic on every load path).
    fn price(c: f64, times: &[Vec<f64>]) -> CostVector {
        CostVector::price(&AnalogParams::paper_calibrated(), c, times)
    }

    pub fn from_solve(
        spec: OperatingPointSpec,
        hw: HwSolve,
        accuracy: Option<f64>,
        meta: PointMeta,
    ) -> OperatingPoint {
        let times: Vec<Vec<f64>> =
            hw.sets.iter().map(|s| s.times.clone()).collect();
        let cost = OperatingPoint::price(hw.c, &times);
        OperatingPoint {
            spec,
            c: hw.c,
            grt: hw.grt(),
            levels: hw.sets.iter().map(|s| s.levels.clone()).collect(),
            times,
            windows: hw.windows,
            ems: hw.ems,
            accuracy,
            meta,
            cost,
        }
    }

    /// The peak (topmost) window — what drives the capacitor.
    pub fn peak_window(&self) -> &CapMinResult {
        self.windows
            .iter()
            .max_by_key(|w| w.q_hi)
            .expect("at least one matmul")
    }

    /// Stable JSON form written to `runs/points/<key>.json`.
    pub fn to_json(&self) -> Json {
        let windows = Json::Arr(
            self.windows
                .iter()
                .map(|w| {
                    obj(vec![
                        ("k", Json::Num(w.k as f64)),
                        ("q_lo", Json::Num(w.q_lo as f64)),
                        ("q_hi", Json::Num(w.q_hi as f64)),
                        ("coverage", Json::Num(w.coverage)),
                    ])
                })
                .collect(),
        );
        let levels = Json::Arr(
            self.levels
                .iter()
                .map(|ls| {
                    Json::Arr(
                        ls.iter().map(|&l| Json::Num(l as f64)).collect(),
                    )
                })
                .collect(),
        );
        let times = Json::Arr(
            self.times
                .iter()
                .map(|ts| {
                    Json::Arr(ts.iter().map(|&t| Json::Num(t)).collect())
                })
                .collect(),
        );
        let ems = Json::Arr(
            self.ems
                .iter()
                .map(|em| {
                    obj(vec![
                        (
                            "cdf",
                            Json::Arr(
                                em.cdf
                                    .iter()
                                    .map(|&v| Json::Num(v as f64))
                                    .collect(),
                            ),
                        ),
                        (
                            "vals",
                            Json::Arr(
                                em.vals
                                    .iter()
                                    .map(|&v| Json::Num(v as f64))
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("spec", self.spec.to_json()),
            ("c", Json::Num(self.c)),
            ("grt", Json::Num(self.grt)),
            ("windows", windows),
            ("levels", levels),
            ("times", times),
            ("ems", ems),
            (
                "accuracy",
                match self.accuracy {
                    Some(a) => Json::Num(a),
                    None => Json::Null,
                },
            ),
            (
                "meta",
                obj(vec![
                    ("backend", Json::Str(self.meta.backend.clone())),
                    ("kernel", Json::Str(self.meta.kernel.clone())),
                    ("threads", Json::Num(self.meta.threads as f64)),
                    ("tile", Json::Str(self.meta.tile.clone())),
                    ("mc_mode", Json::Str(self.meta.mc_mode.clone())),
                    ("mc_draws", Json::Num(self.meta.mc_draws as f64)),
                    ("solve_ms", Json::Num(self.meta.solve_ms)),
                    ("queue_ms", Json::Num(self.meta.queue_ms)),
                ]),
            ),
            // informational for external readers: `from_json`
            // recomputes the price, it never parses this field
            ("cost", self.cost.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<OperatingPoint> {
        let field = |k: &str| {
            j.get(k)
                .ok_or_else(|| anyhow!("point JSON missing `{k}`"))
        };
        let num = |v: &Json, what: &str| -> Result<f64> {
            match v {
                Json::Num(n) => Ok(*n),
                other => Err(anyhow!("bad {what}: {other:?}")),
            }
        };
        let arr = |v: &Json, what: &str| -> Result<Vec<Json>> {
            match v {
                Json::Arr(a) => Ok(a.clone()),
                other => Err(anyhow!("bad {what}: {other:?}")),
            }
        };
        let spec = OperatingPointSpec::from_json(field("spec")?)?;
        let mut windows = vec![];
        for w in arr(field("windows")?, "windows")? {
            windows.push(CapMinResult {
                k: num(
                    w.get("k")
                        .ok_or_else(|| anyhow!("window missing k"))?,
                    "window k",
                )? as usize,
                q_lo: num(
                    w.get("q_lo")
                        .ok_or_else(|| anyhow!("window missing q_lo"))?,
                    "window q_lo",
                )? as usize,
                q_hi: num(
                    w.get("q_hi")
                        .ok_or_else(|| anyhow!("window missing q_hi"))?,
                    "window q_hi",
                )? as usize,
                coverage: num(
                    w.get("coverage")
                        .ok_or_else(|| anyhow!("window missing coverage"))?,
                    "window coverage",
                )?,
            });
        }
        let mut levels = vec![];
        for ls in arr(field("levels")?, "levels")? {
            let mut row = vec![];
            for l in arr(&ls, "levels row")? {
                row.push(num(&l, "level")? as usize);
            }
            levels.push(row);
        }
        let mut times = vec![];
        for ts in arr(field("times")?, "times")? {
            let mut row = vec![];
            for t in arr(&ts, "times row")? {
                row.push(num(&t, "time")?);
            }
            times.push(row);
        }
        let mut ems = vec![];
        for e in arr(field("ems")?, "ems")? {
            let cdf_j = e
                .get("cdf")
                .ok_or_else(|| anyhow!("em missing cdf"))?;
            let vals_j = e
                .get("vals")
                .ok_or_else(|| anyhow!("em missing vals"))?;
            let mut cdf = vec![];
            for v in arr(cdf_j, "em cdf")? {
                cdf.push(num(&v, "cdf entry")? as f32);
            }
            let mut vals = vec![];
            for v in arr(vals_j, "em vals")? {
                vals.push(num(&v, "vals entry")? as f32);
            }
            if cdf.len() != N_LEVELS * N_LEVELS || vals.len() != N_LEVELS {
                return Err(anyhow!(
                    "error-model shape {}/{} (want {}/{})",
                    cdf.len(),
                    vals.len(),
                    N_LEVELS * N_LEVELS,
                    N_LEVELS
                ));
            }
            ems.push(ErrorModel { cdf, vals });
        }
        let accuracy = match field("accuracy")? {
            Json::Null => None,
            v => Some(num(v, "accuracy")?),
        };
        // absent in points written before the backend layer (PR 1 era):
        // default provenance, still a valid point
        let meta = match j.get("meta") {
            Some(m) => PointMeta {
                backend: match m.get("backend") {
                    Some(Json::Str(s)) => s.clone(),
                    _ => String::new(),
                },
                // absent in pre-dispatch points: default provenance
                kernel: match m.get("kernel") {
                    Some(Json::Str(s)) => s.clone(),
                    _ => String::new(),
                },
                threads: match m.get("threads") {
                    Some(Json::Num(n)) => *n as usize,
                    _ => 0,
                },
                // absent in pre-blocked-kernel points
                tile: match m.get("tile") {
                    Some(Json::Str(s)) => s.clone(),
                    _ => String::new(),
                },
                // absent in pre-mc-mode points
                mc_mode: match m.get("mc_mode") {
                    Some(Json::Str(s)) => s.clone(),
                    _ => String::new(),
                },
                mc_draws: match m.get("mc_draws") {
                    Some(Json::Num(n)) => *n as u64,
                    _ => 0,
                },
                // absent in pre-§17 points: no timing provenance
                solve_ms: match m.get("solve_ms") {
                    Some(Json::Num(n)) => *n,
                    _ => 0.0,
                },
                queue_ms: match m.get("queue_ms") {
                    Some(Json::Num(n)) => *n,
                    _ => 0.0,
                },
            },
            None => PointMeta::default(),
        };
        let c = num(field("c")?, "c")?;
        // recompute the price instead of trusting the file: cost-less
        // pre-§13 point files stay valid, and every point carries the
        // *current* pricing model's vector (it is metadata, never keyed)
        let cost = OperatingPoint::price(c, &times);
        Ok(OperatingPoint {
            spec,
            c,
            grt: num(field("grt")?, "grt")?,
            windows,
            levels,
            times,
            ems,
            accuracy,
            meta,
            cost,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::params::AnalogParams;
    use crate::capmin::Fmac;
    use crate::analog::montecarlo::McSettings;
    use crate::data::synth::Dataset;
    use crate::session::solver::solve;

    #[test]
    fn json_roundtrip_exact() {
        let p = AnalogParams::paper_calibrated();
        let fmacs =
            vec![Fmac::gaussian(5, 2.0, 1e8), Fmac::gaussian(16, 2.0, 1e8)];
        let spec =
            OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 2)
                .with_eval(7, 3);
        let hw = solve(
            p,
            42,
            McSettings::paper(100),
            1,
            &fmacs,
            spec.k,
            spec.sigma,
            spec.phi,
        );
        let draws = hw.mc_draws;
        assert!(draws > 0, "sigma > 0 paper solve consumes draws");
        let meta = PointMeta {
            backend: "native".into(),
            kernel: "avx2".into(),
            threads: 8,
            tile: "4x8k64".into(),
            mc_mode: "paper".into(),
            mc_draws: draws,
            solve_ms: 12.5,
            queue_ms: 0.25,
        };
        let point =
            OperatingPoint::from_solve(spec, hw, Some(0.913), meta);
        let text = point.to_json().to_string();
        let back = OperatingPoint::from_json(
            &Json::parse(&text).map_err(anyhow::Error::msg).unwrap(),
        )
        .unwrap();
        assert_eq!(point, back);
        assert_eq!(back.meta.backend, "native");
        assert_eq!(back.meta.kernel, "avx2");
        assert_eq!(back.meta.threads, 8);
        assert_eq!(back.meta.tile, "4x8k64");
        assert_eq!(back.meta.mc_mode, "paper");
        assert_eq!(back.meta.mc_draws, draws);
        assert_eq!(back.meta.solve_ms, 12.5);
        assert_eq!(back.meta.queue_ms, 0.25);
    }

    #[test]
    fn hardware_only_point_roundtrips_null_accuracy() {
        let p = AnalogParams::paper_calibrated();
        let fmacs = vec![Fmac::gaussian(16, 2.0, 1e8)];
        let spec = OperatingPointSpec::new(Dataset::KmnistSyn, 16, 0.0, 0);
        let hw = solve(
            p,
            1,
            McSettings::paper(50),
            1,
            &fmacs,
            spec.k,
            spec.sigma,
            spec.phi,
        );
        let point = OperatingPoint::from_solve(
            spec,
            hw,
            None,
            PointMeta::default(),
        );
        let text = point.to_json().to_string();
        let back = OperatingPoint::from_json(
            &Json::parse(&text).map_err(anyhow::Error::msg).unwrap(),
        )
        .unwrap();
        assert_eq!(back.accuracy, None);
        assert_eq!(point, back);
    }

    #[test]
    fn pre_backend_points_parse_with_default_meta() {
        // a PR-1-era point JSON has no `meta` field
        let p = AnalogParams::paper_calibrated();
        let fmacs = vec![Fmac::gaussian(16, 2.0, 1e8)];
        let spec = OperatingPointSpec::new(Dataset::KmnistSyn, 10, 0.0, 0);
        let hw = solve(
            p,
            1,
            McSettings::paper(50),
            1,
            &fmacs,
            spec.k,
            spec.sigma,
            spec.phi,
        );
        let point = OperatingPoint::from_solve(
            spec,
            hw,
            None,
            PointMeta::default(),
        );
        let text = point.to_json().to_string();
        // drop the meta field structurally (key order in the text form
        // is the writer's business, not this test's) to emulate the
        // old format
        let mut legacy = Json::parse(&text)
            .map_err(anyhow::Error::msg)
            .unwrap();
        match &mut legacy {
            Json::Obj(m) => {
                assert!(
                    m.remove("meta").is_some(),
                    "meta field expected in JSON form"
                );
            }
            other => panic!("point JSON not an object: {other:?}"),
        }
        let back = OperatingPoint::from_json(&legacy).unwrap();
        assert_eq!(back.meta, PointMeta::default());
    }

    #[test]
    fn pre_timing_meta_parses_with_zero_provenance() {
        // a pre-§17 meta object has no solve_ms/queue_ms — both must
        // default to 0 rather than fail the parse
        let p = AnalogParams::paper_calibrated();
        let fmacs = vec![Fmac::gaussian(16, 2.0, 1e8)];
        let spec = OperatingPointSpec::new(Dataset::KmnistSyn, 10, 0.0, 0);
        let hw = solve(
            p,
            1,
            McSettings::paper(50),
            1,
            &fmacs,
            spec.k,
            spec.sigma,
            spec.phi,
        );
        let meta = PointMeta {
            solve_ms: 9.5,
            queue_ms: 1.5,
            ..PointMeta::default()
        };
        let point = OperatingPoint::from_solve(spec, hw, None, meta);
        let mut legacy = Json::parse(&point.to_json().to_string())
            .map_err(anyhow::Error::msg)
            .unwrap();
        match &mut legacy {
            Json::Obj(m) => match m.get_mut("meta") {
                Some(Json::Obj(meta)) => {
                    assert!(meta.remove("solve_ms").is_some());
                    assert!(meta.remove("queue_ms").is_some());
                }
                other => panic!("bad meta: {other:?}"),
            },
            other => panic!("point JSON not an object: {other:?}"),
        }
        let back = OperatingPoint::from_json(&legacy).unwrap();
        assert_eq!(back.meta.solve_ms, 0.0);
        assert_eq!(back.meta.queue_ms, 0.0);
    }

    #[test]
    fn pre_cost_points_parse_and_are_repriced() {
        // a pre-§13 point JSON has no `cost` field — the parser must
        // reprice it from c + times rather than reject the file
        let p = AnalogParams::paper_calibrated();
        let fmacs =
            vec![Fmac::gaussian(5, 2.0, 1e8), Fmac::gaussian(16, 2.0, 1e8)];
        let spec = OperatingPointSpec::new(Dataset::CifarSyn, 12, 0.02, 2);
        let hw = solve(
            p,
            3,
            McSettings::paper(50),
            1,
            &fmacs,
            spec.k,
            spec.sigma,
            spec.phi,
        );
        let point = OperatingPoint::from_solve(
            spec,
            hw,
            None,
            PointMeta::default(),
        );
        let text = point.to_json().to_string();
        // `cost` is the last field: strip it to emulate the old format
        let at = text.find(",\"cost\":").expect("cost field in JSON");
        let legacy = format!("{}}}", &text[..at]);
        assert_ne!(legacy, text);
        let back = OperatingPoint::from_json(
            &Json::parse(&legacy).map_err(anyhow::Error::msg).unwrap(),
        )
        .unwrap();
        assert_eq!(back.cost, point.cost, "repriced on load");
        assert_eq!(back, point);
    }
}
