//! `DesignSession` — the crate's public codesign query service
//! (DESIGN.md §3).
//!
//! The paper's core deliverable is a *codesign query*: given a model's
//! F_MAC statistics and a (k, sigma, phi) choice, produce a hardware
//! operating point — window, capacitor size, spike-time set, error
//! model, accuracy. A session owns the run [`Store`], the
//! [`ExperimentConfig`] and one lazily-constructed
//! [`InferenceBackend`] (native sub-MAC engine or, behind the `xla`
//! feature, the PJRT artifact path — DESIGN.md §9), and answers typed
//! [`OperatingPointSpec`] requests with memoized [`OperatingPoint`]s:
//!
//! ```no_run
//! use capmin::coordinator::config::ExperimentConfig;
//! use capmin::data::synth::Dataset;
//! use capmin::session::{DesignSession, OperatingPointSpec};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = DesignSession::builder()
//!     .config(ExperimentConfig::default())
//!     .build()?;
//! let spec = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0)
//!     .with_eval(1, 3);
//! let point = session.query(&spec)?;
//! println!("C = {:.3e} F, accuracy {:?}", point.c, point.accuracy);
//! # Ok(()) }
//! ```
//!
//! Repeated (spec -> point) queries hit an in-memory map, then the
//! on-disk `runs/points/` cache, before any Monte-Carlo work reruns;
//! [`DesignSession::query_many`] additionally fans independent solves
//! out across the shared [`ScopedPool`]. The old `Pipeline` stage
//! graph survives as a crate-internal, `xla`-gated training detail.

pub mod cache;
pub mod point;
pub mod solver;
pub mod spec;

use std::cell::{Cell, OnceCell};
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::obs;

use crate::analog::params::AnalogParams;
use crate::backend::autotune;
use crate::backend::kernels::{KernelKind, ResolvedTile, TileSpec};
use crate::backend::{BackendKind, InferenceBackend, NativeBackend};
use crate::capmin::Fmac;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::store::{NamedTensor, Store};
use crate::data::synth::Dataset;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;
use crate::util::pool::ScopedPool;

use cache::PointCache;
pub use point::{OperatingPoint, PointMeta};
use solver::HwSolve;
pub use spec::{EvalSettings, OperatingPointSpec};

/// Run-store cache names for per-dataset stage results.
pub(crate) fn folded_cache_name(ds: Dataset) -> String {
    format!("{}_folded.capt", ds.spec().name)
}

pub(crate) fn fmac_cache_name(ds: Dataset) -> String {
    format!("{}_fmac.capt", ds.spec().name)
}

/// Monotone counters exposing the session's cache behaviour: tests
/// assert memoization through them (`solves` must not grow on a repeat
/// query) and the CLI prints them after a `point` command.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Specs received via `query` / `query_many`.
    pub queries: u64,
    /// Answered from the in-memory map.
    pub mem_hits: u64,
    /// Answered from `runs/points/` (then promoted to memory).
    pub disk_hits: u64,
    /// Hardware solves actually executed (window + capacitor + MC).
    pub solves: u64,
    /// Accuracy evaluations actually executed (inference backend).
    pub evals: u64,
    /// Batch entries answered by an identical spec *within the same*
    /// `query_many` call (solved once, fanned back out) — the
    /// intra-batch dedup the plan engine's cross-experiment sweeps
    /// lean on.
    pub deduped: u64,
}

impl SessionStats {
    pub fn hits(&self) -> u64 {
        self.mem_hits + self.disk_hits
    }

    /// Fraction of queries answered without any solve or eval work
    /// (memory + disk + intra-batch dedup); 0 when nothing was asked.
    pub fn hit_rate(&self) -> f64 {
        if self.queries == 0 {
            return 0.0;
        }
        (self.hits() + self.deduped) as f64 / self.queries as f64
    }
}

pub struct DesignSession {
    cfg: ExperimentConfig,
    store: Store,
    /// Lazily constructed PJRT runtime (`xla` feature): a session
    /// serving cached points, native-backend traffic, or hardware-only
    /// queries never compiles artifacts.
    #[cfg(feature = "xla")]
    rt: OnceCell<Arc<Runtime>>,
    /// Lazily constructed inference backend (`--backend`): pure
    /// hardware queries never build one.
    backend: OnceCell<Box<dyn InferenceBackend>>,
    points: PointCache,
    /// Hardware solves keyed without the eval settings: querying the
    /// same (dataset, k, sigma, phi) with and without accuracy
    /// evaluation shares one Monte-Carlo solve. The paired `f64` is
    /// the solve's wall time in ms — provenance for
    /// [`PointMeta::solve_ms`]; memoized replays report the original
    /// solve's cost.
    hw_solves: Mutex<HashMap<String, (HwSolve, f64)>>,
    fmacs: Mutex<HashMap<Dataset, (Vec<Fmac>, Fmac)>>,
    /// Folded hardware tensors per dataset, in host (backend-agnostic)
    /// form.
    folded: Mutex<HashMap<Dataset, Arc<Vec<NamedTensor>>>>,
    /// Datasets served by the deterministic *untrained* fallback
    /// (native-only build, cold store): their F_MACs and accuracies
    /// are flagged and never persisted as if trained.
    untrained: Mutex<HashSet<Dataset>>,
    /// The worker pool every solve, MC sweep and native kernel fans
    /// over. Scoped by default (threads per call); a long-running
    /// server installs a persistent crew via
    /// [`DesignSessionBuilder::pool`] so no threads are constructed
    /// per request (DESIGN.md §12). Results are bit-identical either
    /// way.
    pool: ScopedPool,
    stats: Cell<SessionStats>,
    /// Queue wait (ms) the serving tier attributes to the *next*
    /// freshly built point (DESIGN.md §17). `Cell` is fine: the
    /// session is a single-threaded facade (`stats` already makes it
    /// `!Sync`) and the serve session thread owns it exclusively.
    queue_ms: Cell<f64>,
}

pub struct DesignSessionBuilder {
    cfg: ExperimentConfig,
    pool: Option<ScopedPool>,
    #[cfg(feature = "xla")]
    runtime: Option<Runtime>,
}

impl DesignSessionBuilder {
    pub fn config(mut self, cfg: ExperimentConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Override the run/cache directory without touching the rest of
    /// the config.
    pub fn run_dir(mut self, dir: &str) -> Self {
        self.cfg.run_dir = dir.to_string();
        self
    }

    /// Supply the worker pool the session fans out over instead of
    /// the default scoped one — `capmin serve` passes
    /// [`ScopedPool::persistent`] so solve/eval worker threads are
    /// spawned once at startup and reused across requests. The pool's
    /// thread count takes precedence over `cfg.threads`.
    pub fn pool(mut self, pool: ScopedPool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Supply a pre-built runtime (benches that also drive the trainer
    /// directly share one PJRT client with the session).
    #[cfg(feature = "xla")]
    pub fn runtime(mut self, rt: Runtime) -> Self {
        self.runtime = Some(rt);
        self
    }

    pub fn build(self) -> Result<DesignSession> {
        // library users can set cfg.backend / cfg.kernel directly,
        // bypassing the CLI validation — reject typos (and SIMD tiers
        // this CPU lacks) here rather than deep inside a query
        BackendKind::parse(&self.cfg.backend)?;
        KernelKind::resolve(&self.cfg.kernel)?;
        TileSpec::parse(&self.cfg.tile)?;
        // also covers hand-built configs with a typo'd mc_mode
        self.cfg.mc_settings()?;
        let store = Store::new(&self.cfg.run_dir)?;
        let points =
            PointCache::new(store.path("points"), self.cfg.point_cache);
        #[cfg(feature = "xla")]
        let rt = OnceCell::new();
        #[cfg(feature = "xla")]
        if let Some(r) = self.runtime {
            let _ = rt.set(Arc::new(r));
        }
        let pool = self
            .pool
            .unwrap_or_else(|| ScopedPool::new(self.cfg.threads));
        Ok(DesignSession {
            cfg: self.cfg,
            store,
            #[cfg(feature = "xla")]
            rt,
            backend: OnceCell::new(),
            points,
            hw_solves: Mutex::new(HashMap::new()),
            fmacs: Mutex::new(HashMap::new()),
            folded: Mutex::new(HashMap::new()),
            untrained: Mutex::new(HashSet::new()),
            pool,
            stats: Cell::new(SessionStats::default()),
            queue_ms: Cell::new(0.0),
        })
    }
}

impl DesignSession {
    pub fn builder() -> DesignSessionBuilder {
        DesignSessionBuilder {
            cfg: ExperimentConfig::default(),
            pool: None,
            #[cfg(feature = "xla")]
            runtime: None,
        }
    }

    /// Shorthand for `builder().config(cfg).build()`.
    pub fn new(cfg: ExperimentConfig) -> Result<DesignSession> {
        DesignSession::builder().config(cfg).build()
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn store(&self) -> &Store {
        &self.store
    }

    /// The analog substrate parameters at the session's default sigma.
    pub fn params(&self) -> AnalogParams {
        AnalogParams::paper_calibrated().with_sigma(self.cfg.sigma_rel)
    }

    pub fn stats(&self) -> SessionStats {
        self.stats.get()
    }

    /// The backend this session's config resolves to ("native" or
    /// "xla") — recorded in cache keys and point metadata. Cheap: no
    /// backend is constructed.
    pub fn backend_name(&self) -> &'static str {
        BackendKind::resolve(&self.cfg)
    }

    /// Worker threads the session fans out over (`--threads`, 0 =
    /// all cores via `std::thread::available_parallelism`) — solve
    /// batches, MC sample sweeps and native kernels. Always the
    /// *resolved* count (never 0), which is what point metadata
    /// records.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The session's worker pool (persistent when the builder
    /// installed one — `ScopedPool::spawned_workers` is then stable
    /// for the session's life, which `capmin serve` reports in
    /// `Stats`).
    pub fn pool(&self) -> &ScopedPool {
        &self.pool
    }

    /// The native microkernel tier this session's config resolves to
    /// ("scalar"/"avx2"/"neon"; empty when the backend is xla —
    /// kernel dispatch is a native-path concept). Recorded in point
    /// metadata, never in cache keys (DESIGN.md §11).
    pub fn kernel_name(&self) -> &'static str {
        if self.backend_name() != "native" {
            return "";
        }
        KernelKind::resolve(&self.cfg.kernel)
            .expect("kernel validated at session build")
            .name()
    }

    /// The register-blocking tile this session's config resolves to
    /// (`"4x8k64"` / `"scalar-safe"`; empty when the backend is xla).
    /// `--tile auto` autotunes per machine on first use, memoized in
    /// `<run_dir>/autotune.json`. Recorded in point metadata, never in
    /// cache keys (DESIGN.md §14).
    pub fn tile_name(&self) -> String {
        self.resolved_tile().map(|t| t.name()).unwrap_or_default()
    }

    fn resolved_tile(&self) -> Option<ResolvedTile> {
        if self.backend_name() != "native" {
            return None;
        }
        let spec = TileSpec::parse(&self.cfg.tile)
            .expect("tile validated at session build");
        let kind = KernelKind::resolve(&self.cfg.kernel)
            .expect("kernel validated at session build");
        Some(autotune::resolve(
            spec,
            kind,
            &self.store.path("autotune.json"),
        ))
    }

    /// The inference backend, constructed on first use.
    pub fn backend(&self) -> Result<&dyn InferenceBackend> {
        if self.backend.get().is_none() {
            let b: Box<dyn InferenceBackend> = match self.backend_name()
            {
                "xla" => self.xla_backend()?,
                _ => Box::new(
                    NativeBackend::with_pool(
                        self.pool.clone(),
                        KernelKind::resolve(&self.cfg.kernel)?,
                        true,
                    )
                    .with_tile(self.resolved_tile().expect(
                        "native backend implies a resolved tile",
                    )),
                ),
            };
            // single-threaded session facade: set cannot race
            let _ = self.backend.set(b);
        }
        Ok(self.backend.get().expect("backend just initialized").as_ref())
    }

    #[cfg(feature = "xla")]
    fn xla_backend(&self) -> Result<Box<dyn InferenceBackend>> {
        Ok(Box::new(crate::backend::XlaBackend::new(
            self.runtime_arc()?.clone(),
            &self.cfg.engine,
        )))
    }

    #[cfg(not(feature = "xla"))]
    fn xla_backend(&self) -> Result<Box<dyn InferenceBackend>> {
        anyhow::bail!(
            "--backend xla needs a build with the `xla` cargo feature \
             (vendored PJRT bridge; DESIGN.md §9) — use --backend \
             native or rebuild with --features xla"
        )
    }

    /// The PJRT runtime, constructed on first use (`xla` builds only).
    #[cfg(feature = "xla")]
    pub fn runtime(&self) -> Result<&Runtime> {
        Ok(self.runtime_arc()?.as_ref())
    }

    #[cfg(feature = "xla")]
    fn runtime_arc(&self) -> Result<&Arc<Runtime>> {
        if self.rt.get().is_none() {
            let rt = Runtime::new()?;
            // single-threaded session facade: set cannot race
            let _ = self.rt.set(Arc::new(rt));
        }
        Ok(self.rt.get().expect("runtime just initialized"))
    }

    /// Hardware-mode accuracy evaluator on the session's engine
    /// (legacy direct access; new code goes through
    /// [`DesignSession::backend`]).
    #[cfg(feature = "xla")]
    pub fn evaluator(
        &self,
    ) -> Result<crate::coordinator::evaluator::Evaluator<'_>> {
        Ok(crate::coordinator::evaluator::Evaluator::new(
            self.runtime()?,
            &self.cfg.engine,
        ))
    }

    #[cfg(feature = "xla")]
    fn pipeline(
        &self,
    ) -> Result<crate::coordinator::pipeline::Pipeline<'_>> {
        crate::coordinator::pipeline::Pipeline::new(
            self.runtime()?,
            self.cfg.clone(),
        )
    }

    /// Train (or load) `ds`'s model so later queries only pay for the
    /// solve + eval.
    pub fn ensure_trained(&self, ds: Dataset) -> Result<()> {
        self.folded(ds).map(|_| ())
    }

    /// Trained + folded hardware tensors for `ds` in host form
    /// (memory-, then disk-cached; trains through the XLA pipeline on
    /// a cold store when available, otherwise falls back to a
    /// deterministic untrained init so native-only machines still run
    /// end-to-end).
    pub fn folded(&self, ds: Dataset) -> Result<Arc<Vec<NamedTensor>>> {
        if let Some(f) = self.folded.lock().unwrap().get(&ds) {
            return Ok(f.clone());
        }
        let (ts, untrained) = self.obtain_folded(ds)?;
        if untrained {
            self.untrained.lock().unwrap().insert(ds);
        }
        let ts = Arc::new(ts);
        self.folded.lock().unwrap().insert(ds, ts.clone());
        Ok(ts)
    }

    fn obtain_folded(&self, ds: Dataset)
        -> Result<(Vec<NamedTensor>, bool)> {
        let cache = folded_cache_name(ds);
        if self.store.exists(&cache) {
            return Ok((self.store.load_tensors(&cache)?, false));
        }
        #[cfg(feature = "xla")]
        if crate::runtime::artifacts_dir().join("manifest.json").exists()
        {
            return Ok((self.pipeline()?.ensure_folded(ds)?, false));
        }
        let spec = ds.spec();
        eprintln!(
            "[session] {}: no cached trained weights and no XLA \
             trainer on this build; using a deterministic untrained \
             init for {} (accuracies will be near-chance, tensors stay \
             out of the run store)",
            spec.name, spec.model
        );
        Ok((crate::backend::native::init_folded(spec.model)?, true))
    }

    /// True when `ds` is being served by the untrained fallback.
    pub fn is_untrained(&self, ds: Dataset) -> bool {
        self.untrained.lock().unwrap().contains(&ds)
    }

    /// F_MAC histograms for `ds`: (per-matmul, sum). Served from memory
    /// or the run store when possible, otherwise extracted through the
    /// session's backend.
    pub fn fmac(&self, ds: Dataset) -> Result<(Vec<Fmac>, Fmac)> {
        if let Some(f) = self.fmacs.lock().unwrap().get(&ds) {
            return Ok(f.clone());
        }
        let cache = fmac_cache_name(ds);
        let res = if self.store.exists(&cache) {
            self.store.load_fmac(&cache)?
        } else {
            let spec = ds.spec();
            let folded = self.folded(ds)?;
            let be = self.backend()?;
            eprintln!(
                "[session] extracting F_MAC for {} ({} backend)...",
                spec.name,
                be.name()
            );
            let r = be.fmac(
                spec.model,
                &folded,
                spec.clone(),
                self.cfg.hist_limit,
                self.cfg.seed ^ 0x48_31u64,
            )?;
            eprintln!(
                "[session] {}: F_MAC over {} samples, clean train-acc \
                 {:.3}",
                spec.name, r.n_samples, r.accuracy
            );
            let pair = (r.per_matmul, r.sum);
            if !self.is_untrained(ds) {
                self.store.save_fmac(&cache, &pair.0, &pair.1)?;
            }
            pair
        };
        self.fmacs.lock().unwrap().insert(ds, res.clone());
        Ok(res)
    }

    /// Inject F_MAC statistics for `ds` instead of extracting them —
    /// offline tests and benches query hardware points on synthetic
    /// histograms without artifacts or training.
    pub fn put_fmac(&self, ds: Dataset, per_matmul: Vec<Fmac>, sum: Fmac) {
        self.fmacs.lock().unwrap().insert(ds, (per_matmul, sum));
    }

    /// Answer one codesign query (memoized).
    pub fn query(&self, spec: &OperatingPointSpec)
        -> Result<Arc<OperatingPoint>> {
        self.bump(|s| s.queries += 1);
        let key = spec.cache_key(&self.cfg);
        if let Some(p) = self.lookup(&key, spec) {
            return Ok(p);
        }
        let (hw, solve_ms) = self.hw_solve(spec)?;
        self.finish(spec, &key, hw, solve_ms)
    }

    /// The shared hardware solve behind a spec: served from the
    /// in-memory solve cache when only the eval settings differ.
    /// Returns the solve and its wall time in ms (the original solve's
    /// time on a memoized replay).
    fn hw_solve(&self, spec: &OperatingPointSpec)
        -> Result<(HwSolve, f64)> {
        let hkey = spec.hw_cache_key(&self.cfg);
        if let Some(hit) = self.hw_solves.lock().unwrap().get(&hkey) {
            return Ok(hit.clone());
        }
        let (per_fmac, _) = self.fmac(spec.dataset)?;
        let _span = crate::span!("session.solve");
        let t0 = Instant::now();
        let hw = solver::solve_on(
            &self.pool,
            self.params(),
            self.cfg.seed,
            self.cfg.mc_settings()?,
            &per_fmac,
            spec.k,
            spec.sigma,
            spec.phi,
        );
        let solve_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.bump(|s| s.solves += 1);
        obs::registry::inc("session.solves");
        self.hw_solves
            .lock()
            .unwrap()
            .insert(hkey, (hw.clone(), solve_ms));
        Ok((hw, solve_ms))
    }

    /// Answer a batch of independent queries, solving cache misses in
    /// parallel on the shared [`ScopedPool`] (the MC/pmap stage is
    /// embarrassingly parallel and dominates sweep wall time). Results
    /// match sequential [`DesignSession::query`] calls exactly: every
    /// solve seeds its PRNG streams from (config seed, matmul index)
    /// only, so thread scheduling cannot change an answer.
    ///
    /// Identical specs within one batch are deduplicated up front: the
    /// first occurrence is solved (and evaluated) once, later
    /// occurrences fan its result back out and count as
    /// [`SessionStats::deduped`].
    pub fn query_many(&self, specs: &[OperatingPointSpec])
        -> Result<Vec<Arc<OperatingPoint>>> {
        self.bump(|s| s.queries += specs.len() as u64);
        let keys: Vec<String> =
            specs.iter().map(|s| s.cache_key(&self.cfg)).collect();
        let mut out: Vec<Option<Arc<OperatingPoint>>> = specs
            .iter()
            .zip(&keys)
            .map(|(s, k)| self.lookup(k, s))
            .collect();

        // intra-batch dedup: among the misses, the first entry with a
        // given full cache key is the representative; duplicates take
        // its finished point at the end
        let mut rep_of: HashMap<&str, usize> = HashMap::new();
        let mut dup_of: Vec<Option<usize>> = vec![None; specs.len()];
        for i in 0..specs.len() {
            if out[i].is_some() {
                continue;
            }
            match rep_of.get(keys[i].as_str()) {
                Some(&rep) => dup_of[i] = Some(rep),
                None => {
                    rep_of.insert(keys[i].as_str(), i);
                }
            }
        }
        let dups = dup_of.iter().filter(|d| d.is_some()).count() as u64;
        if dups > 0 {
            self.bump(|s| s.deduped += dups);
            obs::registry::add("session.deduped", dups);
        }

        // one solve job per distinct *hardware* key among the misses
        // (eval variants of the same point share it)
        let hkeys: Vec<String> = specs
            .iter()
            .map(|s| s.hw_cache_key(&self.cfg))
            .collect();
        struct Job {
            hkey: String,
            base: AnalogParams,
            seed: u64,
            mc: crate::analog::montecarlo::McSettings,
            per_fmac: Vec<Fmac>,
            k: usize,
            sigma: f64,
            phi: usize,
        }
        let mc = self.cfg.mc_settings()?;
        let mut jobs: Vec<Job> = vec![];
        let mut queued: HashSet<String> = HashSet::new();
        for (i, spec) in specs.iter().enumerate() {
            if out[i].is_some()
                || dup_of[i].is_some()
                || queued.contains(&hkeys[i])
                || self.hw_solves.lock().unwrap().contains_key(&hkeys[i])
            {
                continue;
            }
            // F_MAC extraction (and any training) happens here,
            // sequentially: the backend facade is not Sync-shared, the
            // solve is pure.
            let (per_fmac, _) = self.fmac(spec.dataset)?;
            queued.insert(hkeys[i].clone());
            jobs.push(Job {
                hkey: hkeys[i].clone(),
                base: self.params(),
                seed: self.cfg.seed,
                mc,
                per_fmac,
                k: spec.k,
                sigma: spec.sigma,
                phi: spec.phi,
            });
        }

        if !jobs.is_empty() {
            // split the workers between the job fan-out and each
            // job's MC level sweep: small batches on many-core hosts
            // still use every core, without oversubscribing (results
            // are bit-identical at any split). The inner per-job
            // pools stay scoped even when the session pool is
            // persistent — a persistent crew must not re-enter itself
            let pool = &self.pool;
            let per_job = (pool.threads() / jobs.len()).max(1);
            let solved: Vec<(String, HwSolve, f64)> =
                pool.map(jobs.len(), |i| {
                    let _span = crate::span!("session.solve");
                    let t0 = Instant::now();
                    let j = &jobs[i];
                    let hw = solver::solve(
                        j.base,
                        j.seed,
                        j.mc,
                        per_job,
                        &j.per_fmac,
                        j.k,
                        j.sigma,
                        j.phi,
                    );
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    (j.hkey.clone(), hw, ms)
                });
            self.bump(|s| s.solves += jobs.len() as u64);
            obs::registry::add("session.solves", jobs.len() as u64);
            let mut hw_solves = self.hw_solves.lock().unwrap();
            for (hkey, hw, ms) in solved {
                hw_solves.insert(hkey, (hw, ms));
            }
        }

        // finish representatives in request order (accuracy evaluation
        // is sequential: one backend), then fan results out to the
        // intra-batch duplicates
        for (i, spec) in specs.iter().enumerate() {
            if out[i].is_some() || dup_of[i].is_some() {
                continue;
            }
            if let Some(p) = self.points.get_memory(&keys[i]) {
                out[i] = Some(p);
                continue;
            }
            let (hw, solve_ms) = self
                .hw_solves
                .lock()
                .unwrap()
                .get(&hkeys[i])
                .cloned()
                .expect("a solve was queued for every miss");
            out[i] = Some(self.finish(spec, &keys[i], hw, solve_ms)?);
        }
        for i in 0..specs.len() {
            if let Some(rep) = dup_of[i] {
                let p = out[rep].clone().expect("representative done");
                out[i] = Some(p);
            }
        }
        Ok(out.into_iter().map(|p| p.expect("filled above")).collect())
    }

    fn lookup(&self, key: &str, spec: &OperatingPointSpec)
        -> Option<Arc<OperatingPoint>> {
        if let Some(p) = self.points.get_memory(key) {
            self.bump(|s| s.mem_hits += 1);
            obs::registry::inc("session.cache.mem_hits");
            self.queue_ms.set(0.0);
            return Some(p);
        }
        if let Some(p) = self.points.get_disk(key, spec) {
            self.bump(|s| s.disk_hits += 1);
            obs::registry::inc("session.cache.disk_hits");
            self.queue_ms.set(0.0);
            return Some(p);
        }
        obs::registry::inc("session.cache.misses");
        None
    }

    /// Attribute the *next* freshly built point to a serve request that
    /// waited `ms` between admission and solve start. Consumed (reset
    /// to 0) by the next [`DesignSession::query`] that actually builds
    /// a point; cache hits ignore and clear it.
    pub fn note_queue_ms(&self, ms: f64) {
        self.queue_ms.set(ms);
    }

    /// Accuracy-evaluate (if requested), package, and cache one solved
    /// point.
    fn finish(
        &self,
        spec: &OperatingPointSpec,
        key: &str,
        hw: HwSolve,
        solve_ms: f64,
    ) -> Result<Arc<OperatingPoint>> {
        let accuracy = match spec.eval {
            None => None,
            Some(e) => {
                let _span = crate::span!("session.eval");
                let ds = spec.dataset.spec();
                let folded = self.folded(spec.dataset)?;
                let be = self.backend()?;
                self.bump(|s| s.evals += 1);
                obs::registry::inc("session.evals");
                Some(be.accuracy_multi_seed(
                    ds.model,
                    &folded,
                    ds.clone(),
                    &hw.ems,
                    self.cfg.eval_limit,
                    e.n_seeds,
                    e.seed,
                )?)
            }
        };
        let meta = PointMeta {
            backend: self.backend_name().to_string(),
            kernel: self.kernel_name().to_string(),
            threads: self.threads(),
            tile: self.tile_name(),
            mc_mode: self.cfg.mc_mode.clone(),
            mc_draws: hw.mc_draws,
            solve_ms,
            queue_ms: self.queue_ms.replace(0.0),
        };
        let point = Arc::new(OperatingPoint::from_solve(
            *spec, hw, accuracy, meta,
        ));
        if self.is_untrained(spec.dataset) {
            // untrained-fallback results memoize for this session only
            // — never onto disk, where a later session with trained
            // weights would replay them under the same key
            self.points.put_memory(key, point.clone());
        } else {
            self.points.put(key, point.clone())?;
        }
        Ok(point)
    }

    fn bump(&self, f: impl FnOnce(&mut SessionStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }
}
