//! `DesignSession` — the crate's public codesign query service
//! (DESIGN.md §3).
//!
//! The paper's core deliverable is a *codesign query*: given a model's
//! F_MAC statistics and a (k, sigma, phi) choice, produce a hardware
//! operating point — window, capacitor size, spike-time set, error
//! model, accuracy. A session owns the PJRT [`Runtime`] (lazily
//! initialized: hardware-only queries never load artifacts), the run
//! [`Store`] and the [`ExperimentConfig`], and answers typed
//! [`OperatingPointSpec`] requests with memoized [`OperatingPoint`]s:
//!
//! ```no_run
//! use capmin::coordinator::config::ExperimentConfig;
//! use capmin::data::synth::Dataset;
//! use capmin::session::{DesignSession, OperatingPointSpec};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = DesignSession::builder()
//!     .config(ExperimentConfig::default())
//!     .build()?;
//! let spec = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0)
//!     .with_eval(1, 3);
//! let point = session.query(&spec)?;
//! println!("C = {:.3e} F, accuracy {:?}", point.c, point.accuracy);
//! # Ok(()) }
//! ```
//!
//! Repeated (spec -> point) queries hit an in-memory map, then the
//! on-disk `runs/points/` cache, before any Monte-Carlo work reruns;
//! [`DesignSession::query_many`] additionally fans independent solves
//! out across threads. The old `Pipeline` stage graph survives as a
//! crate-internal implementation detail of this module.

pub mod cache;
pub mod point;
pub mod solver;
pub mod spec;

use std::cell::{Cell, OnceCell};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::analog::params::AnalogParams;
use crate::capmin::Fmac;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::evaluator::Evaluator;
use crate::coordinator::pipeline::Pipeline;
use crate::coordinator::store::Store;
use crate::data::synth::Dataset;
use crate::runtime::Runtime;

use cache::PointCache;
pub use point::OperatingPoint;
use solver::HwSolve;
pub use spec::{EvalSettings, OperatingPointSpec};

/// Monotone counters exposing the session's cache behaviour: tests
/// assert memoization through them (`solves` must not grow on a repeat
/// query) and the CLI prints them after a `point` command.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Specs received via `query` / `query_many`.
    pub queries: u64,
    /// Answered from the in-memory map.
    pub mem_hits: u64,
    /// Answered from `runs/points/` (then promoted to memory).
    pub disk_hits: u64,
    /// Hardware solves actually executed (window + capacitor + MC).
    pub solves: u64,
    /// Accuracy evaluations actually executed (PJRT eval artifact).
    pub evals: u64,
}

impl SessionStats {
    pub fn hits(&self) -> u64 {
        self.mem_hits + self.disk_hits
    }
}

pub struct DesignSession {
    cfg: ExperimentConfig,
    store: Store,
    /// Lazily constructed: a session serving cached points (or
    /// hardware-only queries on injected F_MACs) never compiles
    /// artifacts.
    rt: OnceCell<Runtime>,
    points: PointCache,
    /// Hardware solves keyed without the eval settings: querying the
    /// same (dataset, k, sigma, phi) with and without accuracy
    /// evaluation shares one Monte-Carlo solve.
    hw_solves: Mutex<HashMap<String, HwSolve>>,
    fmacs: Mutex<HashMap<Dataset, (Vec<Fmac>, Fmac)>>,
    folded: Mutex<HashMap<Dataset, Arc<Vec<xla::Literal>>>>,
    stats: Cell<SessionStats>,
}

pub struct DesignSessionBuilder {
    cfg: ExperimentConfig,
    runtime: Option<Runtime>,
}

impl DesignSessionBuilder {
    pub fn config(mut self, cfg: ExperimentConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Override the run/cache directory without touching the rest of
    /// the config.
    pub fn run_dir(mut self, dir: &str) -> Self {
        self.cfg.run_dir = dir.to_string();
        self
    }

    /// Supply a pre-built runtime (benches that also drive the trainer
    /// directly share one PJRT client with the session).
    pub fn runtime(mut self, rt: Runtime) -> Self {
        self.runtime = Some(rt);
        self
    }

    pub fn build(self) -> Result<DesignSession> {
        let store = Store::new(&self.cfg.run_dir)?;
        let points =
            PointCache::new(store.path("points"), self.cfg.point_cache);
        let rt = OnceCell::new();
        if let Some(r) = self.runtime {
            let _ = rt.set(r);
        }
        Ok(DesignSession {
            cfg: self.cfg,
            store,
            rt,
            points,
            hw_solves: Mutex::new(HashMap::new()),
            fmacs: Mutex::new(HashMap::new()),
            folded: Mutex::new(HashMap::new()),
            stats: Cell::new(SessionStats::default()),
        })
    }
}

impl DesignSession {
    pub fn builder() -> DesignSessionBuilder {
        DesignSessionBuilder {
            cfg: ExperimentConfig::default(),
            runtime: None,
        }
    }

    /// Shorthand for `builder().config(cfg).build()`.
    pub fn new(cfg: ExperimentConfig) -> Result<DesignSession> {
        DesignSession::builder().config(cfg).build()
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn store(&self) -> &Store {
        &self.store
    }

    /// The analog substrate parameters at the session's default sigma.
    pub fn params(&self) -> AnalogParams {
        AnalogParams::paper_calibrated().with_sigma(self.cfg.sigma_rel)
    }

    pub fn stats(&self) -> SessionStats {
        self.stats.get()
    }

    /// The PJRT runtime, constructed on first use.
    pub fn runtime(&self) -> Result<&Runtime> {
        if self.rt.get().is_none() {
            let rt = Runtime::new()?;
            // single-threaded session: set cannot race
            let _ = self.rt.set(rt);
        }
        Ok(self.rt.get().expect("runtime just initialized"))
    }

    /// Hardware-mode accuracy evaluator on the session's engine.
    pub fn evaluator(&self) -> Result<Evaluator<'_>> {
        Ok(Evaluator::new(self.runtime()?, &self.cfg.engine))
    }

    fn pipeline(&self) -> Result<Pipeline<'_>> {
        Pipeline::new(self.runtime()?, self.cfg.clone())
    }

    /// Train (or load) `ds`'s model so later queries only pay for the
    /// solve + eval.
    pub fn ensure_trained(&self, ds: Dataset) -> Result<()> {
        self.folded(ds).map(|_| ())
    }

    /// Trained + folded hardware tensors for `ds` (memory-, then
    /// disk-cached; trains on a cold store).
    pub fn folded(&self, ds: Dataset) -> Result<Arc<Vec<xla::Literal>>> {
        if let Some(f) = self.folded.lock().unwrap().get(&ds) {
            return Ok(f.clone());
        }
        let lits = Arc::new(self.pipeline()?.ensure_folded(ds)?);
        self.folded.lock().unwrap().insert(ds, lits.clone());
        Ok(lits)
    }

    /// F_MAC histograms for `ds`: (per-matmul, sum). Served from memory
    /// or the run store without touching the runtime when possible.
    pub fn fmac(&self, ds: Dataset) -> Result<(Vec<Fmac>, Fmac)> {
        if let Some(f) = self.fmacs.lock().unwrap().get(&ds) {
            return Ok(f.clone());
        }
        let cache = Pipeline::fmac_cache_name(ds);
        let res = if self.store.exists(&cache) {
            self.store.load_fmac(&cache)?
        } else {
            self.pipeline()?.ensure_fmac(ds)?
        };
        self.fmacs.lock().unwrap().insert(ds, res.clone());
        Ok(res)
    }

    /// Inject F_MAC statistics for `ds` instead of extracting them —
    /// offline tests and benches query hardware points on synthetic
    /// histograms without artifacts or training.
    pub fn put_fmac(&self, ds: Dataset, per_matmul: Vec<Fmac>, sum: Fmac) {
        self.fmacs.lock().unwrap().insert(ds, (per_matmul, sum));
    }

    /// Answer one codesign query (memoized).
    pub fn query(&self, spec: &OperatingPointSpec)
        -> Result<Arc<OperatingPoint>> {
        self.bump(|s| s.queries += 1);
        let key = spec.cache_key(&self.cfg);
        if let Some(p) = self.lookup(&key, spec) {
            return Ok(p);
        }
        let hw = self.hw_solve(spec)?;
        self.finish(spec, &key, hw)
    }

    /// The shared hardware solve behind a spec: served from the
    /// in-memory solve cache when only the eval settings differ.
    fn hw_solve(&self, spec: &OperatingPointSpec) -> Result<HwSolve> {
        let hkey = spec.hw_cache_key(&self.cfg);
        if let Some(hw) = self.hw_solves.lock().unwrap().get(&hkey) {
            return Ok(hw.clone());
        }
        let (per_fmac, _) = self.fmac(spec.dataset)?;
        let hw = solver::solve(
            self.params(),
            self.cfg.seed,
            self.cfg.mc_samples,
            &per_fmac,
            spec.k,
            spec.sigma,
            spec.phi,
        );
        self.bump(|s| s.solves += 1);
        self.hw_solves.lock().unwrap().insert(hkey, hw.clone());
        Ok(hw)
    }

    /// Answer a batch of independent queries, solving cache misses in
    /// parallel with scoped threads (the MC/pmap stage is embarrassingly
    /// parallel and dominates sweep wall time). Results match
    /// sequential [`DesignSession::query`] calls exactly: every solve
    /// seeds its PRNG streams from (config seed, matmul index) only, so
    /// thread scheduling cannot change an answer.
    pub fn query_many(&self, specs: &[OperatingPointSpec])
        -> Result<Vec<Arc<OperatingPoint>>> {
        self.bump(|s| s.queries += specs.len() as u64);
        let keys: Vec<String> =
            specs.iter().map(|s| s.cache_key(&self.cfg)).collect();
        let mut out: Vec<Option<Arc<OperatingPoint>>> = specs
            .iter()
            .zip(&keys)
            .map(|(s, k)| self.lookup(k, s))
            .collect();

        // one solve job per distinct *hardware* key among the misses
        // (eval variants of the same point share it)
        let hkeys: Vec<String> = specs
            .iter()
            .map(|s| s.hw_cache_key(&self.cfg))
            .collect();
        struct Job {
            hkey: String,
            base: AnalogParams,
            seed: u64,
            mc_samples: usize,
            per_fmac: Vec<Fmac>,
            k: usize,
            sigma: f64,
            phi: usize,
        }
        let mut jobs: Vec<Job> = vec![];
        let mut queued: HashSet<String> = HashSet::new();
        for (i, spec) in specs.iter().enumerate() {
            if out[i].is_some()
                || queued.contains(&hkeys[i])
                || self.hw_solves.lock().unwrap().contains_key(&hkeys[i])
            {
                continue;
            }
            // F_MAC extraction (and any training) happens here,
            // sequentially: the runtime is not thread-safe, the solve is.
            let (per_fmac, _) = self.fmac(spec.dataset)?;
            queued.insert(hkeys[i].clone());
            jobs.push(Job {
                hkey: hkeys[i].clone(),
                base: self.params(),
                seed: self.cfg.seed,
                mc_samples: self.cfg.mc_samples,
                per_fmac,
                k: spec.k,
                sigma: spec.sigma,
                phi: spec.phi,
            });
        }

        let solved: Mutex<Vec<(String, HwSolve)>> =
            Mutex::new(Vec::with_capacity(jobs.len()));
        if !jobs.is_empty() {
            let n_workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(jobs.len());
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..n_workers {
                    // handles are joined by the scope itself
                    let _ = scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let j = &jobs[i];
                        let hw = solver::solve(
                            j.base,
                            j.seed,
                            j.mc_samples,
                            &j.per_fmac,
                            j.k,
                            j.sigma,
                            j.phi,
                        );
                        solved.lock().unwrap().push((j.hkey.clone(), hw));
                    });
                }
            });
            self.bump(|s| s.solves += jobs.len() as u64);
            let mut hw_solves = self.hw_solves.lock().unwrap();
            for (hkey, hw) in solved.into_inner().unwrap() {
                hw_solves.insert(hkey, hw);
            }
        }

        // finish in request order (accuracy evaluation is sequential:
        // one PJRT client); duplicates of an already-finished key are
        // served from memory
        for (i, spec) in specs.iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            if let Some(p) = self.points.get_memory(&keys[i]) {
                out[i] = Some(p);
                continue;
            }
            let hw = self
                .hw_solves
                .lock()
                .unwrap()
                .get(&hkeys[i])
                .cloned()
                .expect("a solve was queued for every miss");
            out[i] = Some(self.finish(spec, &keys[i], hw)?);
        }
        Ok(out.into_iter().map(|p| p.expect("filled above")).collect())
    }

    fn lookup(&self, key: &str, spec: &OperatingPointSpec)
        -> Option<Arc<OperatingPoint>> {
        if let Some(p) = self.points.get_memory(key) {
            self.bump(|s| s.mem_hits += 1);
            return Some(p);
        }
        if let Some(p) = self.points.get_disk(key, spec) {
            self.bump(|s| s.disk_hits += 1);
            return Some(p);
        }
        None
    }

    /// Accuracy-evaluate (if requested), package, and cache one solved
    /// point.
    fn finish(
        &self,
        spec: &OperatingPointSpec,
        key: &str,
        hw: HwSolve,
    ) -> Result<Arc<OperatingPoint>> {
        let accuracy = match spec.eval {
            None => None,
            Some(e) => {
                let ds = spec.dataset.spec();
                let folded = self.folded(spec.dataset)?;
                let ev = self.evaluator()?;
                self.bump(|s| s.evals += 1);
                Some(ev.accuracy_multi_seed(
                    ds.model,
                    folded.as_slice(),
                    ds.clone(),
                    &hw.ems,
                    self.cfg.eval_limit,
                    e.n_seeds,
                    e.seed,
                )?)
            }
        };
        let point =
            Arc::new(OperatingPoint::from_solve(*spec, hw, accuracy));
        self.points.put(key, point.clone())?;
        Ok(point)
    }

    fn bump(&self, f: impl FnOnce(&mut SessionStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }
}
