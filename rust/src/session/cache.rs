//! Operating-point memoization: an in-memory map in front of the
//! on-disk `runs/points/` directory (DESIGN.md §7).
//!
//! Entries are keyed by the spec's content-addressed key; a disk entry
//! is trusted only if its embedded spec matches the request (collision
//! and stale-format guard). Corrupt or mismatched files are treated as
//! misses and overwritten on the next store.

use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::point::OperatingPoint;
use super::spec::OperatingPointSpec;
use crate::util::json::Json;

pub struct PointCache {
    dir: PathBuf,
    /// When false, the disk layer is bypassed entirely (benchmarks and
    /// cold-path measurements; `--no-point-cache` on the CLI).
    persist: bool,
    mem: Mutex<HashMap<String, Arc<OperatingPoint>>>,
}

impl PointCache {
    pub fn new(dir: PathBuf, persist: bool) -> PointCache {
        PointCache {
            dir,
            persist,
            mem: Mutex::new(HashMap::new()),
        }
    }

    pub fn path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    pub fn get_memory(&self, key: &str) -> Option<Arc<OperatingPoint>> {
        self.mem.lock().unwrap().get(key).cloned()
    }

    /// Disk probe: parse + spec check; promotes a hit into memory.
    pub fn get_disk(
        &self,
        key: &str,
        spec: &OperatingPointSpec,
    ) -> Option<Arc<OperatingPoint>> {
        if !self.persist {
            return None;
        }
        let text = fs::read_to_string(self.path(key)).ok()?;
        let json = Json::parse(&text).ok()?;
        let point = OperatingPoint::from_json(&json).ok()?;
        if point.spec != *spec {
            return None;
        }
        let point = Arc::new(point);
        self.mem
            .lock()
            .unwrap()
            .insert(key.to_string(), point.clone());
        Some(point)
    }

    /// Insert into memory and atomically onto disk: the JSON is
    /// written to a tmp file *unique to this writer* (pid + a process
    /// counter), then renamed over `<key>.json`. Rename is atomic on
    /// POSIX, so a concurrently-serving process (or a second CLI run
    /// over the same run dir) can never read a torn point file — and
    /// because the tmp name is unique, two racing writers of the same
    /// key can't rename each other's half-written tmp either; last
    /// rename wins with both files complete.
    pub fn put(&self, key: &str, point: Arc<OperatingPoint>)
        -> Result<()> {
        if self.persist {
            fs::create_dir_all(&self.dir)?;
            static SEQ: AtomicU64 = AtomicU64::new(0);
            let tmp = self.dir.join(format!(
                "{key}.{}.{}.tmp",
                std::process::id(),
                SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            fs::write(&tmp, point.to_json().to_string())?;
            fs::rename(tmp, self.path(key))?;
        }
        crate::obs::registry::inc("session.cache.stores");
        self.mem
            .lock()
            .unwrap()
            .insert(key.to_string(), point);
        Ok(())
    }

    /// Insert into memory only, regardless of the persist setting —
    /// points evaluated on the untrained fallback model must never
    /// reach `runs/points/` (their key doesn't encode model content,
    /// so a later session with real trained weights would replay the
    /// near-chance accuracy as if trained).
    pub fn put_memory(&self, key: &str, point: Arc<OperatingPoint>) {
        self.mem
            .lock()
            .unwrap()
            .insert(key.to_string(), point);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::params::AnalogParams;
    use crate::capmin::Fmac;
    use crate::data::synth::Dataset;
    use crate::session::solver::solve;

    fn test_point(k: usize) -> (OperatingPointSpec, Arc<OperatingPoint>) {
        let spec = OperatingPointSpec::new(Dataset::FashionSyn, k, 0.0, 0);
        let hw = solve(
            AnalogParams::paper_calibrated(),
            1,
            crate::analog::montecarlo::McSettings::paper(50),
            1,
            &[Fmac::gaussian(16, 2.0, 1e8)],
            k,
            0.0,
            0,
        );
        (
            spec,
            Arc::new(OperatingPoint::from_solve(
                spec,
                hw,
                None,
                Default::default(),
            )),
        )
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "capmin_pointcache_{tag}_{}",
            std::process::id()
        ))
    }

    #[test]
    fn disk_roundtrip_and_spec_guard() {
        let dir = tmp_dir("rt");
        let _ = fs::remove_dir_all(&dir);
        let cache = PointCache::new(dir.clone(), true);
        let (spec, point) = test_point(14);
        cache.put("abc", point.clone()).unwrap();
        // fresh cache over the same dir: memory cold, disk warm
        let cold = PointCache::new(dir.clone(), true);
        assert!(cold.get_memory("abc").is_none());
        let hit = cold.get_disk("abc", &spec).unwrap();
        assert_eq!(*hit, *point);
        // after the disk hit the entry is promoted to memory
        assert!(cold.get_memory("abc").is_some());
        // a different spec under the same key is rejected
        let other = OperatingPointSpec::new(Dataset::FashionSyn, 8, 0.0, 0);
        assert!(cold.get_disk("abc", &other).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_entries_are_misses() {
        let dir = tmp_dir("corrupt");
        let _ = fs::remove_dir_all(&dir);
        let cache = PointCache::new(dir.clone(), true);
        fs::create_dir_all(&dir).unwrap();
        fs::write(cache.path("bad"), "{not json").unwrap();
        let (spec, _) = test_point(14);
        assert!(cache.get_disk("bad", &spec).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn racing_writers_never_tear_a_point_file_or_leave_tmps() {
        let dir = tmp_dir("race");
        let _ = fs::remove_dir_all(&dir);
        let cache = PointCache::new(dir.clone(), true);
        let (spec, point) = test_point(14);
        // many threads hammering the same key: every interleaving must
        // leave a complete, parseable file (unique tmp names mean no
        // writer can rename another's half-written file)
        std::thread::scope(|s| {
            for _ in 0..8 {
                let cache = &cache;
                let point = point.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        cache.put("hot", point.clone()).unwrap();
                    }
                });
            }
        });
        let cold = PointCache::new(dir.clone(), true);
        let hit = cold.get_disk("hot", &spec).expect("parseable file");
        assert_eq!(*hit, *point);
        // no tmp litter once the writers are done
        let tmps: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().map(|x| x == "tmp").unwrap_or(false)
            })
            .collect();
        assert!(tmps.is_empty(), "{tmps:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn persist_false_skips_disk() {
        let dir = tmp_dir("nopersist");
        let _ = fs::remove_dir_all(&dir);
        let cache = PointCache::new(dir.clone(), false);
        let (spec, point) = test_point(14);
        cache.put("xyz", point).unwrap();
        assert!(!cache.path("xyz").exists());
        assert!(cache.get_memory("xyz").is_some());
        assert!(cache.get_disk("xyz", &spec).is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}
