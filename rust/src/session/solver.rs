//! The pure hardware solve behind an operating-point query (DESIGN.md
//! §3): CapMin windows -> shared capacitor -> spike-time sets ->
//! (CapMin-V merging) -> Monte-Carlo error models.
//!
//! This is deliberately free of the runtime, the store and the session
//! itself: it is pure CPU work on plain data, which is what lets
//! `DesignSession::query_many` fan it out across threads (the MC stage
//! dominates the fig8 / sigma-sweep wall time).

use crate::analog::capacitor::{CapacitorModel, CapacitorSolver};
use crate::analog::montecarlo::{McSettings, MonteCarlo};
use crate::analog::neuron::SpikeTimeSet;
use crate::analog::params::AnalogParams;
use crate::analog::pmap::Pmap;
use crate::bnn::ErrorModel;
use crate::capmin::{capmin::select_window, capmin_v::capmin_v, Fmac};
use crate::capmin::CapMinResult;
use crate::util::rng::Rng;

/// One solved hardware read-out configuration: shared capacitor plus
/// per-matmul windows, spike-time sets and error models. The in-memory
/// twin of [`super::OperatingPoint`] before accuracy evaluation;
/// `Clone` lets the session reuse one solve across eval variants.
#[derive(Clone)]
pub struct HwSolve {
    /// Shared membrane capacitance [F] (sized by the topmost window).
    pub c: f64,
    /// CapMin window per matmul.
    pub windows: Vec<CapMinResult>,
    /// Spike-time set per matmul (post CapMin-V merging when phi > 0).
    pub sets: Vec<SpikeTimeSet>,
    /// Error model per matmul (the eval artifacts' runtime input).
    pub ems: Vec<ErrorModel>,
    /// Normal draws the Monte-Carlo stages actually consumed, summed
    /// over every pmap/full_map of the solve — provenance recorded in
    /// `PointMeta` (never cache-key material; fast mode's adaptive
    /// stopping makes it data-dependent).
    pub mc_draws: u64,
}

impl HwSolve {
    /// Guaranteed response time of the slowest window (system latency).
    pub fn grt(&self) -> f64 {
        self.sets.iter().map(|s| s.grt()).fold(0.0f64, f64::max)
    }

    /// The peak (topmost) window — what drives the capacitor.
    pub fn peak_window(&self) -> &CapMinResult {
        self.windows
            .iter()
            .max_by_key(|w| w.q_hi)
            .expect("at least one matmul")
    }
}

/// Solve the full hardware read-out configuration for one model at
/// CapMin parameter k: per-matmul windows, one shared capacitor, and the
/// per-matmul error models the eval artifacts consume.
///
/// The IF-SNN has ONE membrane capacitor, but the spike-time decoder
/// is digital and per layer: a matmul whose reduction length only
/// reaches level 9 (grayscale first conv, beta = 9) keeps its own
/// narrow window instead of being wiped out by the peak-centered
/// global window. The capacitor is sized by the most demanding
/// window (largest q_hi) — lower windows have wider time gaps and
/// ride along for free. `phi > 0` applies CapMin-V merging to each
/// window (clamped to its size). `sigma = 0` yields the
/// deterministic Eq.-4 clipping maps (exactly, with zero draws, in
/// every [`McSettings::mode`]).
///
/// `seed`, `mc` and `threads` come from the session's
/// `ExperimentConfig`; the per-matmul MC streams derive
/// deterministically from (seed, matmul index, sample chunk / round)
/// alone, so within a mode the result is independent of which thread
/// runs the solve *and* of `threads` (pass 1 when the caller already
/// parallelizes across solves). Across modes the maps agree
/// statistically (TV distance under tolerance), not bitwise.
#[allow(clippy::too_many_arguments)]
pub fn solve(
    base: AnalogParams,
    seed: u64,
    mc: McSettings,
    threads: usize,
    per_fmac: &[Fmac],
    k: usize,
    sigma: f64,
    phi: usize,
) -> HwSolve {
    let pool = if threads == 1 {
        crate::util::pool::ScopedPool::sequential()
    } else {
        crate::util::pool::ScopedPool::new(threads)
    };
    solve_on(&pool, base, seed, mc, per_fmac, k, sigma, phi)
}

/// [`solve`] on a caller-supplied pool: a long-running session (or
/// server) fans its Monte-Carlo stages over one persistent crew
/// instead of constructing threads per solve (DESIGN.md §12).
#[allow(clippy::too_many_arguments)]
pub fn solve_on(
    pool: &crate::util::pool::ScopedPool,
    base: AnalogParams,
    seed: u64,
    mc: McSettings,
    per_fmac: &[Fmac],
    k: usize,
    sigma: f64,
    phi: usize,
) -> HwSolve {
    let p = base.with_sigma(sigma);
    // captured before `mc` is shadowed by the MonteCarlo engine below
    let mode_name = mc.mode.name();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    let windows: Vec<_> = per_fmac
        .iter()
        .map(|f| select_window(f, k))
        .collect();
    let c = windows
        .iter()
        .map(|w| solver.size_for_window(w.q_lo, w.q_hi))
        .fold(0.0f64, f64::max);
    let mc = MonteCarlo::new(p)
        .with_settings(mc)
        .with_pool(pool.clone());
    let mut sets = Vec::with_capacity(windows.len());
    let mut ems = Vec::with_capacity(windows.len());
    let mut mc_draws = 0u64;
    for (i, w) in windows.iter().enumerate() {
        let base_set = SpikeTimeSet::new(&p, c, w.levels());
        let levels = if phi > 0 {
            let (pmap, d): (Pmap, u64) = mc.pmap_counted(
                &base_set,
                &mut Rng::new(seed ^ 0x5107 ^ i as u64),
            );
            mc_draws += d;
            let res = capmin_v(pmap, phi.min(w.k - 1));
            res.levels
        } else {
            w.levels()
        };
        let set = SpikeTimeSet::new(&p, c, levels);
        // sigma == 0 short-circuits inside full_map_counted to the
        // exact clean map with zero draws
        let (full, d) = mc.full_map_counted(
            &set,
            &mut Rng::new(seed ^ 0x4D43 ^ (i as u64) << 8),
        );
        mc_draws += d;
        ems.push(ErrorModel::from_full(&full));
        sets.push(set);
    }
    // per-mode draw accounting (DESIGN.md §17): analytic mode shows up
    // as a zero-increment series only if ever created — add() creates
    // the counter even for 0 so the exposition lists the mode used
    crate::obs::registry::add(
        &format!("mc.draws.{mode_name}"),
        mc_draws,
    );
    HwSolve {
        c,
        windows,
        sets,
        ems,
        mc_draws,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::montecarlo::McMode;

    #[test]
    fn solve_is_deterministic_across_thread_counts() {
        let p = AnalogParams::paper_calibrated();
        let fmacs =
            vec![Fmac::gaussian(5, 2.0, 1e8), Fmac::gaussian(16, 2.0, 1e8)];
        for mode in [McMode::Paper, McMode::Fast, McMode::Analytic] {
            let mc = McSettings {
                mode,
                ..McSettings::paper(200)
            };
            let a = solve(p, 42, mc, 1, &fmacs, 14, 0.02, 0);
            let b = solve(p, 42, mc, 2, &fmacs, 14, 0.02, 0);
            assert_eq!(a.c, b.c);
            assert_eq!(a.windows, b.windows);
            assert_eq!(a.mc_draws, b.mc_draws, "{mode:?}");
            for (x, y) in a.ems.iter().zip(b.ems.iter()) {
                assert_eq!(x.cdf, y.cdf, "{mode:?}");
                assert_eq!(x.vals, y.vals, "{mode:?}");
            }
        }
    }

    #[test]
    fn capacitor_sized_by_peak_window() {
        let p = AnalogParams::paper_calibrated();
        let fmacs =
            vec![Fmac::gaussian(5, 2.0, 1e8), Fmac::gaussian(16, 2.0, 1e8)];
        let hw = solve(p, 42, McSettings::paper(100), 1, &fmacs, 10, 0.0, 0);
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let w = hw.peak_window();
        assert_eq!(hw.c, solver.size_for_window(w.q_lo, w.q_hi));
        assert!(hw.grt() > 0.0);
    }

    #[test]
    fn phi_thins_the_readout() {
        let p = AnalogParams::paper_calibrated();
        let fmacs = vec![Fmac::gaussian(16, 2.0, 1e8)];
        let hw = solve(p, 42, McSettings::paper(200), 1, &fmacs, 16, 0.02, 2);
        assert_eq!(hw.windows[0].k, 16);
        assert_eq!(hw.sets[0].levels.len(), 14);
    }

    #[test]
    fn sigma_zero_solve_consumes_no_draws() {
        let p = AnalogParams::paper_calibrated();
        let fmacs = vec![Fmac::gaussian(16, 2.0, 1e8)];
        let hw = solve(p, 42, McSettings::paper(100), 1, &fmacs, 10, 0.0, 0);
        assert_eq!(hw.mc_draws, 0);
    }

    #[test]
    fn draw_accounting_orders_analytic_fast_paper() {
        let p = AnalogParams::paper_calibrated();
        let fmacs = vec![Fmac::gaussian(16, 2.0, 1e8)];
        let draws = |mode| {
            let mc = McSettings {
                mode,
                ..McSettings::paper(1000)
            };
            solve(p, 42, mc, 1, &fmacs, 14, 0.02, 2).mc_draws
        };
        let paper = draws(McMode::Paper);
        let fast = draws(McMode::Fast);
        let analytic = draws(McMode::Analytic);
        assert_eq!(analytic, 0);
        assert!(fast > 0);
        assert!(
            paper as f64 / fast as f64 >= 3.0,
            "paper {paper} vs fast {fast}"
        );
    }
}
