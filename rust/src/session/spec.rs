//! Typed operating-point requests and their content-addressed cache
//! keys (DESIGN.md §3).

use anyhow::{anyhow, Result};

use crate::coordinator::config::ExperimentConfig;
use crate::data::synth::Dataset;
use crate::util::hash::hex16;
use crate::util::json::{obj, Json};

/// How (and whether) a queried operating point is accuracy-evaluated:
/// `n_seeds` PRNG seeds starting at `seed` (the paper averages 3 runs
/// for the variation curves), mean-reduced. `n_seeds = 1` is a single
/// evaluation at `seed`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalSettings {
    pub seed: u32,
    pub n_seeds: usize,
}

/// One codesign query: "give me the hardware operating point of
/// `dataset`'s model at CapMin parameter `k`, current variation
/// `sigma`, and `phi` CapMin-V merges".
///
/// With `eval: None` the query is a pure hardware solve (windows,
/// capacitor, spike times, error models) and never touches the PJRT
/// runtime; with `eval: Some(..)` the resulting error models are pushed
/// through the eval artifact and the point carries an accuracy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPointSpec {
    pub dataset: Dataset,
    /// CapMin inclusion parameter (spike times kept), 1..=32.
    pub k: usize,
    /// Relative current variation sigma (0 = deterministic clipping).
    pub sigma: f64,
    /// CapMin-V merges applied per window (0 = plain CapMin).
    pub phi: usize,
    pub eval: Option<EvalSettings>,
}

impl OperatingPointSpec {
    pub fn new(
        dataset: Dataset,
        k: usize,
        sigma: f64,
        phi: usize,
    ) -> OperatingPointSpec {
        OperatingPointSpec {
            dataset,
            k,
            sigma,
            phi,
            eval: None,
        }
    }

    /// Request accuracy evaluation over `n_seeds` seeds from `seed`.
    pub fn with_eval(mut self, seed: u32, n_seeds: usize)
        -> OperatingPointSpec {
        self.eval = Some(EvalSettings { seed, n_seeds });
        self
    }

    /// Canonical material for the *hardware* half of the query:
    /// everything that can change the solve — the F_MACs (via the
    /// training knobs), the MC scale and mode, the base seed, and the
    /// spec's hardware axes — but not the eval settings. The `v3`
    /// prefix is the Monte-Carlo draw-schedule version: v2 chunked
    /// each level's samples into independently-seeded `MC_CHUNK`-draw
    /// streams; v3 adds the solve mode (`analog::montecarlo::McMode`)
    /// as key material — paper/fast/analytic maps agree statistically
    /// but not bitwise, so points from one mode never replay as
    /// another's. Fast mode also keys on its stopping tolerance; the
    /// draw count a fast solve *actually* used is data-dependent and
    /// deliberately excluded (it is provenance in `PointMeta`).
    fn hw_material(&self, cfg: &ExperimentConfig) -> String {
        let mode = if cfg.mc_mode == "fast" {
            format!("fast@{:e}", cfg.mc_tol)
        } else {
            cfg.mc_mode.clone()
        };
        format!(
            "v3|{}|k{}|sigma{:e}|phi{}|steps{}|lr{:e}|lrh{}|tl{}|hl{}|\
             mc{}|mode{}|seed{}",
            self.dataset.spec().name,
            self.k,
            self.sigma,
            self.phi,
            cfg.train_steps,
            cfg.lr0,
            cfg.lr_halve_every,
            cfg.train_limit,
            cfg.hist_limit,
            cfg.mc_samples,
            mode,
            cfg.seed,
        )
    }

    /// Key of the shared hardware solve: specs differing only in eval
    /// settings reuse one Monte-Carlo solve through the session's
    /// in-memory solve cache.
    pub fn hw_cache_key(&self, cfg: &ExperimentConfig) -> String {
        hex16(self.hw_material(cfg).as_bytes())
    }

    /// Content-addressed key of the full operating point: a 64-bit
    /// FNV-1a over the hardware material plus every knob that can
    /// change the accuracy (eval settings, eval scale, engine, and the
    /// *resolved* inference backend — `auto` hashes as whatever it
    /// picks on this build/machine). Two sessions with identical knobs
    /// share disk entries; any knob change misses cleanly. The worker
    /// thread count is deliberately absent: results are bit-identical
    /// at any thread count, so it is recorded as point *metadata*
    /// instead (DESIGN.md §9).
    pub fn cache_key(&self, cfg: &ExperimentConfig) -> String {
        let eval = match self.eval {
            None => "none".to_string(),
            Some(e) => format!("{}x{}", e.seed, e.n_seeds),
        };
        let material = format!(
            "{}|eval{}|el{}|engine{}|be{}",
            self.hw_material(cfg),
            eval,
            cfg.eval_limit,
            cfg.engine,
            crate::backend::BackendKind::resolve(cfg),
        );
        hex16(material.as_bytes())
    }

    pub fn to_json(&self) -> Json {
        let eval = match self.eval {
            None => Json::Null,
            Some(e) => obj(vec![
                ("seed", Json::Num(e.seed as f64)),
                ("n_seeds", Json::Num(e.n_seeds as f64)),
            ]),
        };
        obj(vec![
            ("dataset", Json::Str(self.dataset.spec().name.into())),
            ("k", Json::Num(self.k as f64)),
            ("sigma", Json::Num(self.sigma)),
            ("phi", Json::Num(self.phi as f64)),
            ("eval", eval),
        ])
    }

    pub fn from_json(j: &Json) -> Result<OperatingPointSpec> {
        let field = |k: &str| {
            j.get(k)
                .ok_or_else(|| anyhow!("spec JSON missing `{k}`"))
        };
        let name = match field("dataset")? {
            Json::Str(s) => s.as_str(),
            other => return Err(anyhow!("bad dataset field {other:?}")),
        };
        let dataset = Dataset::from_name(name)
            .ok_or_else(|| anyhow!("unknown dataset `{name}` in spec"))?;
        let num = |k: &str| -> Result<f64> {
            match field(k)? {
                Json::Num(n) => Ok(*n),
                other => Err(anyhow!("bad `{k}` field {other:?}")),
            }
        };
        let eval = match field("eval")? {
            Json::Null => None,
            e => Some(EvalSettings {
                seed: match e.get("seed") {
                    Some(Json::Num(n)) => *n as u32,
                    _ => return Err(anyhow!("bad eval.seed")),
                },
                n_seeds: match e.get("n_seeds") {
                    Some(Json::Num(n)) => *n as usize,
                    _ => return Err(anyhow!("bad eval.n_seeds")),
                },
            }),
        };
        Ok(OperatingPointSpec {
            dataset,
            k: num("k")? as usize,
            sigma: num("sigma")?,
            phi: num("phi")? as usize,
            eval,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        let s = OperatingPointSpec::new(Dataset::CifarSyn, 14, 0.02, 2)
            .with_eval(100, 3);
        let j = s.to_json();
        let back = OperatingPointSpec::from_json(&j).unwrap();
        assert_eq!(s, back);
        let hw = OperatingPointSpec::new(Dataset::FashionSyn, 16, 0.0, 0);
        let back =
            OperatingPointSpec::from_json(&hw.to_json()).unwrap();
        assert_eq!(hw, back);
    }

    #[test]
    fn cache_key_separates_specs_and_config() {
        let cfg = ExperimentConfig::default();
        let a = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
        let b = OperatingPointSpec::new(Dataset::FashionSyn, 16, 0.02, 0);
        assert_ne!(a.cache_key(&cfg), b.cache_key(&cfg));
        assert_ne!(
            a.cache_key(&cfg),
            a.with_eval(1, 1).cache_key(&cfg)
        );
        let mut cfg2 = cfg.clone();
        cfg2.mc_samples += 1;
        assert_ne!(a.cache_key(&cfg), a.cache_key(&cfg2));
        // stable across calls
        assert_eq!(a.cache_key(&cfg), a.cache_key(&cfg));
        assert_eq!(a.cache_key(&cfg).len(), 16);
    }

    #[test]
    fn mc_mode_is_key_material_but_draw_tallies_are_not() {
        let paper = ExperimentConfig::default();
        let a = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
        let mut fast = paper.clone();
        fast.mc_mode = "fast".into();
        let mut analytic = paper.clone();
        analytic.mc_mode = "analytic".into();
        // each mode keys separately (maps agree statistically, not
        // bitwise — stale points must never replay across modes)
        assert_ne!(a.hw_cache_key(&paper), a.hw_cache_key(&fast));
        assert_ne!(a.hw_cache_key(&paper), a.hw_cache_key(&analytic));
        assert_ne!(a.hw_cache_key(&fast), a.hw_cache_key(&analytic));
        // the fast stopping tolerance changes the answer -> keys
        let mut loose = fast.clone();
        loose.mc_tol = 0.05;
        assert_ne!(a.hw_cache_key(&fast), a.hw_cache_key(&loose));
        // ...but in paper/analytic mode the tolerance is inert
        let mut paper_tol = paper.clone();
        paper_tol.mc_tol = 0.05;
        assert_eq!(a.hw_cache_key(&paper), a.hw_cache_key(&paper_tol));
    }

    #[test]
    fn cache_key_tracks_the_resolved_backend_not_threads() {
        let a = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
        let mut native = ExperimentConfig::default();
        native.backend = "native".into();
        let mut xla = native.clone();
        xla.backend = "xla".into();
        assert_ne!(a.cache_key(&native), a.cache_key(&xla));
        // neither thread count nor kernel tier ever shifts a key
        // (results are bit-identical at any fan-out and tier)
        let mut threaded = native.clone();
        threaded.threads = 7;
        assert_eq!(a.cache_key(&native), a.cache_key(&threaded));
        let mut scalar = native.clone();
        scalar.kernel = "scalar".into();
        assert_eq!(a.cache_key(&native), a.cache_key(&scalar));
        // the register-blocking tile is provenance too, never a key
        // (DESIGN.md §14)
        let mut tiled = native.clone();
        tiled.tile = "4x8k32".into();
        assert_eq!(a.cache_key(&native), a.cache_key(&tiled));
        let mut safe = native.clone();
        safe.tile = "scalar-safe".into();
        assert_eq!(a.cache_key(&native), a.cache_key(&safe));
        // hardware half ignores the backend entirely
        assert_eq!(a.hw_cache_key(&native), a.hw_cache_key(&xla));
    }

    #[test]
    fn hw_key_ignores_eval_but_tracks_hardware_axes() {
        let cfg = ExperimentConfig::default();
        let a = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
        // same hardware point regardless of eval settings
        assert_eq!(
            a.hw_cache_key(&cfg),
            a.with_eval(100, 3).hw_cache_key(&cfg)
        );
        // but the full point key separates them
        assert_ne!(a.cache_key(&cfg), a.with_eval(100, 3).cache_key(&cfg));
        // hardware axes still miss cleanly
        let b = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.03, 0);
        assert_ne!(a.hw_cache_key(&cfg), b.hw_cache_key(&cfg));
    }
}
