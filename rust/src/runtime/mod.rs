//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the Rust hot path (pattern from /opt/xla-example/load_hlo).
//!
//! HLO *text* is the interchange format — jax >= 0.5 serialized protos
//! carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see aot.py docstring).
//!
//! Everything touching the PJRT bridge sits behind the `xla` cargo
//! feature (DESIGN.md §9); the manifest view and the artifacts-dir
//! probe stay available so backend resolution (`--backend auto`) works
//! on native-only builds.

pub mod manifest;

#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::Path;
use std::path::PathBuf;
#[cfg(feature = "xla")]
use std::sync::Mutex;

#[cfg(feature = "xla")]
use anyhow::{anyhow, Context};
#[cfg(feature = "xla")]
use anyhow::Result;

pub use manifest::{ArtifactSig, DType, Manifest, ModelInfo, TensorSig};

/// Default artifacts directory: $CAPMIN_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("CAPMIN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "xla")]
/// A compiled artifact with its manifest signature.
pub struct Executable {
    pub sig: ArtifactSig,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl Executable {
    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.run_borrowed(&refs)
    }

    /// Same, over borrowed literals — the training loop feeds the previous
    /// step's outputs back without cloning the weight tensors.
    pub fn run_borrowed(&self, inputs: &[&xla::Literal])
        -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.sig.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.sig.path,
                self.sig.inputs.len(),
                inputs.len()
            ));
        }
        let bufs = self.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.sig.outputs.len() {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                self.sig.path,
                self.sig.outputs.len(),
                outs.len()
            ));
        }
        Ok(outs)
    }
}

#[cfg(feature = "xla")]
/// The runtime: one CPU PJRT client + a compile cache keyed by artifact
/// path (compilation happens once per process per artifact).
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

#[cfg(feature = "xla")]
impl Runtime {
    pub fn new() -> Result<Runtime> {
        Runtime::with_dir(&artifacts_dir())
    }

    pub fn with_dir(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)
            .map_err(|e| anyhow!("manifest: {e} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Load + compile (cached) the `kind` artifact of `model`.
    pub fn load(&self, model: &str, kind: &str)
        -> Result<std::sync::Arc<Executable>> {
        let sig = self
            .manifest
            .model(model)
            .artifacts
            .get(kind)
            .ok_or_else(|| anyhow!("no {kind} artifact for {model}"))?
            .clone();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get(&sig.path) {
                return Ok(e.clone());
            }
        }
        let path = self.dir.join(&sig.path);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        let exec = std::sync::Arc::new(Executable { sig, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(exec.sig.path.clone(), exec.clone());
        Ok(exec)
    }
}

// ----------------------------------------------------------------------
// Literal helpers.
// ----------------------------------------------------------------------

#[cfg(feature = "xla")]
/// f32 literal with shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(
        shape.iter().product::<usize>(),
        data.len(),
        "shape/data mismatch"
    );
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

#[cfg(feature = "xla")]
/// Zero-filled f32 literal (Adam state init).
pub fn lit_zeros(shape: &[usize]) -> Result<xla::Literal> {
    lit_f32(shape, &vec![0.0; shape.iter().product::<usize>().max(1)])
}

#[cfg(feature = "xla")]
/// Scalar literals.
pub fn lit_f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(feature = "xla")]
pub fn lit_u32_scalar(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(feature = "xla")]
/// u32 vector literal (PRNG keys).
pub fn lit_u32(shape: &[usize], data: &[u32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

#[cfg(feature = "xla")]
/// Extract an f32 literal to a host vector.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(feature = "xla")]
/// Scalar f32 extraction.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(feature = "xla")]
#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        let z = lit_zeros(&[4]).unwrap();
        assert_eq!(to_f32(&z).unwrap(), vec![0.0; 4]);
    }

    #[test]
    fn loads_and_runs_init_artifact() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::new().unwrap();
        let init = rt.load("vgg3_tiny", "init").unwrap();
        let key = lit_u32(&[2], &[0, 42]).unwrap();
        let outs = init.run(&[key]).unwrap();
        let mi = rt.manifest.model("vgg3_tiny");
        assert_eq!(outs.len(), mi.n_params + mi.n_state);
        // params are finite floats
        let w0 = to_f32(&outs[0]).unwrap();
        assert!(w0.iter().all(|v| v.is_finite()));
        assert!(w0.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn compile_cache_reuses_executables() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new().unwrap();
        let a = rt.load("vgg3_tiny", "init").unwrap();
        let b = rt.load("vgg3_tiny", "init").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn arity_errors_are_reported() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new().unwrap();
        let init = rt.load("vgg3_tiny", "init").unwrap();
        assert!(init.run(&[]).is_err());
    }
}
