//! Typed view of `artifacts/manifest.json` (written by python aot.py).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    U32,
    I32,
}

impl DType {
    fn parse(s: &str) -> DType {
        match s {
            "f32" => DType::F32,
            "u32" => DType::U32,
            "i32" => DType::I32,
            other => panic!("unknown dtype {other}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSig {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub kind: String,
    pub path: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub arch: String,
    pub description: String,
    pub in_shape: Vec<usize>,
    pub n_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub hist_batch: usize,
    pub n_params: usize,
    pub n_state: usize,
    pub n_folded: usize,
    pub n_matmuls: usize,
    pub mhl_b: f64,
    pub folded_names: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub model: String,
    pub shape: Vec<usize>,
    pub classes: usize,
    pub paper: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub full: bool,
    pub array_size: usize,
    pub models: BTreeMap<String, ModelInfo>,
    pub datasets: BTreeMap<String, DatasetInfo>,
}

fn tensor_sigs(j: &Json) -> Vec<TensorSig> {
    j.as_arr()
        .iter()
        .map(|t| TensorSig {
            name: t.req("name").as_str().to_string(),
            dtype: DType::parse(t.req("dtype").as_str()),
            shape: t
                .req("shape")
                .as_arr()
                .iter()
                .map(|d| d.as_usize())
                .collect(),
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e}"))?;
        let j = Json::parse(&text)?;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models").as_obj() {
            let mut artifacts = BTreeMap::new();
            for a in m.req("artifacts").as_arr() {
                let sig = ArtifactSig {
                    kind: a.req("kind").as_str().to_string(),
                    path: a.req("path").as_str().to_string(),
                    inputs: tensor_sigs(a.req("inputs")),
                    outputs: tensor_sigs(a.req("outputs")),
                };
                artifacts.insert(sig.kind.clone(), sig);
            }
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    arch: m.req("arch").as_str().to_string(),
                    description: m.req("description").as_str().to_string(),
                    in_shape: m
                        .req("in_shape")
                        .as_arr()
                        .iter()
                        .map(|d| d.as_usize())
                        .collect(),
                    n_classes: m.req("n_classes").as_usize(),
                    train_batch: m.req("train_batch").as_usize(),
                    eval_batch: m.req("eval_batch").as_usize(),
                    hist_batch: m.req("hist_batch").as_usize(),
                    n_params: m.req("n_params").as_usize(),
                    n_state: m.req("n_state").as_usize(),
                    n_folded: m.req("n_folded").as_usize(),
                    n_matmuls: m.req("n_matmuls").as_usize(),
                    mhl_b: m.req("mhl_b").as_f64(),
                    folded_names: m
                        .req("folded_names")
                        .as_arr()
                        .iter()
                        .map(|s| s.as_str().to_string())
                        .collect(),
                    artifacts,
                },
            );
        }
        let mut datasets = BTreeMap::new();
        for (name, d) in j.req("datasets").as_obj() {
            datasets.insert(
                name.clone(),
                DatasetInfo {
                    model: d.req("model").as_str().to_string(),
                    shape: d
                        .req("shape")
                        .as_arr()
                        .iter()
                        .map(|x| x.as_usize())
                        .collect(),
                    classes: d.req("classes").as_usize(),
                    paper: d.req("paper").as_str().to_string(),
                },
            );
        }
        Ok(Manifest {
            full: j.req("full").as_bool(),
            array_size: j.req("array_size").as_usize(),
            models,
            datasets,
        })
    }

    pub fn model(&self, name: &str) -> &ModelInfo {
        self.models
            .get(name)
            .unwrap_or_else(|| panic!("unknown model {name}"))
    }

    pub fn model_for_dataset(&self, ds: &str) -> &ModelInfo {
        let d = self
            .datasets
            .get(ds)
            .unwrap_or_else(|| panic!("unknown dataset {ds}"));
        self.model(&d.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.array_size, 32);
        assert!(m.models.contains_key("vgg3_tiny"));
        let t = m.model("vgg3_tiny");
        assert!(t.artifacts.len() >= 6);
        let eval = &t.artifacts["eval"];
        assert_eq!(eval.inputs.len(), t.n_folded + 4);
        assert_eq!(
            eval.inputs[t.n_folded + 1].shape,
            vec![t.n_matmuls, 33, 33],
            "per-matmul cdf input"
        );
        assert_eq!(m.datasets.len(), 5);
    }
}
