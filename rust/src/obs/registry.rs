//! Cross-layer metrics registry (DESIGN.md §17): named atomic
//! counters, gauges and power-of-two histograms, created on demand and
//! snapshotted without stopping writers. Handles are `Arc`s to plain
//! atomics, so the hot path is a single relaxed RMW — the registry
//! mutex is only taken when a handle is first resolved (or a snapshot
//! is built), never per increment.
//!
//! Naming convention: `layer.subsystem.metric` (e.g.
//! `session.cache.mem_hits`, `mc.draws.paper`, `serve.phase.queue_us`).
//! The process-global registry ([`global`]) aggregates series from
//! every layer; code that needs isolation (unit tests, the serve
//! metrics facade) builds private [`Registry`] instances instead.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::{obj, Json};

/// Monotone event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depths, freelist sizes).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }

    #[inline]
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if it is below (running-max tracker).
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Bounded increment: add 1 and return `true` iff the gauge is
    /// below `cap`. Lock-free CAS so the bound is exact under
    /// contention — this is the serve tier's admission primitive.
    pub fn try_raise(&self, cap: i64) -> bool {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            if cur >= cap {
                return false;
            }
            match self.0.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Power-of-two bucketed histogram: bucket `i` counts values in
/// `(2^(i-1), 2^i]` (bucket 0 counts zeros and ones). Quantiles
/// report the chosen bucket's upper bound `2^i` — coarse by design,
/// cheap to record, and honest about being an envelope (a p99 of
/// `4096` means "under 4.1 ms", not "exactly 4.096 ms"). Promoted
/// here from `serve/metrics.rs` so every layer shares one
/// implementation.
pub struct Hist {
    buckets: Vec<AtomicU64>,
}

impl Hist {
    pub fn new(n_buckets: usize) -> Hist {
        Hist {
            buckets: (0..n_buckets).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Ceil-log2 bucket index: the smallest `i` with `v <= 2^i`
    /// (clamped into the last bucket).
    fn bucket_of(&self, v: u64) -> usize {
        let b = (64 - v.saturating_sub(1).leading_zeros()) as usize;
        b.min(self.buckets.len() - 1)
    }

    pub fn record(&self, v: u64) {
        self.buckets[self.bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Upper bound of the bucket holding the q-quantile (0 when
    /// empty).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << (self.buckets.len() - 1)
    }

    /// Raw bucket counts, oldest bucket first (trailing zero buckets
    /// trimmed). Bucket `i` covers `(2^(i-1), 2^i]`.
    pub fn bucket_counts(&self) -> Vec<u64> {
        let mut counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        while counts.len() > 1 && counts.last() == Some(&0) {
            counts.pop();
        }
        counts
    }

    /// Raw bucket counts (trailing zero buckets trimmed).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.bucket_counts()
                .into_iter()
                .map(|c| Json::Num(c as f64))
                .collect(),
        )
    }

    /// Quantile summary used by registry snapshots.
    fn summary_json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("p50_le", Json::Num(self.quantile(0.5) as f64)),
            ("p90_le", Json::Num(self.quantile(0.9) as f64)),
            ("p99_le", Json::Num(self.quantile(0.99) as f64)),
        ])
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Hist>),
}

/// A named family of metrics. Most code uses the process-global
/// instance via the free functions below; serve tests build private
/// registries so parallel tests never see each other's counts.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            metrics: Mutex::new(BTreeMap::new()),
        }
    }

    /// Resolve (creating on first use) the counter called `name`. A
    /// name already registered as a different kind yields a detached
    /// handle that still counts but is not exported — callers are
    /// expected to keep one kind per name.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => c.clone(),
            _ => Arc::new(Counter::new()),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())))
        {
            Metric::Gauge(g) => g.clone(),
            _ => Arc::new(Gauge::new()),
        }
    }

    /// Resolve a histogram with `n_buckets` power-of-two buckets
    /// (ignored when the name already exists).
    pub fn hist(&self, name: &str, n_buckets: usize) -> Arc<Hist> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Hist(Arc::new(Hist::new(n_buckets))))
        {
            Metric::Hist(h) => h.clone(),
            _ => Arc::new(Hist::new(n_buckets)),
        }
    }

    /// One JSON object mapping every registered series to its current
    /// value: counters/gauges as numbers, histograms as
    /// `{count, p50_le, p90_le, p99_le}` summaries. Additive payload
    /// for the serve `Stats` reply.
    pub fn snapshot_json(&self) -> Json {
        let m = self.metrics.lock().unwrap();
        let mut out = BTreeMap::new();
        for (name, metric) in m.iter() {
            let v = match metric {
                Metric::Counter(c) => Json::Num(c.get() as f64),
                Metric::Gauge(g) => Json::Num(g.get() as f64),
                Metric::Hist(h) => h.summary_json(),
            };
            out.insert(name.clone(), v);
        }
        Json::Obj(out)
    }

    /// Prometheus text exposition (`capmin_` prefix, dots become
    /// underscores; histograms as cumulative `_bucket{le=...}` series
    /// plus `_count`).
    pub fn prom_text(&self) -> String {
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        for (name, metric) in m.iter() {
            let pname = prom_name(name);
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {pname} counter\n"));
                    out.push_str(&format!("{pname} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {pname} gauge\n"));
                    out.push_str(&format!("{pname} {}\n", g.get()));
                }
                Metric::Hist(h) => {
                    out.push_str(&format!("# TYPE {pname} histogram\n"));
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, c) in counts.iter().enumerate() {
                        cum += c;
                        out.push_str(&format!(
                            "{pname}_bucket{{le=\"{}\"}} {cum}\n",
                            1u64 << i
                        ));
                    }
                    out.push_str(&format!(
                        "{pname}_bucket{{le=\"+Inf\"}} {cum}\n"
                    ));
                    out.push_str(&format!("{pname}_count {cum}\n"));
                }
            }
        }
        out
    }
}

fn prom_name(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 7);
    s.push_str("capmin_");
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() {
            s.push(ch);
        } else {
            s.push('_');
        }
    }
    s
}

/// The process-global registry every layer reports into.
pub fn global() -> Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Registry::new())).clone()
}

/// `global().counter(name)` — convenience for cold resolution sites.
pub fn counter(name: &str) -> Arc<Counter> {
    global().counter(name)
}

pub fn gauge(name: &str) -> Arc<Gauge> {
    global().gauge(name)
}

pub fn hist(name: &str, n_buckets: usize) -> Arc<Hist> {
    global().hist(name, n_buckets)
}

/// Bump a global counter by `n`. Takes the registry mutex to resolve
/// the name — fine for per-request/per-solve sites; per-iteration hot
/// paths should cache the `Arc<Counter>` in a `OnceLock` instead.
pub fn add(name: &str, n: u64) {
    global().counter(name).add(n);
}

pub fn inc(name: &str) {
    add(name, 1);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_and_quantiles_envelope() {
        let h = Hist::new(12);
        for v in [1u64, 1, 1, 2, 3, 900] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        // p50 of {1,1,1,2,3,900}: 3rd value = 1 -> bucket upper 1
        assert_eq!(h.quantile(0.5), 1);
        // the outlier lands in [512,1024) -> upper bound 1024
        assert_eq!(h.quantile(1.0), 1024);
        assert_eq!(h.quantile(0.99), 1024);
        // zero treated as the smallest bucket, values beyond the last
        // bucket clamp into it
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 8);
    }

    #[test]
    fn registry_resolves_one_handle_per_name() {
        let r = Registry::new();
        let a = r.counter("layer.thing");
        let b = r.counter("layer.thing");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = r.gauge("layer.level");
        g.set(5);
        g.dec();
        assert_eq!(r.gauge("layer.level").get(), 4);
        g.set_max(2);
        assert_eq!(g.get(), 4);
        g.set_max(9);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn snapshot_and_prom_text_cover_all_kinds() {
        let r = Registry::new();
        r.counter("a.count").add(3);
        r.gauge("b.depth").set(2);
        let h = r.hist("c.lat_us", 12);
        h.record(1);
        h.record(3);
        let j = r.snapshot_json();
        assert_eq!(j.req("a.count").as_f64(), 3.0);
        assert_eq!(j.req("b.depth").as_f64(), 2.0);
        assert_eq!(j.req("c.lat_us").req("count").as_f64(), 2.0);
        let prom = r.prom_text();
        assert!(prom.contains("capmin_a_count 3"));
        assert!(prom.contains("# TYPE capmin_b_depth gauge"));
        assert!(prom.contains("capmin_c_lat_us_bucket{le=\"+Inf\"} 2"));
        assert!(prom.contains("capmin_c_lat_us_count 2"));
    }

    #[test]
    fn kind_mismatch_yields_detached_handle() {
        let r = Registry::new();
        r.counter("x").inc();
        // resolving "x" as a gauge must not panic or corrupt the
        // counter; it returns a detached handle
        let g = r.gauge("x");
        g.set(99);
        assert_eq!(r.snapshot_json().req("x").as_f64(), 1.0);
    }
}
