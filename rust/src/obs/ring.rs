//! Lock-free per-thread span ring buffer (DESIGN.md §17). Each
//! traced thread owns one [`SpanRing`]: a fixed array of atomic slots
//! written only by the owning thread and snapshotted by any reader
//! through a per-slot sequence counter (seqlock protocol — readers
//! discard slots whose sequence is odd or changed mid-read, so a
//! concurrent flush never blocks the hot path and never observes a
//! torn event). On overflow the ring wraps and keeps the newest
//! events; `pushed() - len()` is the drop count, reported by the
//! trace exporter so truncation is visible rather than silent.

use std::sync::atomic::{fence, AtomicU64, Ordering};

/// One completed span, as stored in (and read back from) a ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// Interned name id (`obs::name_of` resolves it).
    pub name: u32,
    /// Ring-owner thread id (dense obs-assigned id, not the OS tid).
    pub tid: u32,
    /// Request trace id (0 = untraced / process-local work).
    pub trace: u64,
    pub span: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
    /// Start, nanoseconds since the obs epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
}

#[derive(Default)]
struct Slot {
    /// 0 = never written; odd = write in progress; even = committed
    /// (value `2*(n+1)` for the n-th push overall).
    seq: AtomicU64,
    name_tid: AtomicU64,
    trace: AtomicU64,
    span: AtomicU64,
    parent: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
}

pub struct SpanRing {
    tid: u32,
    name: String,
    /// Total events ever pushed (monotone; head % cap is the next
    /// slot).
    head: AtomicU64,
    slots: Vec<Slot>,
}

impl SpanRing {
    pub fn new(tid: u32, name: String, cap: usize) -> SpanRing {
        SpanRing {
            tid,
            name,
            head: AtomicU64::new(0),
            slots: (0..cap.max(1)).map(|_| Slot::default()).collect(),
        }
    }

    pub fn tid(&self) -> u32 {
        self.tid
    }

    /// Thread name captured at registration (for trace metadata).
    pub fn thread_name(&self) -> &str {
        &self.name
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events pushed over the ring's lifetime.
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Events overwritten by wraparound (oldest-first eviction).
    pub fn dropped(&self) -> u64 {
        self.pushed().saturating_sub(self.slots.len() as u64)
    }

    /// Record one completed span. Called only by the owning thread —
    /// single-writer, so no CAS loop: bump head, mark the slot
    /// in-progress (odd seq), store fields, commit (even seq). No
    /// allocation, no lock. Field stores are `Release` so the odd
    /// marker is globally visible before any field of the new event —
    /// the reader-side acquire fence in [`SpanRing::snapshot`] then
    /// rejects any slot it caught mid-write.
    pub fn push(&self, ev: SpanEvent) {
        let n = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        let commit = 2 * (n + 1);
        slot.seq.store(commit - 1, Ordering::Release);
        slot.name_tid.store(
            ((self.tid as u64) << 32) | ev.name as u64,
            Ordering::Release,
        );
        slot.trace.store(ev.trace, Ordering::Release);
        slot.span.store(ev.span, Ordering::Release);
        slot.parent.store(ev.parent, Ordering::Release);
        slot.start_ns.store(ev.start_ns, Ordering::Release);
        slot.dur_ns.store(ev.dur_ns, Ordering::Release);
        slot.seq.store(commit, Ordering::Release);
    }

    /// Copy out every committed event, oldest first. Slots caught
    /// mid-write (or rewritten during the read) are skipped — a
    /// snapshot taken concurrently with pushes is approximate but
    /// never torn.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for slot in &self.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let name_tid = slot.name_tid.load(Ordering::Relaxed);
            let ev = SpanEvent {
                name: (name_tid & 0xffff_ffff) as u32,
                tid: (name_tid >> 32) as u32,
                trace: slot.trace.load(Ordering::Relaxed),
                span: slot.span.load(Ordering::Relaxed),
                parent: slot.parent.load(Ordering::Relaxed),
                start_ns: slot.start_ns.load(Ordering::Relaxed),
                dur_ns: slot.dur_ns.load(Ordering::Relaxed),
            };
            fence(Ordering::Acquire);
            let s2 = slot.seq.load(Ordering::Relaxed);
            if s1 == s2 {
                out.push((s1, ev));
            }
        }
        out.sort_by_key(|&(seq, _)| seq);
        out.into_iter().map(|(_, ev)| ev).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> SpanEvent {
        SpanEvent {
            name: 7,
            tid: 0,
            trace: 1,
            span: i,
            parent: 0,
            start_ns: i * 100,
            dur_ns: 10,
        }
    }

    #[test]
    fn push_and_snapshot_roundtrip() {
        let r = SpanRing::new(3, "t".into(), 8);
        for i in 1..=5 {
            r.push(ev(i));
        }
        let evs = r.snapshot();
        assert_eq!(evs.len(), 5);
        assert_eq!(r.pushed(), 5);
        assert_eq!(r.dropped(), 0);
        // oldest first, tid stamped by the ring
        assert_eq!(evs[0].span, 1);
        assert_eq!(evs[4].span, 5);
        assert!(evs.iter().all(|e| e.tid == 3));
    }

    #[test]
    fn wraparound_keeps_newest_events() {
        let r = SpanRing::new(0, "t".into(), 8);
        for i in 1..=20 {
            r.push(ev(i));
        }
        let evs = r.snapshot();
        assert_eq!(evs.len(), 8);
        assert_eq!(r.pushed(), 20);
        assert_eq!(r.dropped(), 12);
        let spans: Vec<u64> = evs.iter().map(|e| e.span).collect();
        assert_eq!(spans, (13..=20).collect::<Vec<u64>>());
    }

    #[test]
    fn concurrent_snapshot_never_tears() {
        use std::sync::Arc;
        let r = Arc::new(SpanRing::new(0, "t".into(), 16));
        let writer = {
            let r = r.clone();
            std::thread::spawn(move || {
                for i in 1..=20_000u64 {
                    // span and start encode the same index; a torn
                    // read would mix two pushes and break the pairing
                    r.push(SpanEvent {
                        name: 1,
                        tid: 0,
                        trace: 0,
                        span: i,
                        parent: 0,
                        start_ns: i,
                        dur_ns: i * 2,
                    });
                }
            })
        };
        for _ in 0..200 {
            for e in r.snapshot() {
                assert_eq!(e.span, e.start_ns, "torn slot read");
                assert_eq!(e.dur_ns, e.start_ns * 2, "torn slot read");
            }
        }
        writer.join().unwrap();
        assert_eq!(r.pushed(), 20_000);
    }
}
