//! Chrome-trace-event export and per-phase summaries (DESIGN.md
//! §17). [`chrome_trace_json`] drains every registered span ring into
//! the JSON object format understood by Perfetto and
//! `chrome://tracing`: one `"X"` (complete) event per span with
//! microsecond `ts`/`dur`, span/parent/trace ids in `args` as hex
//! strings, plus `"M"` metadata events naming each thread lane.
//! [`summarize`] folds the same events into per-phase self/total
//! tables for `capmin trace-summary`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{obj, Json};

use super::ring::SpanEvent;
use super::{all_rings, name_of};

fn hex(v: u64) -> Json {
    Json::Str(format!("{v:x}"))
}

/// Collect every committed span event from all thread rings, oldest
/// first per ring.
pub fn collect_events() -> Vec<SpanEvent> {
    let mut out = Vec::new();
    for ring in all_rings() {
        out.extend(ring.snapshot());
    }
    out
}

/// Total events evicted by ring wraparound across all threads.
pub fn dropped_events() -> u64 {
    all_rings().iter().map(|r| r.dropped()).sum()
}

/// Build the Chrome trace object from the given events plus thread
/// metadata from the ring registry.
pub fn chrome_trace_from(events: &[SpanEvent]) -> Json {
    let mut evs: Vec<Json> = Vec::with_capacity(events.len() + 8);
    for ring in all_rings() {
        evs.push(obj(vec![
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(ring.tid() as f64)),
            ("name", Json::Str("thread_name".into())),
            (
                "args",
                obj(vec![(
                    "name",
                    Json::Str(format!(
                        "{} (t{})",
                        ring.thread_name(),
                        ring.tid()
                    )),
                )]),
            ),
        ]));
    }
    for e in events {
        evs.push(obj(vec![
            ("ph", Json::Str("X".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(e.tid as f64)),
            ("name", Json::Str(name_of(e.name).to_string())),
            ("ts", Json::Num(e.start_ns as f64 / 1000.0)),
            ("dur", Json::Num(e.dur_ns as f64 / 1000.0)),
            (
                "args",
                obj(vec![
                    ("span", hex(e.span)),
                    ("parent", hex(e.parent)),
                    ("trace", hex(e.trace)),
                ]),
            ),
        ]));
    }
    obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".into())),
        ("droppedEvents", Json::Num(dropped_events() as f64)),
    ])
}

/// Snapshot all rings into a Chrome trace object.
pub fn chrome_trace_json() -> Json {
    chrome_trace_from(&collect_events())
}

/// Write the current trace to `path`, creating parent directories.
pub fn write_trace(path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, chrome_trace_json().to_string())
        .with_context(|| format!("writing trace {}", path.display()))?;
    Ok(())
}

/// `<run_dir>/trace/<unix-seconds>.trace.json`.
pub fn default_trace_path(run_dir: &str) -> std::path::PathBuf {
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    Path::new(run_dir)
        .join("trace")
        .join(format!("{ts}.trace.json"))
}

/// A span event as re-read from an exported trace file (names
/// resolved to strings, ids parsed back from hex).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEv {
    pub name: String,
    pub span: u64,
    pub parent: u64,
    pub trace: u64,
    pub dur_ns: u64,
}

/// Parse the `"X"` events out of a Chrome trace object (as written by
/// [`write_trace`]; metadata events are skipped).
pub fn parse_chrome_trace(j: &Json) -> Result<Vec<TraceEv>> {
    let evs = j
        .get("traceEvents")
        .ok_or_else(|| anyhow!("trace file has no traceEvents array"))?
        .as_arr();
    let id = |e: &Json, k: &str| -> u64 {
        e.get("args")
            .and_then(|a| a.get(k))
            .map(|v| u64::from_str_radix(v.as_str(), 16).unwrap_or(0))
            .unwrap_or(0)
    };
    let mut out = Vec::new();
    for e in evs {
        if e.get("ph").map(|p| p.as_str()) != Some("X") {
            continue;
        }
        out.push(TraceEv {
            name: e.req("name").as_str().to_string(),
            span: id(e, "span"),
            parent: id(e, "parent"),
            trace: id(e, "trace"),
            dur_ns: (e.req("dur").as_f64() * 1000.0) as u64,
        });
    }
    Ok(out)
}

/// One row of the `trace-summary` table.
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub name: String,
    pub count: u64,
    /// Wall time inside spans of this phase, children included.
    pub total_ms: f64,
    /// Wall time inside this phase excluding child spans present in
    /// the trace.
    pub self_ms: f64,
}

/// Aggregate events into per-phase self/total time, sorted by total
/// descending. Self time subtracts only children that survived ring
/// wraparound, so it is an upper bound under truncation.
pub fn summarize(events: &[TraceEv]) -> Vec<PhaseRow> {
    let mut child_ns: HashMap<u64, u64> = HashMap::new();
    for e in events {
        if e.parent != 0 {
            *child_ns.entry(e.parent).or_insert(0) += e.dur_ns;
        }
    }
    let mut rows: HashMap<&str, PhaseRow> = HashMap::new();
    for e in events {
        let own = e
            .dur_ns
            .saturating_sub(child_ns.get(&e.span).copied().unwrap_or(0));
        let row = rows.entry(e.name.as_str()).or_insert_with(|| PhaseRow {
            name: e.name.clone(),
            count: 0,
            total_ms: 0.0,
            self_ms: 0.0,
        });
        row.count += 1;
        row.total_ms += e.dur_ns as f64 / 1e6;
        row.self_ms += own as f64 / 1e6;
    }
    let mut out: Vec<PhaseRow> = rows.into_values().collect();
    out.sort_by(|a, b| {
        b.total_ms
            .partial_cmp(&a.total_ms)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    out
}

/// Render the summary table for the CLI.
pub fn render_summary(rows: &[PhaseRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<28} {:>8} {:>12} {:>12}\n",
        "phase", "count", "total_ms", "self_ms"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<28} {:>8} {:>12.3} {:>12.3}\n",
            r.name, r.count, r.total_ms, r.self_ms
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, span: u64, parent: u64, dur_ns: u64) -> TraceEv {
        TraceEv {
            name: name.to_string(),
            span,
            parent,
            trace: 1,
            dur_ns,
        }
    }

    #[test]
    fn summary_self_time_excludes_children() {
        let events = vec![
            ev("solve", 1, 0, 10_000_000),
            ev("mc", 2, 1, 6_000_000),
            ev("mc", 3, 1, 2_000_000),
        ];
        let rows = summarize(&events);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "solve");
        assert!((rows[0].total_ms - 10.0).abs() < 1e-9);
        assert!((rows[0].self_ms - 2.0).abs() < 1e-9);
        assert_eq!(rows[1].count, 2);
        assert!((rows[1].self_ms - 8.0).abs() < 1e-9);
        let table = render_summary(&rows);
        assert!(table.contains("solve"));
        assert!(table.contains("total_ms"));
    }

    #[test]
    fn chrome_trace_roundtrips_through_parse() {
        let j = obj(vec![
            (
                "traceEvents",
                Json::Arr(vec![
                    obj(vec![
                        ("ph", Json::Str("M".into())),
                        ("name", Json::Str("thread_name".into())),
                    ]),
                    obj(vec![
                        ("ph", Json::Str("X".into())),
                        ("name", Json::Str("solve".into())),
                        ("ts", Json::Num(1.5)),
                        ("dur", Json::Num(2.0)),
                        (
                            "args",
                            obj(vec![
                                ("span", Json::Str("a".into())),
                                ("parent", Json::Str("0".into())),
                                ("trace", Json::Str("ff".into())),
                            ]),
                        ),
                    ]),
                ]),
            ),
        ]);
        let evs = parse_chrome_trace(&j).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "solve");
        assert_eq!(evs[0].span, 0xa);
        assert_eq!(evs[0].trace, 0xff);
        assert_eq!(evs[0].dur_ns, 2000);
    }
}
