//! Unified telemetry (DESIGN.md §17): structured tracing spans over
//! lock-free per-thread ring buffers, a cross-layer metrics registry,
//! Chrome-trace export for Perfetto, and leveled logging — all
//! zero-dependency, all off by default.
//!
//! Hot-path contract: with tracing disabled (the default) a
//! [`span!`](crate::span!) callsite is a single relaxed atomic load
//! plus a no-op guard; with tracing enabled, entering and leaving a
//! span allocates nothing and takes no lock — it bumps two atomics
//! and writes one fixed-size slot into the current thread's
//! [`ring::SpanRing`].
//!
//! Trace ids are allocated per serve request at admission, carried
//! through batcher → session → solver → kernels (pool workers inherit
//! the spawning thread's span context, see `util/pool.rs`) and echoed
//! in replies as a hex string, so a slow request can be attributed to
//! queueing vs batching vs forward vs reply from the exported trace.

pub mod registry;
pub mod ring;
pub mod trace;

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use ring::{SpanEvent, SpanRing};

/// Events per thread ring; overflow wraps and keeps the newest
/// (DESIGN.md §17 sizing rationale).
pub const RING_CAP: usize = 8192;

// ---------------------------------------------------------------
// Monotonic clock
// ---------------------------------------------------------------

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process obs epoch (first clock use).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Nanoseconds-since-epoch of an `Instant` captured earlier (e.g. a
/// request's admission time). Saturates to 0 for pre-epoch instants.
pub fn ns_of(t: Instant) -> u64 {
    t.checked_duration_since(epoch())
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

// ---------------------------------------------------------------
// Tracing enable flag (THE disabled-mode fast path)
// ---------------------------------------------------------------

static TRACING: AtomicBool = AtomicBool::new(false);

#[inline]
pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Turn span collection on or off process-wide. Enabling pins the
/// obs epoch so all span timestamps share one origin.
pub fn set_tracing(on: bool) {
    if on {
        epoch();
    }
    TRACING.store(on, Ordering::Relaxed);
}

// ---------------------------------------------------------------
// Span-name interning
// ---------------------------------------------------------------

fn names() -> &'static Mutex<Vec<&'static str>> {
    static NAMES: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    NAMES.get_or_init(|| Mutex::new(Vec::new()))
}

/// Intern a span name, returning its dense id. Called once per
/// callsite (the [`span!`](crate::span!) macro caches the id in a
/// `OnceLock`), so the mutex here is cold.
pub fn intern(name: &'static str) -> u32 {
    let mut v = names().lock().unwrap();
    if let Some(i) = v.iter().position(|n| *n == name) {
        return i as u32;
    }
    v.push(name);
    (v.len() - 1) as u32
}

/// Resolve an interned id back to its name (`"?"` if unknown).
pub fn name_of(id: u32) -> &'static str {
    names()
        .lock()
        .unwrap()
        .get(id as usize)
        .copied()
        .unwrap_or("?")
}

// ---------------------------------------------------------------
// Per-thread ring + span context
// ---------------------------------------------------------------

fn rings() -> &'static Mutex<Vec<Arc<SpanRing>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<SpanRing>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Every ring ever registered (rings of finished threads survive via
/// the `Arc`, so pool workers' spans remain flushable).
pub fn all_rings() -> Vec<Arc<SpanRing>> {
    rings().lock().unwrap().clone()
}

struct ThreadCtx {
    ring: Arc<SpanRing>,
    trace_id: Cell<u64>,
    current_span: Cell<u64>,
}

impl ThreadCtx {
    fn register() -> ThreadCtx {
        static NEXT_TID: AtomicU32 = AtomicU32::new(0);
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current()
            .name()
            .unwrap_or("thread")
            .to_string();
        let ring = Arc::new(SpanRing::new(tid, name, RING_CAP));
        rings().lock().unwrap().push(ring.clone());
        ThreadCtx {
            ring,
            trace_id: Cell::new(0),
            current_span: Cell::new(0),
        }
    }
}

thread_local! {
    static CTX: ThreadCtx = ThreadCtx::register();
}

static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Allocate a request trace id (never 0; cheap enough to run even
/// with tracing disabled — the id is echoed in serve replies either
/// way). Mixed with the pid so ids from different shard processes
/// don't collide in a merged trace.
pub fn new_trace_id() -> u64 {
    let n = NEXT_TRACE.fetch_add(1, Ordering::Relaxed);
    ((std::process::id() as u64) << 40) | n
}

/// The ambient (trace id, current span) pair of this thread —
/// captured by pools before a fan-out and re-attached on workers so
/// child spans nest under the spawning span.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span: u64,
}

pub fn current_ctx() -> TraceCtx {
    if !tracing_enabled() {
        return TraceCtx::default();
    }
    CTX.with(|c| TraceCtx {
        trace_id: c.trace_id.get(),
        span: c.current_span.get(),
    })
}

/// RAII restore for [`TraceCtx::attach`].
pub struct CtxGuard {
    prev: TraceCtx,
    active: bool,
}

impl TraceCtx {
    /// Install this context on the current thread until the guard
    /// drops. A no-op (and allocation-free) when tracing is off.
    pub fn attach(self) -> CtxGuard {
        if !tracing_enabled() {
            return CtxGuard {
                prev: TraceCtx::default(),
                active: false,
            };
        }
        CTX.with(|c| {
            let prev = TraceCtx {
                trace_id: c.trace_id.get(),
                span: c.current_span.get(),
            };
            c.trace_id.set(self.trace_id);
            c.current_span.set(self.span);
            CtxGuard { prev, active: true }
        })
    }
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        if self.active {
            CTX.with(|c| {
                c.trace_id.set(self.prev.trace_id);
                c.current_span.set(self.prev.span);
            });
        }
    }
}

// ---------------------------------------------------------------
// Spans
// ---------------------------------------------------------------

struct ActiveSpan {
    name: u32,
    span_id: u64,
    parent: u64,
    trace_id: u64,
    start_ns: u64,
}

/// RAII span: records a completed event into the thread ring on drop.
/// Construct via the [`span!`](crate::span!) macro, which handles
/// name interning and the disabled-mode fast path.
pub struct SpanGuard {
    active: Option<ActiveSpan>,
}

impl SpanGuard {
    /// The no-op guard returned when tracing is off.
    #[inline]
    pub fn disabled() -> SpanGuard {
        SpanGuard { active: None }
    }

    /// Open a span starting now.
    pub fn enter(name: u32) -> SpanGuard {
        if !tracing_enabled() {
            return SpanGuard::disabled();
        }
        SpanGuard::enter_at(name, now_ns())
    }

    /// Open a span whose start predates this call (e.g. measured from
    /// a request's admission instant).
    pub fn enter_at(name: u32, start_ns: u64) -> SpanGuard {
        if !tracing_enabled() {
            return SpanGuard::disabled();
        }
        CTX.with(|c| {
            let span_id = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
            let parent = c.current_span.replace(span_id);
            SpanGuard {
                active: Some(ActiveSpan {
                    name,
                    span_id,
                    parent,
                    trace_id: c.trace_id.get(),
                    start_ns,
                }),
            }
        })
    }

    /// This span's id (0 when disabled) — attach it to a [`TraceCtx`]
    /// to parent work on other threads under this span.
    pub fn id(&self) -> u64 {
        self.active.as_ref().map(|a| a.span_id).unwrap_or(0)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(a) = self.active.take() {
            let end = now_ns();
            CTX.with(|c| {
                c.current_span.set(a.parent);
                c.ring.push(SpanEvent {
                    name: a.name,
                    tid: c.ring.tid(),
                    trace: a.trace_id,
                    span: a.span_id,
                    parent: a.parent,
                    start_ns: a.start_ns,
                    dur_ns: end.saturating_sub(a.start_ns),
                });
            });
        }
    }
}

/// Record an already-elapsed interval `[t0, now]` as a completed span
/// under the current context (used for queue-time spans whose start
/// was stamped on another thread). Returns the span id (0 when
/// tracing is off).
pub fn record_since(name: u32, t0: Instant) -> u64 {
    if !tracing_enabled() {
        return 0;
    }
    let start_ns = ns_of(t0);
    let end = now_ns();
    CTX.with(|c| {
        let span_id = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
        c.ring.push(SpanEvent {
            name,
            tid: c.ring.tid(),
            trace: c.trace_id.get(),
            span: span_id,
            parent: c.current_span.get(),
            start_ns,
            dur_ns: end.saturating_sub(start_ns),
        });
        span_id
    })
}

/// Open a lexically scoped span. `$name` must be a string literal;
/// the interned id is cached per callsite, and the disabled path is
/// one relaxed atomic load.
#[macro_export]
macro_rules! span {
    ($name:literal) => {{
        if $crate::obs::tracing_enabled() {
            static __SPAN_ID: std::sync::OnceLock<u32> =
                std::sync::OnceLock::new();
            $crate::obs::SpanGuard::enter(
                *__SPAN_ID.get_or_init(|| $crate::obs::intern($name)),
            )
        } else {
            $crate::obs::SpanGuard::disabled()
        }
    }};
}

/// Record the interval from `$t0` (an `Instant`) to now as a closed
/// span under the current context; evaluates to the span id.
#[macro_export]
macro_rules! span_since {
    ($name:literal, $t0:expr) => {{
        if $crate::obs::tracing_enabled() {
            static __SPAN_ID: std::sync::OnceLock<u32> =
                std::sync::OnceLock::new();
            $crate::obs::record_since(
                *__SPAN_ID.get_or_init(|| $crate::obs::intern($name)),
                $t0,
            )
        } else {
            0u64
        }
    }};
}

// ---------------------------------------------------------------
// Leveled logging
// ---------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl LogLevel {
    pub const CHOICES: [&'static str; 4] =
        ["error", "warn", "info", "debug"];

    pub fn parse(s: &str) -> Option<LogLevel> {
        match s {
            "error" => Some(LogLevel::Error),
            "warn" => Some(LogLevel::Warn),
            "info" => Some(LogLevel::Info),
            "debug" => Some(LogLevel::Debug),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        Self::CHOICES[self as usize]
    }
}

static LOG_LEVEL: AtomicU8 = AtomicU8::new(LogLevel::Info as u8);

pub fn set_log_level(l: LogLevel) {
    LOG_LEVEL.store(l as u8, Ordering::Relaxed);
}

#[inline]
pub fn log_enabled(l: LogLevel) -> bool {
    (l as u8) <= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Emit one formatted log line to stderr:
/// `[<secs-since-start> LEVEL target] message`.
pub fn log_line(l: LogLevel, target: &str, msg: &str) {
    eprintln!(
        "[{:10.3} {:<5} {}] {}",
        epoch().elapsed().as_secs_f64(),
        l.name(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::LogLevel::Error) {
            $crate::obs::log_line(
                $crate::obs::LogLevel::Error,
                $target,
                &format!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::LogLevel::Warn) {
            $crate::obs::log_line(
                $crate::obs::LogLevel::Warn,
                $target,
                &format!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::LogLevel::Info) {
            $crate::obs::log_line(
                $crate::obs::LogLevel::Info,
                $target,
                &format!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::LogLevel::Debug) {
            $crate::obs::log_line(
                $crate::obs::LogLevel::Debug,
                $target,
                &format!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        // tracing defaults off: guards are no-ops and allocate no ids
        assert!(!tracing_enabled());
        let g = crate::span!("test.disabled");
        assert_eq!(g.id(), 0);
        drop(g);
        assert_eq!(crate::span_since!("test.disabled", Instant::now()), 0);
        assert_eq!(current_ctx(), TraceCtx::default());
    }

    #[test]
    fn intern_is_stable_and_resolvable() {
        let a = intern("test.alpha");
        let b = intern("test.beta");
        assert_ne!(a, b);
        assert_eq!(intern("test.alpha"), a);
        assert_eq!(name_of(a), "test.alpha");
        assert_eq!(name_of(u32::MAX), "?");
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = new_trace_id();
        let b = new_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn log_levels_order_and_parse() {
        assert!(LogLevel::Error < LogLevel::Debug);
        assert_eq!(LogLevel::parse("warn"), Some(LogLevel::Warn));
        assert_eq!(LogLevel::parse("nope"), None);
        assert_eq!(LogLevel::Debug.name(), "debug");
    }
}
