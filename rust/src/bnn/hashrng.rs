//! Rust port of `python/compile/kernels/hashrng.py` — must stay
//! bit-identical (the cross-language tests depend on it).

const GOLDEN: u32 = 0x9E37_79B9;
const M1: u32 = 0x85EB_CA6B;
const M2: u32 = 0xC2B2_AE35;

/// Murmur3 finalizer over a u32 index stream, keyed by `seed`.
#[inline]
pub fn hash_u32(seed: u32, idx: u32) -> u32 {
    let mut x = idx.wrapping_add(seed.wrapping_mul(GOLDEN));
    x ^= x >> 16;
    x = x.wrapping_mul(M1);
    x ^= x >> 13;
    x = x.wrapping_mul(M2);
    x ^= x >> 16;
    x
}

/// Uniform f32 in [0, 1) from the top 24 bits (exact in f32; matches the
/// kernel's `hash01`).
#[inline]
pub fn hash01(seed: u32, idx: u32) -> f32 {
    (hash_u32(seed, idx) >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // murmur3 finalizer of 0 is 0 (the u=0 case the CDF inversion
        // handles with `<=`)
        assert_eq!(hash_u32(0, 0), 0);
        assert_eq!(hash01(0, 0), 0.0);
        // distinct seeds/indices decorrelate
        assert_ne!(hash_u32(1, 0), hash_u32(0, 1));
    }

    #[test]
    fn range() {
        for i in 0..10_000u32 {
            let u = hash01(7, i);
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn matches_python_reference_values() {
        // pinned from python/compile/kernels/hashrng.py (the L1 kernel's
        // PRNG); any drift here breaks rust <-> artifact bit-equality
        assert_eq!(hash_u32(7, 0), 0x78bc_1b8f);
        assert_eq!(hash_u32(7, 1), 0xf8ed_16a2);
        assert_eq!(hash_u32(7, 2), 0x78c8_af1a);
        assert_eq!(hash_u32(7, 3), 0x21dc_9daa);
        assert_eq!(hash_u32(123_456_789, 1_000_000), 0xf87a_f45f);
        assert!((hash01(7, 42) - 0.131_385_505).abs() < 1e-9);
    }
}
