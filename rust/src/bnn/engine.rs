//! Bit-packed sub-MAC matmul engine — the Rust twin of the L1 kernel.
//!
//! Semantics (identical to `python/compile/kernels/ref.py`):
//!   out[o][d] = 2 * sum_g decode(level_g(o, d), u(o, g, d)) - beta
//! where `decode` inverts the 33x33 row-CDF of the error model with the
//! shared counter-based PRNG. With the identity model this is the exact
//! +-1 dot product. The engine exists to (a) cross-check the AOT
//! artifacts bit-for-bit, (b) serve as the host-engine baseline the
//! paper replaces, and (c) run large sweeps at native speed.

use super::bitpack::{group_level, row_group, BitMatrix};
use super::hashrng::hash01;
use crate::capmin::N_LEVELS;

/// 33x33 row-CDF + decoded column values (the AOT artifacts' runtime
/// error-model inputs, host-side). `PartialEq` is bitwise — operating
/// points compare and round-trip exactly (DESIGN.md §3).
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorModel {
    pub cdf: Vec<f32>,  // row-major 33*33
    pub vals: Vec<f32>, // 33
}

impl ErrorModel {
    pub fn identity() -> ErrorModel {
        let mut cdf = vec![0.0f32; N_LEVELS * N_LEVELS];
        for m in 0..N_LEVELS {
            for j in m..N_LEVELS {
                cdf[m * N_LEVELS + j] = 1.0;
            }
        }
        ErrorModel {
            cdf,
            vals: (0..N_LEVELS).map(|v| v as f32).collect(),
        }
    }

    pub fn from_full(full: &[Vec<f64>]) -> ErrorModel {
        let (cdf, vals) = crate::analog::pmap::to_cdf_inputs(full);
        ErrorModel { cdf, vals }
    }

    /// Decode a true level under sample u — right-continuous CDF
    /// inversion, identical to the kernels (`<=`, not `<`).
    ///
    /// Perf (EXPERIMENTS.md §Perf L3): the CDF row is sorted, so
    /// `partition_point` (binary search, <=6 comparisons) replaces the
    /// original 33-comparison linear scan kept below as
    /// `decode_linear` for the before/after benchmark.
    #[inline]
    pub fn decode(&self, level: usize, u: f32) -> f32 {
        let row = &self.cdf[level * N_LEVELS..(level + 1) * N_LEVELS];
        let col = row.partition_point(|&c| c <= u);
        self.vals[col.min(N_LEVELS - 1)]
    }

    /// The pre-optimization linear-scan decode (benchmark baseline).
    #[inline]
    pub fn decode_linear(&self, level: usize, u: f32) -> f32 {
        let row = &self.cdf[level * N_LEVELS..(level + 1) * N_LEVELS];
        let mut col = 0usize;
        for &c in row {
            if c <= u {
                col += 1;
            }
        }
        self.vals[col.min(N_LEVELS - 1)]
    }
}

/// The engine: W is packed once (weights are stationary), X per call.
pub struct SubMacEngine {
    pub w: BitMatrix,
    /// true (pre-padding) reduction length the accumulator subtracts
    pub beta: usize,
}

impl SubMacEngine {
    /// `w_vals`: row-major [o x k_padded] +-1 weights (k_padded % 32 == 0,
    /// pads +1 — i.e. the AOT export's `wb` tensors verbatim).
    pub fn new(o: usize, k_padded: usize, w_vals: &[f32], beta: usize)
        -> SubMacEngine {
        assert_eq!(k_padded % 32, 0);
        SubMacEngine {
            w: BitMatrix::pack(o, k_padded, w_vals, true),
            beta,
        }
    }

    pub fn n_groups(&self) -> usize {
        self.w.words_per_row
    }

    /// Exact +-1 matmul (identity circuit): out [o x d] row-major.
    /// `x` is packed with pad bits 0 (-1).
    pub fn matmul_exact(&self, x: &BitMatrix) -> Vec<f32> {
        let (o, d, g) = (self.w.rows, x.rows, self.n_groups());
        assert_eq!(x.words_per_row, g);
        let mut out = vec![0.0f32; o * d];
        for oi in 0..o {
            let wr = self.w.row64(oi);
            for di in 0..d {
                let xr = x.row64(di);
                let mut level_sum = 0u32;
                for gi in 0..g {
                    level_sum +=
                        group_level(row_group(wr, gi), row_group(xr, gi));
                }
                out[oi * d + di] =
                    (2 * level_sum as i64 - self.beta as i64) as f32;
            }
        }
        out
    }

    /// Sub-MAC matmul through the error model, bit-identical to the AOT
    /// kernels given the same (seed, salt).
    pub fn matmul_error(
        &self,
        x: &BitMatrix,
        em: &ErrorModel,
        seed: u32,
        salt: u32,
    ) -> Vec<f32> {
        let (o, d, g) = (self.w.rows, x.rows, self.n_groups());
        assert_eq!(x.words_per_row, g);
        let mut out = vec![0.0f32; o * d];
        for oi in 0..o {
            let wr = self.w.row64(oi);
            for di in 0..d {
                let xr = x.row64(di);
                let mut acc = 0.0f32;
                for gi in 0..g {
                    let level =
                        group_level(row_group(wr, gi), row_group(xr, gi))
                            as usize;
                    // logical index (o*G + g)*D + d — the kernels' layout
                    let lin = salt.wrapping_add(
                        ((oi as u32) * (g as u32))
                            .wrapping_add(gi as u32)
                            .wrapping_mul(d as u32)
                            .wrapping_add(di as u32),
                    );
                    let u = hash01(seed, lin);
                    acc += 2.0 * em.decode(level, u);
                }
                out[oi * d + di] = acc - self.beta as f32;
            }
        }
        out
    }

    /// Sub-MAC level histogram contribution (F_MAC of one matmul).
    pub fn histogram(&self, x: &BitMatrix) -> [u64; N_LEVELS] {
        let (o, d, g) = (self.w.rows, x.rows, self.n_groups());
        let mut hist = [0u64; N_LEVELS];
        for oi in 0..o {
            let wr = self.w.row64(oi);
            for di in 0..d {
                let xr = x.row64(di);
                for gi in 0..g {
                    hist[group_level(row_group(wr, gi), row_group(xr, gi))
                        as usize] += 1;
                }
            }
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_pm(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.pm1(0.5)).collect()
    }

    fn dense_dot(w: &[f32], x: &[f32], o: usize, k: usize, d: usize)
        -> Vec<f32> {
        let mut out = vec![0.0; o * d];
        for oi in 0..o {
            for di in 0..d {
                let mut s = 0.0;
                for ki in 0..k {
                    s += w[oi * k + ki] * x[di * k + ki];
                }
                out[oi * d + di] = s;
            }
        }
        out
    }

    #[test]
    fn exact_matches_dense() {
        let mut rng = Rng::new(1);
        for (o, k, d) in [(4, 32, 6), (3, 64, 5), (7, 96, 11)] {
            let w = rand_pm(&mut rng, o * k);
            let x = rand_pm(&mut rng, d * k);
            let eng = SubMacEngine::new(o, k, &w, k);
            let xb = BitMatrix::pack(d, k, &x, false);
            assert_eq!(eng.matmul_exact(&xb), dense_dot(&w, &x, o, k, d));
        }
    }

    #[test]
    fn identity_error_model_equals_exact() {
        let mut rng = Rng::new(2);
        let (o, k, d) = (5, 64, 9);
        let w = rand_pm(&mut rng, o * k);
        let x = rand_pm(&mut rng, d * k);
        let eng = SubMacEngine::new(o, k, &w, k);
        let xb = BitMatrix::pack(d, k, &x, false);
        let em = ErrorModel::identity();
        assert_eq!(
            eng.matmul_error(&xb, &em, 17, 3),
            eng.matmul_exact(&xb)
        );
    }

    #[test]
    fn ragged_beta_subtraction() {
        // 41 valid cells padded to 64: pads non-conducting, beta = 41
        let mut rng = Rng::new(3);
        let (o, k, kp, d) = (2, 41, 64, 4);
        let mut w = vec![1.0f32; o * kp];
        let mut x = vec![-1.0f32; d * kp];
        let wv = rand_pm(&mut rng, o * k);
        let xv = rand_pm(&mut rng, d * k);
        for oi in 0..o {
            w[oi * kp..oi * kp + k].copy_from_slice(&wv[oi * k..(oi + 1) * k]);
        }
        for di in 0..d {
            x[di * kp..di * kp + k].copy_from_slice(&xv[di * k..(di + 1) * k]);
        }
        let eng = SubMacEngine::new(o, kp, &w, k);
        let xb = BitMatrix::pack(d, kp, &x, false);
        assert_eq!(eng.matmul_exact(&xb), dense_dot(&wv, &xv, o, k, d));
    }

    #[test]
    fn histogram_total() {
        let mut rng = Rng::new(4);
        let (o, k, d) = (6, 96, 10);
        let w = rand_pm(&mut rng, o * k);
        let x = rand_pm(&mut rng, d * k);
        let eng = SubMacEngine::new(o, k, &w, k);
        let xb = BitMatrix::pack(d, k, &x, false);
        let h = eng.histogram(&xb);
        assert_eq!(h.iter().sum::<u64>(), (o * d * 3) as u64);
    }

    #[test]
    fn decode_binary_search_equals_linear() {
        let mut rng = Rng::new(77);
        // random stochastic model
        let mut full = vec![vec![0.0f64; 33]; 33];
        for (m, row) in full.iter_mut().enumerate() {
            let mut tot = 0.0;
            for d in -3i64..=3 {
                let j = (m as i64 + d).clamp(0, 32) as usize;
                let w = rng.f64() + 0.01;
                row[j] += w;
                tot += w;
            }
            row.iter_mut().for_each(|v| *v /= tot);
        }
        let em = ErrorModel::from_full(&full);
        for _ in 0..20_000 {
            let level = rng.below(33) as usize;
            let u = rng.f32();
            assert_eq!(
                em.decode(level, u),
                em.decode_linear(level, u),
                "level {level} u {u}"
            );
        }
        // the u = 0 edge (hash(0) = 0) that forced `<=`
        assert_eq!(em.decode(5, 0.0), em.decode_linear(5, 0.0));
    }

    #[test]
    fn clip_model_bounds_levels() {
        let mut rng = Rng::new(5);
        let (o, k, d) = (4, 64, 8);
        let w = rand_pm(&mut rng, o * k);
        let x = rand_pm(&mut rng, d * k);
        let eng = SubMacEngine::new(o, k, &w, k);
        let xb = BitMatrix::pack(d, k, &x, false);
        // clip to [14, 18]
        let mut full = vec![vec![0.0f64; 33]; 33];
        for (m, row) in full.iter_mut().enumerate() {
            row[m.clamp(14, 18)] = 1.0;
        }
        let em = ErrorModel::from_full(&full);
        let out = eng.matmul_error(&xb, &em, 0, 0);
        for &v in &out {
            // each group decodes in [14,18] -> out in [2*2*14-64, 2*2*18-64]
            assert!((2.0 * 2.0 * 14.0 - 64.0..=2.0 * 2.0 * 18.0 - 64.0)
                .contains(&v));
        }
    }
}

/// Dummy-cell biasing for a partial tail group (mirrors
/// python/compile/nn.py::centered_pad; DESIGN.md §4): `p_on` of the
/// 32 - (beta % 32) pad cells are driven conducting, centering the
/// partial group's levels on the peak; the accumulator subtracts
/// beta_eff = beta + 2 * p_on. Returns (p_on, beta_eff).
pub fn centered_pad(beta: usize) -> (usize, usize) {
    let r = beta % 32;
    if r == 0 {
        return (0, beta);
    }
    let p_on = (32 - r) / 2;
    (p_on, beta + 2 * p_on)
}

#[cfg(test)]
mod centered_pad_tests {
    use super::centered_pad;
    use super::{BitMatrix, SubMacEngine};
    use crate::util::rng::Rng;

    #[test]
    fn centers_partial_groups_on_the_peak() {
        for beta in [9usize, 27, 72, 144, 392] {
            let (p_on, beta_eff) = centered_pad(beta);
            let r = beta % 32;
            if r == 0 {
                assert_eq!((p_on, beta_eff), (0, beta));
            } else {
                // shifted peak p_on + r/2 within 1 of level 16
                let peak = p_on as f64 + r as f64 / 2.0;
                assert!((peak - 16.0).abs() <= 1.0, "beta {beta}");
                assert_eq!(beta_eff, beta + 2 * p_on);
            }
        }
    }

    #[test]
    fn biased_padding_recovers_exact_dot() {
        // engine with conducting pads + beta_eff == plain dot product
        let mut rng = Rng::new(8);
        let (o, beta, d) = (3usize, 41usize, 5usize);
        let (p_on, beta_eff) = centered_pad(beta);
        let kp = beta.div_ceil(32) * 32;
        let wv: Vec<f32> = (0..o * beta).map(|_| rng.pm1(0.5)).collect();
        let xv: Vec<f32> = (0..d * beta).map(|_| rng.pm1(0.5)).collect();
        let mut w = vec![1.0f32; o * kp];
        let mut x = vec![-1.0f32; d * kp];
        for oi in 0..o {
            w[oi * kp..oi * kp + beta]
                .copy_from_slice(&wv[oi * beta..(oi + 1) * beta]);
        }
        for di in 0..d {
            x[di * kp..di * kp + beta]
                .copy_from_slice(&xv[di * beta..(di + 1) * beta]);
            for j in 0..p_on {
                x[di * kp + beta + j] = 1.0; // conducting dummy cells
            }
        }
        let eng = SubMacEngine::new(o, kp, &w, beta_eff);
        let xb = BitMatrix::pack(d, kp, &x, false);
        let got = eng.matmul_exact(&xb);
        for oi in 0..o {
            for di in 0..d {
                let mut dot = 0.0f32;
                for ki in 0..beta {
                    dot += wv[oi * beta + ki] * xv[di * beta + ki];
                }
                assert_eq!(got[oi * d + di], dot);
            }
        }
    }
}
