//! Bit-packed +-1 matrices: one u32 word per a=32 sub-MAC group.
//!
//! Bit = 1 encodes +1. The XNOR-popcount level of a group is then
//! `popcount(!(w ^ x))` — but padding must contribute 0, so pad bits are
//! set to w=1, x=0, and the level is computed as
//! `popcount(!(w ^ x) & mask)` with `mask` covering... no mask needed:
//! w_pad=1 ^ x_pad=0 = 1, negated = 0, so pads vanish for free — exactly
//! the (w=+1, x=-1) non-conducting convention of the kernels.

/// Row-major bit-packed matrix: `rows x cols` logical +-1 entries,
/// `words_per_row = ceil(cols/32)` u32 words per row.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    pub words_per_row: usize,
    pub data: Vec<u32>,
    /// Fill value for pad bits (true = +1). Weights pad with +1,
    /// activations with -1 (bit 0), per the non-conducting convention.
    pub pad_one: bool,
}

impl BitMatrix {
    /// Pack a +-1 f32 matrix (row-major `rows x cols`).
    pub fn pack(rows: usize, cols: usize, vals: &[f32], pad_one: bool)
        -> BitMatrix {
        assert_eq!(vals.len(), rows * cols);
        let wpr = cols.div_ceil(32);
        let mut data = vec![0u32; rows * wpr];
        for r in 0..rows {
            for c in 0..cols {
                let v = vals[r * cols + c];
                debug_assert!(v == 1.0 || v == -1.0, "not binary: {v}");
                if v > 0.0 {
                    data[r * wpr + c / 32] |= 1 << (c % 32);
                }
            }
            if pad_one {
                // set pad bits of the last word to 1 (+1)
                let used = cols % 32;
                if used != 0 {
                    let pad_mask = !0u32 << used;
                    data[r * wpr + wpr - 1] |= pad_mask;
                }
            }
        }
        BitMatrix {
            rows,
            cols,
            words_per_row: wpr,
            data,
            pad_one,
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Logical +-1 value at (r, c).
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let w = self.data[r * self.words_per_row + c / 32];
        if (w >> (c % 32)) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }
}

/// XNOR-popcount level of one 32-cell group: `popcount(!(w ^ x))`.
/// With w padded to 1 and x padded to 0, pad cells contribute 0 —
/// the level equals the count over valid cells only.
#[inline]
pub fn group_level(w: u32, x: u32) -> u32 {
    (!(w ^ x)).count_ones()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrip() {
        let vals: Vec<f32> = (0..2 * 40)
            .map(|i| if i % 3 == 0 { 1.0 } else { -1.0 })
            .collect();
        let m = BitMatrix::pack(2, 40, &vals, true);
        for r in 0..2 {
            for c in 0..40 {
                assert_eq!(m.get(r, c), vals[r * 40 + c], "({r},{c})");
            }
        }
        assert_eq!(m.words_per_row, 2);
    }

    #[test]
    fn group_level_counts_matches() {
        // w = x -> all 32 match
        assert_eq!(group_level(0xDEAD_BEEF, 0xDEAD_BEEF), 32);
        // complement -> none match
        assert_eq!(group_level(0xDEAD_BEEF, !0xDEAD_BEEF), 0);
        // single-bit difference
        assert_eq!(group_level(0, 1), 31);
    }

    #[test]
    fn pad_cells_are_nonconducting() {
        // 5 valid cells, all matching (+1/+1): level must be 5
        let w = BitMatrix::pack(1, 5, &[1.0; 5], true);
        let x = BitMatrix::pack(1, 5, &[1.0; 5], false);
        assert_eq!(group_level(w.row(0)[0], x.row(0)[0]), 5);
        // 5 valid cells, all mismatching: level 0
        let x2 = BitMatrix::pack(1, 5, &[-1.0; 5], false);
        assert_eq!(group_level(w.row(0)[0], x2.row(0)[0]), 0);
    }

    #[test]
    fn exact_dot_recovered_from_levels() {
        // dot = 2 * sum(levels) - beta over groups
        let cols = 70;
        let wv: Vec<f32> = (0..cols)
            .map(|i| if (i * 7) % 5 < 2 { 1.0 } else { -1.0 })
            .collect();
        let xv: Vec<f32> = (0..cols)
            .map(|i| if (i * 3) % 4 < 2 { 1.0 } else { -1.0 })
            .collect();
        let w = BitMatrix::pack(1, cols, &wv, true);
        let x = BitMatrix::pack(1, cols, &xv, false);
        let mut level_sum = 0i64;
        for g in 0..w.words_per_row {
            level_sum += group_level(w.row(0)[g], x.row(0)[g]) as i64;
        }
        let dot: f32 = wv.iter().zip(&xv).map(|(a, b)| a * b).sum();
        assert_eq!(2 * level_sum - cols as i64, dot as i64);
    }
}
