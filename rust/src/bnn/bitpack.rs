//! Bit-packed +-1 matrices: u64 storage words, one u32 half-word per
//! a=32 sub-MAC group.
//!
//! Bit = 1 encodes +1. The XNOR-popcount level of a group is
//! `popcount(!(w ^ x))` over its 32 bits — pad bits are set to w=1,
//! x=0, so `!(w ^ x)` is 0 there and pads vanish for free (the
//! (w=+1, x=-1) non-conducting convention of the kernels).
//!
//! Storage is u64 words (`words64_per_row = ceil(groups/2)`), so the
//! word-level popcount microkernels in `backend::kernels` accumulate
//! two groups per XOR+popcount:
//! `sum_g popcount(!(w_g ^ x_g)) == sum_w popcount(!(w64 ^ x64))`
//! exactly, because the *phantom* high half of an odd trailing word
//! follows the same pad convention and contributes 0. Per-group levels
//! (error decode, F_MAC histograms) read the u32 halves back out —
//! hoist a [`BitMatrix::row64`] slice and index it with [`row_group`]
//! (or use [`BitMatrix::group`] for one-off reads).

/// Row-major bit-packed matrix: `rows x cols` logical +-1 entries,
/// `words_per_row = ceil(cols/32)` sub-MAC groups per row, stored as
/// `words64_per_row = ceil(words_per_row/2)` u64 words per row.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Semantic width: u32 sub-MAC groups per row (`ceil(cols/32)`).
    pub words_per_row: usize,
    /// Storage width: u64 words per row (`ceil(words_per_row/2)`).
    pub words64_per_row: usize,
    pub data: Vec<u64>,
    /// Fill value for pad bits (true = +1). Weights pad with +1,
    /// activations with -1 (bit 0), per the non-conducting convention.
    pub pad_one: bool,
}

impl BitMatrix {
    /// Pack a +-1 f32 matrix (row-major `rows x cols`).
    pub fn pack(rows: usize, cols: usize, vals: &[f32], pad_one: bool)
        -> BitMatrix {
        BitMatrix::pack_with(Vec::new(), rows, cols, vals, pad_one)
    }

    /// Pack into a recycled storage buffer (the native backend's
    /// scratch arena lends these across matmuls — DESIGN.md §11);
    /// `buf` is cleared and resized, its capacity reused.
    pub fn pack_with(
        mut buf: Vec<u64>,
        rows: usize,
        cols: usize,
        vals: &[f32],
        pad_one: bool,
    ) -> BitMatrix {
        assert_eq!(vals.len(), rows * cols);
        let wpr = cols.div_ceil(32);
        let wpr64 = wpr.div_ceil(2);
        buf.clear();
        buf.resize(rows * wpr64, 0u64);
        for r in 0..rows {
            let row = &mut buf[r * wpr64..(r + 1) * wpr64];
            for c in 0..cols {
                let v = vals[r * cols + c];
                debug_assert!(v == 1.0 || v == -1.0, "not binary: {v}");
                if v > 0.0 {
                    row[c / 64] |= 1u64 << (c % 64);
                }
            }
            if pad_one {
                // set every bit from `cols` to the end of the storage
                // row to 1 (+1): partial-group padding and the phantom
                // high half of an odd trailing word alike
                let used = cols % 64;
                if used != 0 {
                    row[cols / 64] |= !0u64 << used;
                }
            }
        }
        BitMatrix {
            rows,
            cols,
            words_per_row: wpr,
            words64_per_row: wpr64,
            data: buf,
            pad_one,
        }
    }

    /// Hand the storage buffer back (to a scratch arena) once the
    /// matrix is consumed.
    pub fn into_data(self) -> Vec<u64> {
        self.data
    }

    /// One row as u64 storage words.
    #[inline]
    pub fn row64(&self, r: usize) -> &[u64] {
        &self.data
            [r * self.words64_per_row..(r + 1) * self.words64_per_row]
    }

    /// The 32-bit sub-MAC group `gi` of row `r`.
    #[inline]
    pub fn group(&self, r: usize, gi: usize) -> u32 {
        debug_assert!(gi < self.words_per_row);
        row_group(self.row64(r), gi)
    }

    /// Repack rows `r0..r1` into lane-interleaved panels for the
    /// register-blocked kernels (DESIGN.md §14): `lanes` consecutive
    /// rows form one panel, and within a panel storage word `k` of
    /// every lane is contiguous —
    /// `buf[(p * words64_per_row + k) * lanes + l]` holds word `k` of
    /// row `r0 + p * lanes + l` — so a microkernel's K sweep walks one
    /// contiguous span and a single vector load fetches word `k` of
    /// all `lanes` rows at once. Lanes past `r1` in the last panel
    /// stay zero (the kernels never store those lanes, so the value
    /// is immaterial; zero keeps the buffer deterministic). `buf` is
    /// cleared and resized, its capacity reused (scratch-arena
    /// friendly).
    pub fn pack_panels(
        &self,
        r0: usize,
        r1: usize,
        lanes: usize,
        buf: &mut Vec<u64>,
    ) {
        assert!(r0 <= r1 && r1 <= self.rows);
        assert!(lanes >= 1);
        let kw = self.words64_per_row;
        let panels = (r1 - r0).div_ceil(lanes);
        buf.clear();
        buf.resize(panels * kw * lanes, 0u64);
        for p in 0..panels {
            let panel = &mut buf[p * kw * lanes..(p + 1) * kw * lanes];
            for l in 0..lanes {
                let r = r0 + p * lanes + l;
                if r >= r1 {
                    break; // tail lanes stay zero
                }
                let row = &self.data[r * kw..(r + 1) * kw];
                for (k, &w) in row.iter().enumerate() {
                    panel[k * lanes + l] = w;
                }
            }
        }
    }

    /// Logical +-1 value at (r, c).
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let w = self.data[r * self.words64_per_row + c / 64];
        if (w >> (c % 64)) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }
}

/// The 32-bit sub-MAC group `gi` of a packed row's u64 storage words
/// (hoist the [`BitMatrix::row64`] slice outside inner loops and read
/// groups through this).
#[inline]
pub fn row_group(row64: &[u64], gi: usize) -> u32 {
    (row64[gi / 2] >> (32 * (gi & 1))) as u32
}

/// XNOR-popcount level of one 32-cell group: `popcount(!(w ^ x))`.
/// With w padded to 1 and x padded to 0, pad cells contribute 0 —
/// the level equals the count over valid cells only.
#[inline]
pub fn group_level(w: u32, x: u32) -> u32 {
    (!(w ^ x)).count_ones()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrip() {
        let vals: Vec<f32> = (0..2 * 40)
            .map(|i| if i % 3 == 0 { 1.0 } else { -1.0 })
            .collect();
        let m = BitMatrix::pack(2, 40, &vals, true);
        for r in 0..2 {
            for c in 0..40 {
                assert_eq!(m.get(r, c), vals[r * 40 + c], "({r},{c})");
            }
        }
        assert_eq!(m.words_per_row, 2);
        assert_eq!(m.words64_per_row, 1);
    }

    #[test]
    fn group_level_counts_matches() {
        // w = x -> all 32 match
        assert_eq!(group_level(0xDEAD_BEEF, 0xDEAD_BEEF), 32);
        // complement -> none match
        assert_eq!(group_level(0xDEAD_BEEF, !0xDEAD_BEEF), 0);
        // single-bit difference
        assert_eq!(group_level(0, 1), 31);
    }

    #[test]
    fn pad_cells_are_nonconducting() {
        // 5 valid cells, all matching (+1/+1): level must be 5
        let w = BitMatrix::pack(1, 5, &[1.0; 5], true);
        let x = BitMatrix::pack(1, 5, &[1.0; 5], false);
        assert_eq!(group_level(w.group(0, 0), x.group(0, 0)), 5);
        assert_eq!((!(w.row64(0)[0] ^ x.row64(0)[0])).count_ones(), 5);
        // 5 valid cells, all mismatching: level 0
        let x2 = BitMatrix::pack(1, 5, &[-1.0; 5], false);
        assert_eq!(group_level(w.group(0, 0), x2.group(0, 0)), 0);
    }

    #[test]
    fn word_sum_equals_group_sum_on_odd_group_counts() {
        // 3 groups (96 cols) -> 2 storage words with a phantom high
        // half; the phantom must contribute 0 to the word-level sum
        for cols in [33usize, 65, 96, 100, 129] {
            let wv: Vec<f32> = (0..cols)
                .map(|i| if (i * 7) % 3 == 0 { 1.0 } else { -1.0 })
                .collect();
            let xv: Vec<f32> = (0..cols)
                .map(|i| if (i * 5) % 4 < 2 { 1.0 } else { -1.0 })
                .collect();
            let w = BitMatrix::pack(1, cols, &wv, true);
            let x = BitMatrix::pack(1, cols, &xv, false);
            let by_group: u32 = (0..w.words_per_row)
                .map(|g| group_level(w.group(0, g), x.group(0, g)))
                .sum();
            let by_word: u32 = w
                .row64(0)
                .iter()
                .zip(x.row64(0))
                .map(|(a, b)| (!(a ^ b)).count_ones())
                .sum();
            assert_eq!(by_group, by_word, "cols {cols}");
        }
    }

    #[test]
    fn pack_with_reuses_capacity() {
        let vals = vec![1.0f32; 4 * 64];
        let a = BitMatrix::pack(4, 64, &vals, false);
        let buf = a.into_data();
        let cap = buf.capacity();
        let b = BitMatrix::pack_with(buf, 4, 64, &vals, false);
        assert!(b.data.capacity() >= cap.min(4));
        for c in 0..64 {
            assert_eq!(b.get(2, c), 1.0);
        }
    }

    #[test]
    fn pack_panels_interleaves_and_zero_fills() {
        // 5 rows x 100 cols (2 storage words), 4 lanes -> 2 panels,
        // the second with 3 zero tail lanes
        let vals: Vec<f32> = (0..5 * 100)
            .map(|i| if (i * 11) % 7 < 3 { 1.0 } else { -1.0 })
            .collect();
        let m = BitMatrix::pack(5, 100, &vals, false);
        let kw = m.words64_per_row;
        let mut buf = Vec::new();
        m.pack_panels(0, 5, 4, &mut buf);
        assert_eq!(buf.len(), 2 * kw * 4);
        for p in 0..2 {
            let panel = &buf[p * kw * 4..(p + 1) * kw * 4];
            for l in 0..4 {
                let r = p * 4 + l;
                for k in 0..kw {
                    let want =
                        if r < 5 { m.row64(r)[k] } else { 0u64 };
                    assert_eq!(
                        panel[k * 4 + l],
                        want,
                        "panel {p} lane {l} word {k}"
                    );
                }
            }
        }
        // sub-ranges pack relative to r0, reusing the buffer
        m.pack_panels(2, 5, 2, &mut buf);
        assert_eq!(buf.len(), 2 * kw * 2);
        assert_eq!(buf[0], m.row64(2)[0]);
        assert_eq!(buf[1], m.row64(3)[0]);
    }

    #[test]
    fn exact_dot_recovered_from_levels() {
        // dot = 2 * sum(levels) - beta over groups
        let cols = 70;
        let wv: Vec<f32> = (0..cols)
            .map(|i| if (i * 7) % 5 < 2 { 1.0 } else { -1.0 })
            .collect();
        let xv: Vec<f32> = (0..cols)
            .map(|i| if (i * 3) % 4 < 2 { 1.0 } else { -1.0 })
            .collect();
        let w = BitMatrix::pack(1, cols, &wv, true);
        let x = BitMatrix::pack(1, cols, &xv, false);
        let mut level_sum = 0i64;
        for g in 0..w.words_per_row {
            level_sum += group_level(w.group(0, g), x.group(0, g)) as i64;
        }
        let dot: f32 = wv.iter().zip(&xv).map(|(a, b)| a * b).sum();
        assert_eq!(2 * level_sum - cols as i64, dot as i64);
    }
}
