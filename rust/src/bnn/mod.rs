//! Host-side binarized-NN engine.
//!
//! A bit-packed XNOR-popcount sub-MAC engine that mirrors the L1 Pallas
//! kernel *bit-for-bit* (same counter-based PRNG, same CDF inversion).
//! Three roles: (1) independent oracle for integration tests against the
//! AOT artifacts, (2) the baseline comparator the paper's framework
//! replaces (a host MAC engine), (3) a fast native path for large
//! Monte-Carlo sweeps in the benches.

pub mod bitpack;
pub mod engine;
pub mod hashrng;

pub use bitpack::BitMatrix;
pub use engine::{ErrorModel, SubMacEngine};
