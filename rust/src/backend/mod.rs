//! Backend abstraction layer (DESIGN.md §9): one trait for "evaluate a
//! folded BNN under per-matmul error models, and collect its F_MAC
//! histograms", with two interchangeable engines behind it:
//!
//! * [`native::NativeBackend`] — the whole multi-layer forward pass on
//!   host (bit-pack -> grouped sub-MAC -> counter-PRNG error decode ->
//!   folded affine -> argmax) on width-dispatched popcount
//!   microkernels (`kernels::KernelKind`: runtime-detected
//!   AVX2/NEON with a portable scalar fallback), thread-pooled and
//!   arena-backed. No XLA, no artifacts, no Python anywhere.
//! * `xla_backend::XlaBackend` (behind the `xla` cargo feature) — the
//!   original path through the AOT eval/hist artifacts and the PJRT
//!   runtime.
//!
//! Both consume the same inputs (the model's name in the native
//! registry, the folded tensors in export order, per-matmul
//! [`ErrorModel`]s, a PRNG seed) and share one batching + per-batch
//! seed schedule, so their logits agree bit-for-bit — the native path
//! is a drop-in replacement, not an approximation
//! (`tests/backend.rs`).

pub mod arch;
pub mod autotune;
pub mod kernels;
pub mod native;
#[cfg(feature = "xla")]
pub mod xla_backend;

use anyhow::{anyhow, Result};

use crate::bnn::ErrorModel;
use crate::capmin::Fmac;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::store::NamedTensor;
use crate::data::synth::DatasetSpec;
use crate::data::{Loader, Split};
use crate::util::stats::argmax;

/// Requested backend (`--backend`); `Auto` resolves per machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Auto,
    Native,
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => Err(anyhow!(
                "bad --backend `{other}`: expected native, xla or auto"
            )),
        }
    }

    /// Resolve `auto` for this build and machine: XLA when the crate
    /// was built with the `xla` feature *and* compiled artifacts are
    /// present, native otherwise. Explicit choices pass through
    /// unchanged (an explicit `xla` on a native-only build errors at
    /// backend construction, not here — keys still need a name).
    pub fn resolve(cfg: &ExperimentConfig) -> &'static str {
        match BackendKind::parse(&cfg.backend) {
            Ok(BackendKind::Native) => "native",
            Ok(BackendKind::Xla) => "xla",
            _ => {
                if cfg!(feature = "xla")
                    && crate::runtime::artifacts_dir()
                        .join("manifest.json")
                        .exists()
                {
                    "xla"
                } else {
                    "native"
                }
            }
        }
    }
}

/// F_MAC extraction result (per-matmul + summed histograms plus the
/// clean accuracy measured on the same forward passes).
pub struct FmacResult {
    pub per_matmul: Vec<Fmac>,
    pub sum: Fmac,
    pub accuracy: f64,
    pub n_samples: usize,
}

/// Evaluate a folded BNN over a data split under per-matmul error
/// models, and collect F_MAC histograms — the two operations every
/// figure driver needs.
///
/// Contract shared by all implementations (so results are
/// backend-independent bit-for-bit):
/// * `folded` is the export-ordered tensor list (`wb{i}` padded +-1
///   weights, `scale{i}`/`bias{i}` affines, `out.b`);
/// * matmul `i` uses PRNG salt `i * 0x9E3779B1` over logical element
///   indices `(o*G + g)*D + d` with the shared murmur3 `hash01`;
/// * accuracy runs the test split through batches of the model's
///   `eval_batch`, seeding batch `bi` with
///   `seed + bi * 0x9E37` (wrapping) and a loader seeded `0xE7A1`.
///
/// Deliberately not `Send`/`Sync`: the session facade drives one
/// backend sequentially (the PJRT client is single-threaded); the
/// *native* backend parallelizes internally through its pool.
pub trait InferenceBackend {
    fn name(&self) -> &'static str;

    /// Logits [batch, n_classes] of one input batch.
    fn logits(
        &self,
        model: &str,
        folded: &[NamedTensor],
        x: &[f32],
        batch: usize,
        ems: &[ErrorModel],
        seed: u32,
    ) -> Result<Vec<f32>>;

    /// Accuracy on `spec`'s test split over `limit` samples. The
    /// default implementation drives [`InferenceBackend::logits`]
    /// through the shared batch/seed schedule.
    fn accuracy(
        &self,
        model: &str,
        folded: &[NamedTensor],
        spec: DatasetSpec,
        ems: &[ErrorModel],
        limit: usize,
        seed: u32,
    ) -> Result<f64> {
        let meta = arch::model_meta(model)?;
        let eb = meta.eval_batch;
        let mut loader = Loader::new(spec, Split::Test, eb, limit, 0xE7A1);
        let n_batches = (limit / eb).max(1);
        let (mut correct, mut total) = (0usize, 0usize);
        for bi in 0..n_batches {
            let batch = loader.next_batch();
            // per-batch seed: decorrelates batches within one run
            let logits = self.logits(
                model,
                folded,
                &batch.x,
                eb,
                ems,
                seed.wrapping_add(bi as u32 * 0x9E37),
            )?;
            for (i, &label) in batch.labels.iter().enumerate() {
                let row =
                    &logits[i * meta.n_classes..(i + 1) * meta.n_classes];
                if argmax(row) == label {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Mean accuracy over `n_seeds` PRNG seeds (paper: average of 3
    /// runs for the variation curves).
    #[allow(clippy::too_many_arguments)]
    fn accuracy_multi_seed(
        &self,
        model: &str,
        folded: &[NamedTensor],
        spec: DatasetSpec,
        ems: &[ErrorModel],
        limit: usize,
        n_seeds: usize,
        base_seed: u32,
    ) -> Result<f64> {
        let mut acc = 0.0;
        for s in 0..n_seeds {
            acc += self.accuracy(
                model,
                folded,
                spec.clone(),
                ems,
                limit,
                base_seed.wrapping_add(s as u32 * 7919),
            )?;
        }
        Ok(acc / n_seeds as f64)
    }

    /// F_MAC histograms over `limit` training samples (clean forward,
    /// histograms over the dummy-biased packed operands).
    fn fmac(
        &self,
        model: &str,
        folded: &[NamedTensor],
        spec: DatasetSpec,
        limit: usize,
        seed: u64,
    ) -> Result<FmacResult>;
}

pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

/// Content hash of a folded tensor list (FNV-1a over tensor names and
/// f32 bit patterns) — keys both backends' prepared-model caches, so
/// re-exported weights invalidate cleanly.
pub(crate) fn fold_hash(folded: &[NamedTensor]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for t in folded {
        for b in t.name.as_bytes() {
            eat(*b);
        }
        for &v in &t.data {
            for b in v.to_bits().to_le_bytes() {
                eat(b);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn explicit_kinds_resolve_to_themselves() {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = "native".into();
        assert_eq!(BackendKind::resolve(&cfg), "native");
        cfg.backend = "xla".into();
        assert_eq!(BackendKind::resolve(&cfg), "xla");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn auto_resolves_native_without_the_xla_feature() {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = "auto".into();
        assert_eq!(BackendKind::resolve(&cfg), "native");
    }
}
