//! Tiled, cache-blocked sub-MAC matmul kernels over the bit-packed
//! operands, fanned out over the shared [`ScopedPool`].
//!
//! Semantics are *identical* to the scalar [`SubMacEngine`] loops (and
//! therefore to the AOT kernels): every output element is
//! `2 * sum_g decode(level_g, u(o,g,d)) - beta` with the counter-based
//! PRNG indexed by the logical `(o*G + g)*D + d` position — independent
//! per element, so both the d-blocked tiling and the o-block threading
//! are bit-exact at any tile size or thread count (pinned by
//! `tests/backend.rs`).
//!
//! Tiling (idiom from the rten/gemm microkernels referenced in
//! SNIPPETS.md, scaled to bit-packed operands): the inner loops walk a
//! block of `TILE_D` activation rows for each weight row, so the packed
//! x-rows of a block stay resident in L1 across the whole o-sweep
//! instead of streaming the full x matrix once per output row.

use crate::bnn::bitpack::{group_level, BitMatrix};
use crate::bnn::hashrng::hash01;
use crate::bnn::{ErrorModel, SubMacEngine};
use crate::capmin::N_LEVELS;
use crate::util::pool::ScopedPool;

/// Activation rows held hot per tile: 128 rows x <=49 words = <=25 KiB,
/// inside L1/L2 on every testbed core.
pub const TILE_D: usize = 128;

/// Exact +-1 matmul, cache-blocked (single thread). Bit-identical to
/// [`SubMacEngine::matmul_exact`].
pub fn matmul_exact_tiled(eng: &SubMacEngine, x: &BitMatrix) -> Vec<f32> {
    let (o, d) = (eng.w.rows, x.rows);
    let mut out = vec![0.0f32; o * d];
    exact_block(eng, x, 0, o, &mut out);
    out
}

/// Exact +-1 matmul, tiled and fanned over `pool` in contiguous
/// o-blocks. Bit-identical to the scalar loop at any thread count.
pub fn matmul_exact(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
) -> Vec<f32> {
    let (o, d) = (eng.w.rows, x.rows);
    let blocks = o_blocks(o, pool.threads());
    if blocks.len() <= 1 {
        return matmul_exact_tiled(eng, x);
    }
    let parts = pool.map(blocks.len(), |bi| {
        let (o0, o1) = blocks[bi];
        let mut part = vec![0.0f32; (o1 - o0) * d];
        exact_block(eng, x, o0, o1, &mut part);
        part
    });
    parts.concat()
}

fn exact_block(
    eng: &SubMacEngine,
    x: &BitMatrix,
    o0: usize,
    o1: usize,
    out: &mut [f32],
) {
    let (d, g) = (x.rows, eng.n_groups());
    debug_assert_eq!(x.words_per_row, g);
    for d0 in (0..d).step_by(TILE_D) {
        let d1 = (d0 + TILE_D).min(d);
        for oi in o0..o1 {
            let wr = eng.w.row(oi);
            let row = &mut out[(oi - o0) * d..(oi - o0 + 1) * d];
            for di in d0..d1 {
                let xr = x.row(di);
                let mut level_sum = 0u32;
                for gi in 0..g {
                    level_sum += group_level(wr[gi], xr[gi]);
                }
                row[di] =
                    (2 * level_sum as i64 - eng.beta as i64) as f32;
            }
        }
    }
}

/// Error-model matmul, cache-blocked (single thread). Bit-identical to
/// [`SubMacEngine::matmul_error`].
pub fn matmul_error_tiled(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
) -> Vec<f32> {
    let (o, d) = (eng.w.rows, x.rows);
    let mut out = vec![0.0f32; o * d];
    error_block(eng, x, em, seed, salt, 0, o, &mut out);
    out
}

/// Error-model matmul fanned over `pool` in contiguous o-blocks. The
/// PRNG is indexed by the logical element position, so this is
/// bit-identical to the scalar loop at any thread count.
pub fn matmul_error(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
) -> Vec<f32> {
    let (o, d) = (eng.w.rows, x.rows);
    let blocks = o_blocks(o, pool.threads());
    if blocks.len() <= 1 {
        return matmul_error_tiled(eng, x, em, seed, salt);
    }
    let parts = pool.map(blocks.len(), |bi| {
        let (o0, o1) = blocks[bi];
        let mut part = vec![0.0f32; (o1 - o0) * d];
        error_block(eng, x, em, seed, salt, o0, o1, &mut part);
        part
    });
    parts.concat()
}

#[allow(clippy::too_many_arguments)]
fn error_block(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    o0: usize,
    o1: usize,
    out: &mut [f32],
) {
    let (d, g) = (x.rows, eng.n_groups());
    debug_assert_eq!(x.words_per_row, g);
    for d0 in (0..d).step_by(TILE_D) {
        let d1 = (d0 + TILE_D).min(d);
        for oi in o0..o1 {
            let wr = eng.w.row(oi);
            let row = &mut out[(oi - o0) * d..(oi - o0 + 1) * d];
            for di in d0..d1 {
                let xr = x.row(di);
                let mut acc = 0.0f32;
                for gi in 0..g {
                    let level = group_level(wr[gi], xr[gi]) as usize;
                    // logical index (o*G + g)*D + d — the kernels' layout
                    let lin = salt.wrapping_add(
                        ((oi as u32) * (g as u32))
                            .wrapping_add(gi as u32)
                            .wrapping_mul(d as u32)
                            .wrapping_add(di as u32),
                    );
                    acc += 2.0 * em.decode(level, hash01(seed, lin));
                }
                row[di] = acc - eng.beta as f32;
            }
        }
    }
}

/// F_MAC level histogram of one matmul, fanned over `pool` (per-block
/// histograms merge by addition, so the fan-out is exact).
pub fn histogram(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
) -> [u64; N_LEVELS] {
    let (o, d, g) = (eng.w.rows, x.rows, eng.n_groups());
    let blocks = o_blocks(o, pool.threads());
    let parts = pool.map(blocks.len(), |bi| {
        let (o0, o1) = blocks[bi];
        let mut hist = [0u64; N_LEVELS];
        for oi in o0..o1 {
            let wr = eng.w.row(oi);
            for di in 0..d {
                let xr = x.row(di);
                for gi in 0..g {
                    hist[group_level(wr[gi], xr[gi]) as usize] += 1;
                }
            }
        }
        hist
    });
    let mut hist = [0u64; N_LEVELS];
    for part in parts {
        for (a, b) in hist.iter_mut().zip(part.iter()) {
            *a += b;
        }
    }
    hist
}

/// Contiguous output-row blocks, one per worker (so the per-block
/// results concatenate into the row-major output with no interleaving).
fn o_blocks(o: usize, workers: usize) -> Vec<(usize, usize)> {
    let n = workers.min(o).max(1);
    let base = o / n;
    let extra = o % n;
    let mut blocks = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let len = base + usize::from(i < extra);
        blocks.push((start, start + len));
        start += len;
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_engine(
        rng: &mut Rng,
        o: usize,
        k: usize,
        d: usize,
    ) -> (SubMacEngine, BitMatrix) {
        let w: Vec<f32> = (0..o * k).map(|_| rng.pm1(0.5)).collect();
        let x: Vec<f32> = (0..d * k).map(|_| rng.pm1(0.5)).collect();
        (
            SubMacEngine::new(o, k, &w, k),
            BitMatrix::pack(d, k, &x, false),
        )
    }

    fn rand_em(rng: &mut Rng) -> ErrorModel {
        let mut full = vec![vec![0.0f64; N_LEVELS]; N_LEVELS];
        for (m, row) in full.iter_mut().enumerate() {
            let mut tot = 0.0;
            for dlt in -2i64..=2 {
                let j = (m as i64 + dlt).clamp(0, 32) as usize;
                let w = rng.f64() + 0.05;
                row[j] += w;
                tot += w;
            }
            row.iter_mut().for_each(|v| *v /= tot);
        }
        ErrorModel::from_full(&full)
    }

    #[test]
    fn tiled_exact_matches_scalar() {
        let mut rng = Rng::new(31);
        for (o, k, d) in [(5, 64, 300), (17, 96, 131), (1, 32, 1)] {
            let (eng, xb) = rand_engine(&mut rng, o, k, d);
            assert_eq!(matmul_exact_tiled(&eng, &xb), eng.matmul_exact(&xb));
        }
    }

    #[test]
    fn threaded_exact_matches_scalar_at_every_pool_size() {
        let mut rng = Rng::new(32);
        let (eng, xb) = rand_engine(&mut rng, 13, 64, 257);
        let want = eng.matmul_exact(&xb);
        for threads in [1usize, 2, 3, 8, 32] {
            let pool = ScopedPool::new(threads);
            assert_eq!(
                matmul_exact(&pool, &eng, &xb),
                want,
                "threads {threads}"
            );
        }
    }

    #[test]
    fn tiled_and_threaded_error_match_scalar_bitwise() {
        let mut rng = Rng::new(33);
        let (eng, xb) = rand_engine(&mut rng, 9, 96, 200);
        let em = rand_em(&mut rng);
        for (seed, salt) in [(0u32, 0u32), (7, 0x9E3779B1), (0xDEAD, 42)] {
            let want = eng.matmul_error(&xb, &em, seed, salt);
            assert_eq!(
                matmul_error_tiled(&eng, &xb, &em, seed, salt),
                want
            );
            for threads in [2usize, 5] {
                let pool = ScopedPool::new(threads);
                assert_eq!(
                    matmul_error(&pool, &eng, &xb, &em, seed, salt),
                    want,
                    "seed {seed} salt {salt} threads {threads}"
                );
            }
        }
    }

    #[test]
    fn histogram_matches_engine() {
        let mut rng = Rng::new(34);
        let (eng, xb) = rand_engine(&mut rng, 6, 96, 77);
        let want = eng.histogram(&xb);
        for threads in [1usize, 3] {
            let pool = ScopedPool::new(threads);
            assert_eq!(histogram(&pool, &eng, &xb), want);
        }
    }

    #[test]
    fn o_blocks_cover_and_are_contiguous() {
        for (o, w) in [(10, 3), (3, 8), (1, 1), (64, 64)] {
            let blocks = o_blocks(o, w);
            assert_eq!(blocks[0].0, 0);
            assert_eq!(blocks.last().unwrap().1, o);
            for win in blocks.windows(2) {
                assert_eq!(win[0].1, win[1].0);
                assert!(win[0].1 > win[0].0);
            }
        }
    }
}
