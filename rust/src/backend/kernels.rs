//! Width-dispatched popcount sub-MAC microkernels (DESIGN.md §11).
//!
//! Semantics are *identical* to the scalar [`SubMacEngine`] loops (and
//! therefore to the AOT kernels): every output element is
//! `2 * sum_g decode(level_g, u(o,g,d)) - beta` with the counter-based
//! PRNG indexed by the logical `(o*G + g)*D + d` position. All math on
//! the hot path is integer (XOR + popcount over the packed u64 words,
//! pad and phantom bits vanish by the non-conducting convention), so
//! every kernel tier, tile size and thread count is bit-exact —
//! pinned by the in-file tests and `tests/backend.rs`.
//!
//! Three layers, modeled on the runtime-dispatch architecture of the
//! `gemm` crates referenced in SNIPPETS.md:
//!
//! * **Tier dispatch** ([`KernelKind`]): one generic, `inline(always)`
//!   kernel body instantiated per CPU tier — `scalar` (portable),
//!   `avx2` (x86_64, runtime-detected AVX2 + hardware POPCNT; long
//!   rows additionally run a vpshufb nibble-LUT popcount), `neon`
//!   (aarch64, `cnt`-lowered popcounts under the neon target
//!   feature). `--kernel scalar|auto` selects; the resolved tier is
//!   recorded in point-cache meta.
//! * **Blocking** ([`work_blocks`]): the (o x d) output grid splits
//!   into contiguous, non-empty rectangles — o-blocks while `o >=
//!   workers`, per-row d-splits otherwise, so small-o matmuls (early
//!   convs) no longer idle most of the pool. Within a block, d-tiles
//!   of [`TILE_D`] x-rows stay resident in L1 across the o-sweep.
//! * **Fusion** ([`matmul_exact_fused_into`]): the clean F_MAC pass
//!   computes outputs *and* per-group level histograms in one walk
//!   over the operands instead of two.

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::bnn::bitpack::BitMatrix;
use crate::bnn::hashrng::hash01;
use crate::bnn::{ErrorModel, SubMacEngine};
use crate::capmin::N_LEVELS;
use crate::util::pool::ScopedPool;

/// Activation rows held hot per tile: 128 rows of packed words is a
/// few tens of KiB for every registry shape — inside L2 and usually
/// L1 on the testbed cores.
pub const TILE_D: usize = 128;

/// A resolved kernel tier. `Scalar` is the portable fallback; the SIMD
/// tiers are only ever constructed when the running CPU supports them
/// (runtime detection), so executing them is safe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable u64 XOR+popcount (compiler-lowered `count_ones`).
    Scalar,
    /// x86_64 AVX2 + hardware POPCNT (runtime-detected).
    Avx2,
    /// aarch64 NEON `cnt`-lowered popcounts (runtime-detected).
    Neon,
}

impl KernelKind {
    /// CLI values `--kernel` accepts. `auto` resolves per machine;
    /// naming a SIMD tier explicitly errors unless detected.
    pub const CHOICES: &'static [&'static str] =
        &["auto", "scalar", "avx2", "neon"];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Neon => "neon",
        }
    }

    /// The best tier the running CPU supports.
    pub fn detect() -> KernelKind {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("popcnt")
            {
                return KernelKind::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return KernelKind::Neon;
            }
        }
        KernelKind::Scalar
    }

    /// Resolve a `--kernel` request against the running CPU. `auto`
    /// picks the detected tier; `scalar` forces the portable kernel
    /// (cold-path measurements, bit-equality cross-checks); an
    /// explicit SIMD name is accepted only when the CPU has it.
    pub fn resolve(requested: &str) -> Result<KernelKind> {
        match requested {
            "auto" => Ok(KernelKind::detect()),
            "scalar" => Ok(KernelKind::Scalar),
            "avx2" | "neon" => {
                let detected = KernelKind::detect();
                if detected.name() == requested {
                    Ok(detected)
                } else {
                    Err(anyhow!(
                        "--kernel {requested} is not supported on this \
                         CPU (detected tier: {}); use --kernel auto or \
                         scalar",
                        detected.name()
                    ))
                }
            }
            other => Err(anyhow!(
                "bad --kernel `{other}`: expected one of auto, scalar, \
                 avx2, neon"
            )),
        }
    }
}

/// One rectangular work item of the row-major (o x d) output grid:
/// rows `o0..o1`, columns `d0..d1`. [`work_blocks`] only emits shapes
/// whose output elements are contiguous in the row-major buffer
/// (full-width o-blocks, or single-row d-spans).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Block {
    pub o0: usize,
    pub o1: usize,
    pub d0: usize,
    pub d1: usize,
}

impl Block {
    pub fn len(&self) -> usize {
        (self.o1 - self.o0) * (self.d1 - self.d0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Split `start..end` into `n <= end - start` contiguous, non-empty
/// ranges.
fn ranges(start: usize, end: usize, n: usize) -> Vec<(usize, usize)> {
    let len = end - start;
    let n = n.min(len).max(1);
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut at = start;
    for i in 0..n {
        let step = base + usize::from(i < extra);
        out.push((at, at + step));
        at += step;
    }
    out
}

/// Contiguous, non-empty work blocks covering the (o x d) grid in
/// row-major memory order. While `o >= workers` the split is by output
/// rows (one concat-free slice per worker); when `o < workers` —
/// early convs have o as low as 8 while d is in the thousands — each
/// row additionally splits its d-span so no pool worker idles. Every
/// block is non-empty; the list holds at most `workers` items in the
/// o-split arm and at most `o * ceil(workers/o)` (< workers + o) in
/// the d-split arm — extra blocks just queue on the pool.
pub fn work_blocks(o: usize, d: usize, workers: usize) -> Vec<Block> {
    if o == 0 || d == 0 {
        return vec![];
    }
    let w = workers.max(1);
    let mut blocks = vec![];
    if w <= o {
        for (o0, o1) in ranges(0, o, w) {
            blocks.push(Block { o0, o1, d0: 0, d1: d });
        }
    } else {
        let per_row = w.div_ceil(o).min(d).max(1);
        for oi in 0..o {
            for (d0, d1) in ranges(0, d, per_row) {
                blocks.push(Block { o0: oi, o1: oi + 1, d0, d1 });
            }
        }
    }
    blocks
}

/// Split a row-major [o x d] output buffer into one contiguous slice
/// per block (blocks tile the buffer in memory order).
fn split_out<'a>(
    out: &'a mut [f32],
    blocks: &[Block],
) -> Vec<&'a mut [f32]> {
    let mut slices = Vec::with_capacity(blocks.len());
    let mut rest: &mut [f32] = out;
    for b in blocks {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(b.len());
        slices.push(head);
        rest = tail;
    }
    debug_assert!(rest.is_empty());
    slices
}

/// Run `f(block, block_out)` over every block, fanned over the pool,
/// returning the per-block results in block order. Blocks are
/// disjoint, so any schedule writes each element exactly once —
/// bit-identical at every thread count.
fn run_blocks<R, F>(
    pool: &ScopedPool,
    blocks: &[Block],
    out: &mut [f32],
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(&Block, &mut [f32]) -> R + Sync,
{
    if blocks.len() <= 1 || pool.threads() == 1 {
        return blocks
            .iter()
            .zip(split_out(out, blocks))
            .map(|(b, s)| f(b, s))
            .collect();
    }
    let slices: Vec<Mutex<&mut [f32]>> = split_out(out, blocks)
        .into_iter()
        .map(Mutex::new)
        .collect();
    pool.map(blocks.len(), |i| {
        let mut s = slices[i].lock().unwrap();
        f(&blocks[i], &mut **s)
    })
}

// ---------------------------------------------------------------- exact

/// The one exact tiling loop, parameterized by the row-dot primitive:
/// u64-word XOR+popcount accumulation, d-tiled so a tile of packed
/// x-rows stays L1-resident across the o-sweep. Instantiated per tier
/// (the `target_feature` wrappers below) so the popcounts lower to
/// the best instruction the tier has — the blocking logic itself
/// exists exactly once.
#[inline(always)]
fn exact_block_with<D: Fn(&[u64], &[u64]) -> u32>(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
    dot: D,
) {
    let bw = b.d1 - b.d0;
    let beta = eng.beta as i64;
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            let row = &mut out[(oi - b.o0) * bw..(oi - b.o0 + 1) * bw];
            for di in t0..t1 {
                let sum = dot(wr, x.row64(di));
                row[di - b.d0] = (2 * sum as i64 - beta) as f32;
            }
        }
    }
}

/// Portable row dot: one XOR+NOT+popcount per u64 storage word.
#[inline(always)]
fn xnor_popcount_words(w: &[u64], x: &[u64]) -> u32 {
    let mut sum = 0u32;
    for (a, c) in w.iter().zip(x.iter()) {
        sum += (!(a ^ c)).count_ones();
    }
    sum
}

#[inline(always)]
fn exact_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    exact_block_with(eng, x, b, out, xnor_popcount_words);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,popcnt")]
unsafe fn exact_block_avx2(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    // rows of >= 8 u64 words (K >= 512) amortize the vpshufb LUT
    // popcount; shorter rows run the popcnt-instruction loop that
    // `count_ones` lowers to under this target_feature
    if x.words64_per_row >= 8 {
        exact_block_with(eng, x, b, out, |w, xr| {
            // safety: same target features as the enclosing fn
            unsafe { xnor_popcount_avx2(w, xr) }
        });
    } else {
        exact_block_body(eng, x, b, out);
    }
}

/// Mula's AVX2 nibble-LUT popcount over `!(w ^ x)`, 4 u64 words per
/// step, `_mm256_sad_epu8` folding byte counts into 4 u64 lanes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,popcnt")]
unsafe fn xnor_popcount_avx2(w: &[u64], x: &[u64]) -> u32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let low_mask = _mm256_set1_epi8(0x0f);
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1,
        2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let ones = _mm256_set1_epi8(-1);
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 4 <= n {
        let a = _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i);
        let c = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        // XNOR: !(a ^ c) == (a ^ c) ^ ~0
        let v = _mm256_xor_si256(_mm256_xor_si256(a, c), ones);
        let lo = _mm256_and_si256(v, low_mask);
        let hi =
            _mm256_and_si256(_mm256_srli_epi16::<4>(v), low_mask);
        let cnt = _mm256_add_epi8(
            _mm256_shuffle_epi8(lut, lo),
            _mm256_shuffle_epi8(lut, hi),
        );
        acc = _mm256_add_epi64(
            acc,
            _mm256_sad_epu8(cnt, _mm256_setzero_si256()),
        );
        i += 4;
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut sum =
        (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32;
    while i < n {
        sum += (!(w[i] ^ x[i])).count_ones();
        i += 1;
    }
    sum
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn exact_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    // under the neon target feature `count_ones` lowers to cnt + addv
    exact_block_body(eng, x, b, out);
}

fn exact_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: Avx2 is only constructed after runtime detection
        KernelKind::Avx2 => unsafe { exact_block_avx2(eng, x, b, out) },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe { exact_block_neon(eng, x, b, out) },
        _ => exact_block_body(eng, x, b, out),
    }
}

/// Exact +-1 matmul into a caller-provided [o x d] buffer (the native
/// backend's scratch arena) — no steady-state allocation.
pub fn matmul_exact_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    out: &mut [f32],
) {
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    let blocks = work_blocks(o, d, pool.threads());
    run_blocks(pool, &blocks, out, |b, s| exact_block(kind, eng, x, b, s));
}

/// Exact +-1 matmul: out [o x d] row-major. Bit-identical to
/// [`SubMacEngine::matmul_exact`] at every tier and thread count.
pub fn matmul_exact(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
) -> Vec<f32> {
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    matmul_exact_into(pool, eng, x, kind, &mut out);
    out
}

// ----------------------------------------------------------- histogram

/// Per-element group walk shared by the histogram and fused kernels:
/// calls `tally(level)` for each *real* group (the phantom high half
/// of an odd trailing word is skipped) and returns the u64-word level
/// sum (phantom contributes 0 by the pad convention, so the sum equals
/// the real groups' sum exactly).
#[inline(always)]
fn walk_groups<F: FnMut(u32)>(
    wr: &[u64],
    xr: &[u64],
    g: usize,
    mut tally: F,
) -> u32 {
    let mut sum = 0u32;
    let mut gi = 0usize;
    for (a, c) in wr.iter().zip(xr.iter()) {
        let y = !(a ^ c);
        let lo = (y as u32).count_ones();
        sum += lo;
        tally(lo);
        gi += 1;
        if gi < g {
            let hi = ((y >> 32) as u32).count_ones();
            sum += hi;
            tally(hi);
            gi += 1;
        } else {
            // phantom half: popcount 0 by construction
            debug_assert_eq!((y >> 32).count_ones(), 0);
        }
    }
    sum
}

#[inline(always)]
fn hist_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    let g = eng.n_groups();
    let mut hist = [0u64; N_LEVELS];
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            for di in t0..t1 {
                walk_groups(wr, x.row64(di), g, |level| {
                    hist[level as usize] += 1;
                });
            }
        }
    }
    hist
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn hist_block_popcnt(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    hist_block_body(eng, x, b)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn hist_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    hist_block_body(eng, x, b)
}

fn hist_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: Avx2 is only constructed after runtime detection
        KernelKind::Avx2 => unsafe { hist_block_popcnt(eng, x, b) },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe { hist_block_neon(eng, x, b) },
        _ => hist_block_body(eng, x, b),
    }
}

fn merge_hists(parts: Vec<[u64; N_LEVELS]>) -> [u64; N_LEVELS] {
    let mut hist = [0u64; N_LEVELS];
    for part in parts {
        for (a, b) in hist.iter_mut().zip(part.iter()) {
            *a += b;
        }
    }
    hist
}

/// F_MAC level histogram of one matmul, fanned over `pool` (per-block
/// histograms merge by addition, so the fan-out is exact).
/// Bit-identical to [`SubMacEngine::histogram`].
pub fn histogram(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
) -> [u64; N_LEVELS] {
    let (o, d) = (eng.w.rows, x.rows);
    let blocks = work_blocks(o, d, pool.threads());
    merge_hists(
        pool.map(blocks.len(), |i| hist_block(kind, eng, x, &blocks[i])),
    )
}

// --------------------------------------------------------------- fused

#[inline(always)]
fn fused_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    let g = eng.n_groups();
    let bw = b.d1 - b.d0;
    let beta = eng.beta as i64;
    let mut hist = [0u64; N_LEVELS];
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            let row = &mut out[(oi - b.o0) * bw..(oi - b.o0 + 1) * bw];
            for di in t0..t1 {
                let sum = walk_groups(wr, x.row64(di), g, |level| {
                    hist[level as usize] += 1;
                });
                row[di - b.d0] = (2 * sum as i64 - beta) as f32;
            }
        }
    }
    hist
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn fused_block_popcnt(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    fused_block_body(eng, x, b, out)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn fused_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    fused_block_body(eng, x, b, out)
}

fn fused_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: Avx2 is only constructed after runtime detection
        KernelKind::Avx2 => unsafe { fused_block_popcnt(eng, x, b, out) },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe { fused_block_neon(eng, x, b, out) },
        _ => fused_block_body(eng, x, b, out),
    }
}

/// Exact matmul *and* F_MAC histogram in one pass over the operands —
/// the clean F_MAC extraction walks memory once instead of twice. The
/// outputs are bit-identical to [`matmul_exact_into`] +
/// [`histogram`] run separately, at every tier and thread count.
pub fn matmul_exact_fused_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    let blocks = work_blocks(o, d, pool.threads());
    merge_hists(run_blocks(pool, &blocks, out, |b, s| {
        fused_block(kind, eng, x, b, s)
    }))
}

/// Allocating convenience wrapper over [`matmul_exact_fused_into`].
pub fn matmul_exact_fused(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
) -> (Vec<f32>, [u64; N_LEVELS]) {
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    let hist = matmul_exact_fused_into(pool, eng, x, kind, &mut out);
    (out, hist)
}

// --------------------------------------------------------------- error

#[inline(always)]
fn error_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    let g = eng.n_groups();
    let bw = b.d1 - b.d0;
    let d = x.rows;
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            let row = &mut out[(oi - b.o0) * bw..(oi - b.o0 + 1) * bw];
            for di in t0..t1 {
                let mut acc = 0.0f32;
                let mut gi = 0u32;
                // walk_groups yields real-group levels in gi order —
                // the same shared walk (and phantom-half skip) as the
                // histogram and fused kernels
                walk_groups(wr, x.row64(di), g, |level| {
                    // logical index (o*G + g)*D + d — kernel layout
                    let lin = salt.wrapping_add(
                        ((oi as u32) * (g as u32))
                            .wrapping_add(gi)
                            .wrapping_mul(d as u32)
                            .wrapping_add(di as u32),
                    );
                    acc += 2.0
                        * em.decode(level as usize, hash01(seed, lin));
                    gi += 1;
                });
                row[di - b.d0] = acc - eng.beta as f32;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
#[allow(clippy::too_many_arguments)]
unsafe fn error_block_popcnt(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    error_block_body(eng, x, em, seed, salt, b, out)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn error_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    error_block_body(eng, x, em, seed, salt, b, out)
}

#[allow(clippy::too_many_arguments)]
fn error_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: Avx2 is only constructed after runtime detection
        KernelKind::Avx2 => unsafe {
            error_block_popcnt(eng, x, em, seed, salt, b, out)
        },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe {
            error_block_neon(eng, x, em, seed, salt, b, out)
        },
        _ => error_block_body(eng, x, em, seed, salt, b, out),
    }
}

/// Error-model matmul into a caller-provided buffer. The PRNG is
/// indexed by the logical element position, so this is bit-identical
/// to [`SubMacEngine::matmul_error`] at every tier and thread count.
#[allow(clippy::too_many_arguments)]
pub fn matmul_error_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    kind: KernelKind,
    out: &mut [f32],
) {
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    let blocks = work_blocks(o, d, pool.threads());
    run_blocks(pool, &blocks, out, |b, s| {
        error_block(kind, eng, x, em, seed, salt, b, s)
    });
}

/// Error-model matmul (allocating wrapper).
pub fn matmul_error(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    kind: KernelKind,
) -> Vec<f32> {
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    matmul_error_into(pool, eng, x, em, seed, salt, kind, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_engine(
        rng: &mut Rng,
        o: usize,
        k: usize,
        d: usize,
    ) -> (SubMacEngine, BitMatrix) {
        let w: Vec<f32> = (0..o * k).map(|_| rng.pm1(0.5)).collect();
        let x: Vec<f32> = (0..d * k).map(|_| rng.pm1(0.5)).collect();
        (
            SubMacEngine::new(o, k, &w, k),
            BitMatrix::pack(d, k, &x, false),
        )
    }

    fn rand_em(rng: &mut Rng) -> ErrorModel {
        let mut full = vec![vec![0.0f64; N_LEVELS]; N_LEVELS];
        for (m, row) in full.iter_mut().enumerate() {
            let mut tot = 0.0;
            for dlt in -2i64..=2 {
                let j = (m as i64 + dlt).clamp(0, 32) as usize;
                let w = rng.f64() + 0.05;
                row[j] += w;
                tot += w;
            }
            row.iter_mut().for_each(|v| *v /= tot);
        }
        ErrorModel::from_full(&full)
    }

    /// Every tier the running CPU can execute (scalar always; the
    /// detected SIMD tier when there is one).
    fn tiers() -> Vec<KernelKind> {
        let mut ts = vec![KernelKind::Scalar];
        let det = KernelKind::detect();
        if det != KernelKind::Scalar {
            ts.push(det);
        }
        ts
    }

    #[test]
    fn exact_matches_scalar_engine_across_tiers() {
        let mut rng = Rng::new(31);
        // includes odd group counts (ragged u64 rows) and long rows
        // that exercise the AVX2 LUT path (k = 640 -> 10 u64 words)
        for (o, k, d) in
            [(5, 64, 300), (17, 96, 131), (1, 32, 1), (3, 640, 70)]
        {
            let (eng, xb) = rand_engine(&mut rng, o, k, d);
            let want = eng.matmul_exact(&xb);
            for kind in tiers() {
                let pool = ScopedPool::sequential();
                assert_eq!(
                    matmul_exact(&pool, &eng, &xb, kind),
                    want,
                    "{} o={o} k={k} d={d}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn threaded_exact_matches_scalar_at_every_pool_size() {
        let mut rng = Rng::new(32);
        let (eng, xb) = rand_engine(&mut rng, 13, 64, 257);
        let want = eng.matmul_exact(&xb);
        for kind in tiers() {
            for threads in [1usize, 2, 3, 8, 32] {
                let pool = ScopedPool::new(threads);
                assert_eq!(
                    matmul_exact(&pool, &eng, &xb, kind),
                    want,
                    "{} threads {threads}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn small_o_splits_d_and_stays_exact() {
        // o < workers: the d-split path must still be bit-identical
        let mut rng = Rng::new(35);
        let (eng, xb) = rand_engine(&mut rng, 2, 96, 533);
        let want = eng.matmul_exact(&xb);
        for threads in [8usize, 16] {
            let pool = ScopedPool::new(threads);
            for kind in tiers() {
                assert_eq!(
                    matmul_exact(&pool, &eng, &xb, kind),
                    want,
                    "{} threads {threads}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn threaded_error_matches_scalar_bitwise() {
        let mut rng = Rng::new(33);
        let (eng, xb) = rand_engine(&mut rng, 9, 96, 200);
        let em = rand_em(&mut rng);
        for (seed, salt) in [(0u32, 0u32), (7, 0x9E3779B1), (0xDEAD, 42)]
        {
            let want = eng.matmul_error(&xb, &em, seed, salt);
            for kind in tiers() {
                for threads in [1usize, 2, 5, 16] {
                    let pool = ScopedPool::new(threads);
                    assert_eq!(
                        matmul_error(
                            &pool, &eng, &xb, &em, seed, salt, kind
                        ),
                        want,
                        "{} seed {seed} salt {salt} threads {threads}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn histogram_matches_engine() {
        let mut rng = Rng::new(34);
        let (eng, xb) = rand_engine(&mut rng, 6, 96, 77);
        let want = eng.histogram(&xb);
        for kind in tiers() {
            for threads in [1usize, 3, 9] {
                let pool = ScopedPool::new(threads);
                assert_eq!(
                    histogram(&pool, &eng, &xb, kind),
                    want,
                    "{} threads {threads}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn fused_matches_separate_paths() {
        let mut rng = Rng::new(36);
        for (o, k, d) in [(6, 96, 77), (2, 160, 210), (11, 32, 40)] {
            let (eng, xb) = rand_engine(&mut rng, o, k, d);
            let want_out = eng.matmul_exact(&xb);
            let want_hist = eng.histogram(&xb);
            for kind in tiers() {
                for threads in [1usize, 2, 7] {
                    let pool = ScopedPool::new(threads);
                    let (out, hist) =
                        matmul_exact_fused(&pool, &eng, &xb, kind);
                    assert_eq!(
                        out,
                        want_out,
                        "{} o={o} threads {threads}",
                        kind.name()
                    );
                    assert_eq!(
                        hist,
                        want_hist,
                        "{} o={o} threads {threads}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn work_blocks_cover_grid_without_empties() {
        for (o, d, w) in [
            (10, 50, 3),
            (3, 1000, 8),
            (1, 1, 1),
            (64, 64, 64),
            (2, 7, 16),
            (1, 3, 64),
            (5, 4, 0),
        ] {
            let blocks = work_blocks(o, d, w);
            let mut covered = 0usize;
            for b in &blocks {
                assert!(!b.is_empty(), "empty block in {o}x{d}/{w}");
                covered += b.len();
            }
            assert_eq!(covered, o * d, "coverage {o}x{d}/{w}");
            // memory order: each block starts where the previous ended
            let mut at = 0usize;
            for b in &blocks {
                assert_eq!(b.o0 * d + b.d0, at, "order {o}x{d}/{w}");
                at += b.len();
            }
            // o < workers engages the d-split so no worker idles
            if o < w && d >= w.div_ceil(o) {
                assert!(
                    blocks.len() >= w.min(o * d),
                    "{o}x{d}/{w}: only {} blocks",
                    blocks.len()
                );
            }
        }
    }

    #[test]
    fn kernel_kind_resolves() {
        assert_eq!(
            KernelKind::resolve("scalar").unwrap(),
            KernelKind::Scalar
        );
        let auto = KernelKind::resolve("auto").unwrap();
        assert_eq!(auto, KernelKind::detect());
        assert!(KernelKind::resolve("tpu").is_err());
        // explicit SIMD names resolve exactly when detected
        for simd in ["avx2", "neon"] {
            match KernelKind::resolve(simd) {
                Ok(k) => assert_eq!(k.name(), simd),
                Err(e) => {
                    assert!(e.to_string().contains(simd), "{e}")
                }
            }
        }
    }
}
