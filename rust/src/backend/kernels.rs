//! Width-dispatched popcount sub-MAC microkernels (DESIGN.md §11).
//!
//! Semantics are *identical* to the scalar [`SubMacEngine`] loops (and
//! therefore to the AOT kernels): every output element is
//! `2 * sum_g decode(level_g, u(o,g,d)) - beta` with the counter-based
//! PRNG indexed by the logical `(o*G + g)*D + d` position. All math on
//! the hot path is integer (XOR + popcount over the packed u64 words,
//! pad and phantom bits vanish by the non-conducting convention), so
//! every kernel tier, tile size and thread count is bit-exact —
//! pinned by the in-file tests and `tests/backend.rs`.
//!
//! Four layers, modeled on the runtime-dispatch architecture of the
//! `gemm` crates referenced in SNIPPETS.md:
//!
//! * **Tier dispatch** ([`KernelKind`]): one generic, `inline(always)`
//!   kernel body instantiated per CPU tier — `scalar` (portable),
//!   `avx2` (x86_64, runtime-detected AVX2 + hardware POPCNT; long
//!   rows additionally run a vpshufb nibble-LUT popcount), `avx512`
//!   (x86_64, `VPOPCNTQ` vector popcounts under avx512vpopcntdq),
//!   `neon` (aarch64, `cnt`-lowered popcounts under the neon target
//!   feature). `--kernel scalar|auto` selects; the resolved tier is
//!   recorded in point-cache meta.
//! * **Blocking** ([`work_blocks`]): the (o x d) output grid splits
//!   into contiguous, non-empty rectangles — o-blocks while `o >=
//!   workers`, per-row d-splits otherwise, so small-o matmuls (early
//!   convs) no longer idle most of the pool. Within a block, d-tiles
//!   of [`TILE_D`] x-rows stay resident in L1 across the o-sweep.
//! * **Register blocking** ([`matmul_exact_tiled_into`], DESIGN.md
//!   §14): both operands repack into lane-interleaved panels
//!   ([`pack_a_block`]/[`pack_b_block`]) and an MR x NR microkernel
//!   holds the popcount accumulators for a whole output tile in
//!   registers across the K sweep — one vector load fetches the next
//!   K-word of NR activation rows at once. The (MR, NR, K-chunk)
//!   [`Tile`] is autotuned per machine (`backend::autotune`) and
//!   recorded in point meta; `--tile scalar-safe` falls back to the
//!   per-word kernels.
//! * **Fusion** ([`matmul_exact_fused_into`]): the clean F_MAC pass
//!   computes outputs *and* per-group level histograms in one walk
//!   over the operands instead of two.

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::bnn::bitpack::BitMatrix;
use crate::bnn::hashrng::hash01;
use crate::bnn::{ErrorModel, SubMacEngine};
use crate::capmin::N_LEVELS;
use crate::util::pool::ScopedPool;

/// Activation rows held hot per tile: 128 rows of packed words is a
/// few tens of KiB for every registry shape — inside L2 and usually
/// L1 on the testbed cores.
pub const TILE_D: usize = 128;

/// A resolved kernel tier. `Scalar` is the portable fallback; the SIMD
/// tiers are only ever constructed when the running CPU supports them
/// (runtime detection), so executing them is safe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable u64 XOR+popcount (compiler-lowered `count_ones`).
    Scalar,
    /// x86_64 AVX2 + hardware POPCNT (runtime-detected).
    Avx2,
    /// x86_64 AVX-512 `VPOPCNTQ` (avx512vpopcntdq, runtime-detected).
    Avx512,
    /// aarch64 NEON `cnt`-lowered popcounts (runtime-detected).
    Neon,
}

impl KernelKind {
    /// CLI values `--kernel` accepts. `auto` resolves per machine;
    /// naming a SIMD tier explicitly errors unless the CPU has it.
    pub const CHOICES: &'static [&'static str] =
        &["auto", "scalar", "avx2", "avx512", "neon"];

    /// Every tier, best first — [`KernelKind::detect`]'s fallback
    /// order (avx512 → avx2 → neon → scalar).
    pub const TIERS: &'static [KernelKind] = &[
        KernelKind::Avx512,
        KernelKind::Avx2,
        KernelKind::Neon,
        KernelKind::Scalar,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Avx512 => "avx512",
            KernelKind::Neon => "neon",
        }
    }

    /// Whether the running CPU can execute this tier. `Scalar` is
    /// always supported; SIMD tiers check the exact feature set their
    /// kernels need. `Avx512` additionally requires the AVX2 + POPCNT
    /// features its non-8-lane tile fallbacks use, so a CPU with the
    /// `VPOPCNTQ` extension but a partial stack cleanly falls back to
    /// the next tier instead of faulting mid-kernel.
    pub fn supported(self) -> bool {
        match self {
            KernelKind::Scalar => true,
            KernelKind::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("popcnt")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelKind::Avx512 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                        && std::arch::is_x86_feature_detected!(
                            "avx512vpopcntdq"
                        )
                        && std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("popcnt")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelKind::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    /// The best tier the running CPU supports: the first supported
    /// entry of [`KernelKind::TIERS`], so partial AVX-512 support
    /// (e.g. avx512f without avx512vpopcntdq) falls back to avx2,
    /// then scalar.
    pub fn detect() -> KernelKind {
        *KernelKind::TIERS
            .iter()
            .find(|t| t.supported())
            .expect("scalar tier is always supported")
    }

    /// Resolve a `--kernel` request against the running CPU. `auto`
    /// picks the detected tier; `scalar` forces the portable kernel
    /// (cold-path measurements, bit-equality cross-checks); an
    /// explicit SIMD name is accepted whenever the CPU supports it —
    /// `--kernel avx2` still resolves on an AVX-512 machine (pinned
    /// configs keep working across hardware upgrades) but errors on
    /// CPUs without the feature.
    pub fn resolve(requested: &str) -> Result<KernelKind> {
        let kind = match requested {
            "auto" => return Ok(KernelKind::detect()),
            "scalar" => return Ok(KernelKind::Scalar),
            "avx2" => KernelKind::Avx2,
            "avx512" => KernelKind::Avx512,
            "neon" => KernelKind::Neon,
            other => {
                return Err(anyhow!(
                    "bad --kernel `{other}`: expected one of auto, \
                     scalar, avx2, avx512, neon"
                ))
            }
        };
        if kind.supported() {
            Ok(kind)
        } else {
            Err(anyhow!(
                "--kernel {requested} is not supported on this CPU \
                 (detected tier: {}); use --kernel auto or scalar",
                KernelKind::detect().name()
            ))
        }
    }
}

/// A register-blocking tile for the packed bit-GEMM path (DESIGN.md
/// §14): MR weight rows x NR activation rows per microkernel call,
/// with the K dimension swept in `kb`-word chunks. MR and NR are
/// limited to the const-generic instantiations the kernels compile
/// ([`Tile::LANES`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    pub mr: usize,
    pub nr: usize,
    pub kb: usize,
}

impl Tile {
    /// MR/NR values with a compiled microkernel instantiation.
    pub const LANES: &'static [usize] = &[1, 2, 4, 8];

    /// Default K-chunk: 64 u64 words (K = 4096 bits) per accumulate
    /// chunk — wider than every registry shape, so chunking only
    /// engages on oversized synthetic engines.
    pub const DEFAULT_KB: usize = 64;

    pub fn new(mr: usize, nr: usize, kb: usize) -> Tile {
        Tile { mr, nr, kb }
    }

    /// `MRxNRkKB`, e.g. `4x8k64` — recorded in point meta and the
    /// autotune cache.
    pub fn name(&self) -> String {
        format!("{}x{}k{}", self.mr, self.nr, self.kb)
    }

    /// Whether the blocked kernels ship an instantiation for this
    /// tile.
    pub fn is_valid(&self) -> bool {
        Tile::LANES.contains(&self.mr)
            && Tile::LANES.contains(&self.nr)
            && self.kb >= 1
    }

    /// The shape used when no autotune measurement is available: NR
    /// matched to the tier's vector popcount width (8 u64 lanes under
    /// VPOPCNTQ, 4 elsewhere), MR = 4 output rows held in registers.
    pub fn default_for(kind: KernelKind) -> Tile {
        match kind {
            KernelKind::Avx512 => Tile::new(4, 8, Tile::DEFAULT_KB),
            _ => Tile::new(4, 4, Tile::DEFAULT_KB),
        }
    }

    /// Autotune candidates per tier: NR pinned to the tier's vector
    /// width, MR swept over the register-pressure trade-off, plus one
    /// short-KB variant probing L1-resident K-chunks.
    pub fn candidates(kind: KernelKind) -> Vec<Tile> {
        match kind {
            KernelKind::Avx512 => vec![
                Tile::new(2, 8, 64),
                Tile::new(4, 8, 64),
                Tile::new(8, 8, 64),
                Tile::new(4, 8, 16),
            ],
            KernelKind::Avx2 => vec![
                Tile::new(2, 4, 64),
                Tile::new(4, 4, 64),
                Tile::new(8, 4, 64),
                Tile::new(4, 4, 16),
            ],
            _ => vec![
                Tile::new(2, 4, 64),
                Tile::new(4, 4, 64),
                Tile::new(4, 8, 64),
                Tile::new(8, 4, 64),
            ],
        }
    }
}

/// A parsed `--tile` request; resolved per machine by
/// [`crate::backend::autotune::resolve`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileSpec {
    /// Measure candidate tiles once per machine and cache the winner
    /// in `runs/autotune.json`.
    Auto,
    /// Escape hatch (`--tile scalar-safe`): bypass the blocked path
    /// and run the per-word kernels.
    ScalarSafe,
    /// A pinned `MRxNR[kKB]` tile.
    Fixed(Tile),
}

impl TileSpec {
    pub fn parse(s: &str) -> Result<TileSpec> {
        match s {
            "auto" => return Ok(TileSpec::Auto),
            "scalar-safe" => return Ok(TileSpec::ScalarSafe),
            _ => {}
        }
        let bad = || {
            anyhow!(
                "bad --tile `{s}`: expected auto, scalar-safe, or \
                 MRxNR[kKB] with MR, NR in {{1, 2, 4, 8}} — e.g. 4x8 \
                 or 4x8k32"
            )
        };
        let (mr_s, rest) = s.split_once('x').ok_or_else(bad)?;
        let (nr_s, kb_s) = match rest.split_once('k') {
            Some((nr_s, kb_s)) => (nr_s, Some(kb_s)),
            None => (rest, None),
        };
        let mr = mr_s.parse::<usize>().map_err(|_| bad())?;
        let nr = nr_s.parse::<usize>().map_err(|_| bad())?;
        let kb = match kb_s {
            Some(kb_s) => kb_s.parse::<usize>().map_err(|_| bad())?,
            None => Tile::DEFAULT_KB,
        };
        let tile = Tile::new(mr, nr, kb);
        if !tile.is_valid() {
            return Err(bad());
        }
        Ok(TileSpec::Fixed(tile))
    }
}

/// A per-machine resolved tile choice. Recorded in `PointMeta` next
/// to the kernel tier (provenance, never part of cache keys).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolvedTile {
    /// Run the per-word kernels (escape hatch + bench baseline).
    ScalarSafe,
    /// Run the register-blocked packed path with this tile.
    Blocked(Tile),
}

impl ResolvedTile {
    pub fn name(&self) -> String {
        match self {
            ResolvedTile::ScalarSafe => "scalar-safe".to_string(),
            ResolvedTile::Blocked(t) => t.name(),
        }
    }
}

/// One rectangular work item of the row-major (o x d) output grid:
/// rows `o0..o1`, columns `d0..d1`. [`work_blocks`] only emits shapes
/// whose output elements are contiguous in the row-major buffer
/// (full-width o-blocks, or single-row d-spans).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Block {
    pub o0: usize,
    pub o1: usize,
    pub d0: usize,
    pub d1: usize,
}

impl Block {
    pub fn len(&self) -> usize {
        (self.o1 - self.o0) * (self.d1 - self.d0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Split `start..end` into `n <= end - start` contiguous, non-empty
/// ranges.
fn ranges(start: usize, end: usize, n: usize) -> Vec<(usize, usize)> {
    let len = end - start;
    let n = n.min(len).max(1);
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut at = start;
    for i in 0..n {
        let step = base + usize::from(i < extra);
        out.push((at, at + step));
        at += step;
    }
    out
}

/// Contiguous, non-empty work blocks covering the (o x d) grid in
/// row-major memory order. While `o >= workers` the split is by output
/// rows (one concat-free slice per worker); when `o < workers` —
/// early convs have o as low as 8 while d is in the thousands — each
/// row additionally splits its d-span so no pool worker idles. Every
/// block is non-empty; the list holds at most `workers` items in the
/// o-split arm and at most `o * ceil(workers/o)` (< workers + o) in
/// the d-split arm — extra blocks just queue on the pool.
pub fn work_blocks(o: usize, d: usize, workers: usize) -> Vec<Block> {
    if o == 0 || d == 0 {
        return vec![];
    }
    let w = workers.max(1);
    let mut blocks = vec![];
    if w <= o {
        for (o0, o1) in ranges(0, o, w) {
            blocks.push(Block { o0, o1, d0: 0, d1: d });
        }
    } else {
        let per_row = w.div_ceil(o).min(d).max(1);
        for oi in 0..o {
            for (d0, d1) in ranges(0, d, per_row) {
                blocks.push(Block { o0: oi, o1: oi + 1, d0, d1 });
            }
        }
    }
    blocks
}

/// Split a row-major [o x d] output buffer into one contiguous slice
/// per block (blocks tile the buffer in memory order).
fn split_out<'a>(
    out: &'a mut [f32],
    blocks: &[Block],
) -> Vec<&'a mut [f32]> {
    let mut slices = Vec::with_capacity(blocks.len());
    let mut rest: &mut [f32] = out;
    for b in blocks {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(b.len());
        slices.push(head);
        rest = tail;
    }
    debug_assert!(rest.is_empty());
    slices
}

/// Run `f(block, block_out)` over every block, fanned over the pool,
/// returning the per-block results in block order. Blocks are
/// disjoint, so any schedule writes each element exactly once —
/// bit-identical at every thread count.
fn run_blocks<R, F>(
    pool: &ScopedPool,
    blocks: &[Block],
    out: &mut [f32],
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(&Block, &mut [f32]) -> R + Sync,
{
    if blocks.len() <= 1 || pool.threads() == 1 {
        return blocks
            .iter()
            .zip(split_out(out, blocks))
            .map(|(b, s)| f(b, s))
            .collect();
    }
    let slices: Vec<Mutex<&mut [f32]>> = split_out(out, blocks)
        .into_iter()
        .map(Mutex::new)
        .collect();
    pool.map(blocks.len(), |i| {
        let mut s = slices[i].lock().unwrap();
        f(&blocks[i], &mut **s)
    })
}

// ---------------------------------------------------------------- exact

/// The one exact tiling loop, parameterized by the row-dot primitive:
/// u64-word XOR+popcount accumulation, d-tiled so a tile of packed
/// x-rows stays L1-resident across the o-sweep. Instantiated per tier
/// (the `target_feature` wrappers below) so the popcounts lower to
/// the best instruction the tier has — the blocking logic itself
/// exists exactly once.
#[inline(always)]
fn exact_block_with<D: Fn(&[u64], &[u64]) -> u32>(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
    dot: D,
) {
    let bw = b.d1 - b.d0;
    let beta = eng.beta as i64;
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            let row = &mut out[(oi - b.o0) * bw..(oi - b.o0 + 1) * bw];
            for di in t0..t1 {
                let sum = dot(wr, x.row64(di));
                row[di - b.d0] = (2 * sum as i64 - beta) as f32;
            }
        }
    }
}

/// Portable row dot: one XOR+NOT+popcount per u64 storage word.
#[inline(always)]
fn xnor_popcount_words(w: &[u64], x: &[u64]) -> u32 {
    let mut sum = 0u32;
    for (a, c) in w.iter().zip(x.iter()) {
        sum += (!(a ^ c)).count_ones();
    }
    sum
}

#[inline(always)]
fn exact_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    exact_block_with(eng, x, b, out, xnor_popcount_words);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,popcnt")]
unsafe fn exact_block_avx2(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    // rows of >= 8 u64 words (K >= 512) amortize the vpshufb LUT
    // popcount; shorter rows run the popcnt-instruction loop that
    // `count_ones` lowers to under this target_feature
    if x.words64_per_row >= 8 {
        exact_block_with(eng, x, b, out, |w, xr| {
            // safety: same target features as the enclosing fn
            unsafe { xnor_popcount_avx2(w, xr) }
        });
    } else {
        exact_block_body(eng, x, b, out);
    }
}

/// Mula's AVX2 nibble-LUT popcount over `!(w ^ x)`, 4 u64 words per
/// step, `_mm256_sad_epu8` folding byte counts into 4 u64 lanes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,popcnt")]
unsafe fn xnor_popcount_avx2(w: &[u64], x: &[u64]) -> u32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let low_mask = _mm256_set1_epi8(0x0f);
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1,
        2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let ones = _mm256_set1_epi8(-1);
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 4 <= n {
        let a = _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i);
        let c = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        // XNOR: !(a ^ c) == (a ^ c) ^ ~0
        let v = _mm256_xor_si256(_mm256_xor_si256(a, c), ones);
        let lo = _mm256_and_si256(v, low_mask);
        let hi =
            _mm256_and_si256(_mm256_srli_epi16::<4>(v), low_mask);
        let cnt = _mm256_add_epi8(
            _mm256_shuffle_epi8(lut, lo),
            _mm256_shuffle_epi8(lut, hi),
        );
        acc = _mm256_add_epi64(
            acc,
            _mm256_sad_epu8(cnt, _mm256_setzero_si256()),
        );
        i += 4;
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut sum =
        (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32;
    while i < n {
        sum += (!(w[i] ^ x[i])).count_ones();
        i += 1;
    }
    sum
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn exact_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    // under the neon target feature `count_ones` lowers to cnt + addv
    exact_block_body(eng, x, b, out);
}

fn exact_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: SIMD kinds pass runtime detection before
        // construction; Avx512's `supported` includes avx2 + popcnt,
        // so the per-word path shares the AVX2 kernel (the VPOPCNTQ
        // win lives in the blocked path)
        KernelKind::Avx2 | KernelKind::Avx512 => unsafe {
            exact_block_avx2(eng, x, b, out)
        },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe { exact_block_neon(eng, x, b, out) },
        _ => exact_block_body(eng, x, b, out),
    }
}

/// Exact +-1 matmul into a caller-provided [o x d] buffer (the native
/// backend's scratch arena) — no steady-state allocation.
pub fn matmul_exact_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    out: &mut [f32],
) {
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    let blocks = work_blocks(o, d, pool.threads());
    run_blocks(pool, &blocks, out, |b, s| exact_block(kind, eng, x, b, s));
}

/// Exact +-1 matmul: out [o x d] row-major. Bit-identical to
/// [`SubMacEngine::matmul_exact`] at every tier and thread count.
pub fn matmul_exact(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
) -> Vec<f32> {
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    matmul_exact_into(pool, eng, x, kind, &mut out);
    out
}

// ----------------------------------------------------------- histogram

/// Per-element group walk shared by the histogram and fused kernels:
/// calls `tally(level)` for each *real* group (the phantom high half
/// of an odd trailing word is skipped) and returns the u64-word level
/// sum (phantom contributes 0 by the pad convention, so the sum equals
/// the real groups' sum exactly).
#[inline(always)]
fn walk_groups<F: FnMut(u32)>(
    wr: &[u64],
    xr: &[u64],
    g: usize,
    mut tally: F,
) -> u32 {
    let mut sum = 0u32;
    let mut gi = 0usize;
    for (a, c) in wr.iter().zip(xr.iter()) {
        let y = !(a ^ c);
        let lo = (y as u32).count_ones();
        sum += lo;
        tally(lo);
        gi += 1;
        if gi < g {
            let hi = ((y >> 32) as u32).count_ones();
            sum += hi;
            tally(hi);
            gi += 1;
        } else {
            // phantom half: popcount 0 by construction
            debug_assert_eq!((y >> 32).count_ones(), 0);
        }
    }
    sum
}

#[inline(always)]
fn hist_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    let g = eng.n_groups();
    let mut hist = [0u64; N_LEVELS];
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            for di in t0..t1 {
                walk_groups(wr, x.row64(di), g, |level| {
                    hist[level as usize] += 1;
                });
            }
        }
    }
    hist
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn hist_block_popcnt(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    hist_block_body(eng, x, b)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn hist_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    hist_block_body(eng, x, b)
}

fn hist_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
) -> [u64; N_LEVELS] {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: SIMD kinds pass runtime detection; Avx512 implies
        // the popcnt feature this wrapper needs
        KernelKind::Avx2 | KernelKind::Avx512 => unsafe {
            hist_block_popcnt(eng, x, b)
        },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe { hist_block_neon(eng, x, b) },
        _ => hist_block_body(eng, x, b),
    }
}

fn merge_hists(parts: Vec<[u64; N_LEVELS]>) -> [u64; N_LEVELS] {
    let mut hist = [0u64; N_LEVELS];
    for part in parts {
        for (a, b) in hist.iter_mut().zip(part.iter()) {
            *a += b;
        }
    }
    hist
}

/// Bump the per-tier dispatch counter (`kernel.dispatch.<tier>`,
/// DESIGN.md §17). Handles resolve through the registry mutex once
/// per process and are cached, so each kernel entry pays one relaxed
/// atomic add — benches dispatch these thousands of times per second.
fn count_dispatch(kind: KernelKind) {
    use crate::obs::registry::{counter, Counter};
    use std::sync::{Arc, OnceLock};
    static TIERS: OnceLock<[Arc<Counter>; 4]> = OnceLock::new();
    let tiers = TIERS.get_or_init(|| {
        [
            counter("kernel.dispatch.scalar"),
            counter("kernel.dispatch.avx2"),
            counter("kernel.dispatch.avx512"),
            counter("kernel.dispatch.neon"),
        ]
    });
    let idx = match kind {
        KernelKind::Scalar => 0,
        KernelKind::Avx2 => 1,
        KernelKind::Avx512 => 2,
        KernelKind::Neon => 3,
    };
    tiers[idx].inc();
}

/// F_MAC level histogram of one matmul, fanned over `pool` (per-block
/// histograms merge by addition, so the fan-out is exact).
/// Bit-identical to [`SubMacEngine::histogram`].
pub fn histogram(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
) -> [u64; N_LEVELS] {
    count_dispatch(kind);
    let (o, d) = (eng.w.rows, x.rows);
    let blocks = work_blocks(o, d, pool.threads());
    merge_hists(
        pool.map(blocks.len(), |i| hist_block(kind, eng, x, &blocks[i])),
    )
}

// --------------------------------------------------------------- fused

#[inline(always)]
fn fused_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    let g = eng.n_groups();
    let bw = b.d1 - b.d0;
    let beta = eng.beta as i64;
    let mut hist = [0u64; N_LEVELS];
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            let row = &mut out[(oi - b.o0) * bw..(oi - b.o0 + 1) * bw];
            for di in t0..t1 {
                let sum = walk_groups(wr, x.row64(di), g, |level| {
                    hist[level as usize] += 1;
                });
                row[di - b.d0] = (2 * sum as i64 - beta) as f32;
            }
        }
    }
    hist
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn fused_block_popcnt(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    fused_block_body(eng, x, b, out)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn fused_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    fused_block_body(eng, x, b, out)
}

fn fused_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    b: &Block,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: SIMD kinds pass runtime detection; Avx512 implies
        // the popcnt feature this wrapper needs
        KernelKind::Avx2 | KernelKind::Avx512 => unsafe {
            fused_block_popcnt(eng, x, b, out)
        },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe { fused_block_neon(eng, x, b, out) },
        _ => fused_block_body(eng, x, b, out),
    }
}

/// Exact matmul *and* F_MAC histogram in one pass over the operands —
/// the clean F_MAC extraction walks memory once instead of twice. The
/// outputs are bit-identical to [`matmul_exact_into`] +
/// [`histogram`] run separately, at every tier and thread count.
pub fn matmul_exact_fused_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    let blocks = work_blocks(o, d, pool.threads());
    merge_hists(run_blocks(pool, &blocks, out, |b, s| {
        fused_block(kind, eng, x, b, s)
    }))
}

/// Allocating convenience wrapper over [`matmul_exact_fused_into`].
pub fn matmul_exact_fused(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
) -> (Vec<f32>, [u64; N_LEVELS]) {
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    let hist = matmul_exact_fused_into(pool, eng, x, kind, &mut out);
    (out, hist)
}

// --------------------------------------------------------------- error

#[inline(always)]
fn error_block_body(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    let g = eng.n_groups();
    let bw = b.d1 - b.d0;
    let d = x.rows;
    for t0 in (b.d0..b.d1).step_by(TILE_D) {
        let t1 = (t0 + TILE_D).min(b.d1);
        for oi in b.o0..b.o1 {
            let wr = eng.w.row64(oi);
            let row = &mut out[(oi - b.o0) * bw..(oi - b.o0 + 1) * bw];
            for di in t0..t1 {
                let mut acc = 0.0f32;
                let mut gi = 0u32;
                // walk_groups yields real-group levels in gi order —
                // the same shared walk (and phantom-half skip) as the
                // histogram and fused kernels
                walk_groups(wr, x.row64(di), g, |level| {
                    // logical index (o*G + g)*D + d — kernel layout
                    let lin = salt.wrapping_add(
                        ((oi as u32) * (g as u32))
                            .wrapping_add(gi)
                            .wrapping_mul(d as u32)
                            .wrapping_add(di as u32),
                    );
                    acc += 2.0
                        * em.decode(level as usize, hash01(seed, lin));
                    gi += 1;
                });
                row[di - b.d0] = acc - eng.beta as f32;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
#[allow(clippy::too_many_arguments)]
unsafe fn error_block_popcnt(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    error_block_body(eng, x, em, seed, salt, b, out)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn error_block_neon(
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    error_block_body(eng, x, em, seed, salt, b, out)
}

#[allow(clippy::too_many_arguments)]
fn error_block(
    kind: KernelKind,
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    b: &Block,
    out: &mut [f32],
) {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // safety: SIMD kinds pass runtime detection; Avx512 implies
        // the popcnt feature this wrapper needs
        KernelKind::Avx2 | KernelKind::Avx512 => unsafe {
            error_block_popcnt(eng, x, em, seed, salt, b, out)
        },
        #[cfg(target_arch = "aarch64")]
        // safety: Neon is only constructed after runtime detection
        KernelKind::Neon => unsafe {
            error_block_neon(eng, x, em, seed, salt, b, out)
        },
        _ => error_block_body(eng, x, em, seed, salt, b, out),
    }
}

/// Error-model matmul into a caller-provided buffer. The PRNG is
/// indexed by the logical element position, so this is bit-identical
/// to [`SubMacEngine::matmul_error`] at every tier and thread count.
#[allow(clippy::too_many_arguments)]
pub fn matmul_error_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    kind: KernelKind,
    out: &mut [f32],
) {
    count_dispatch(kind);
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    let blocks = work_blocks(o, d, pool.threads());
    run_blocks(pool, &blocks, out, |b, s| {
        error_block(kind, eng, x, em, seed, salt, b, s)
    });
}

/// Error-model matmul (allocating wrapper).
pub fn matmul_error(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    em: &ErrorModel,
    seed: u32,
    salt: u32,
    kind: KernelKind,
) -> Vec<f32> {
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    matmul_error_into(pool, eng, x, em, seed, salt, kind, &mut out);
    out
}

// ------------------------------------------------- blocked packed path
//
// The register-blocked bit-GEMM (DESIGN.md §14). Both operands repack
// into lane-interleaved panels, then MR x NR microkernels sweep the
// panel grid holding the whole accumulator tile in registers across
// K. The error-model and histogram-only paths stay on the per-word
// dispatch above: they are PRNG-decode/tally-bound, so register
// blocking buys them nothing.

/// Reusable packing buffers for the blocked path. The native backend
/// lends these from its scratch `Arena`, so steady-state packing
/// allocates nothing.
#[derive(Default)]
pub struct PackScratch {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
}

/// Pack weight rows `o0..o1` into MR-lane panels (see
/// [`BitMatrix::pack_panels`]): the microkernel reads K-word `k` of
/// its MR rows as one contiguous span.
pub fn pack_a_block(
    w: &BitMatrix,
    o0: usize,
    o1: usize,
    mr: usize,
    buf: &mut Vec<u64>,
) {
    w.pack_panels(o0, o1, mr, buf);
}

/// Pack activation rows `d0..d1` into NR-lane panels: one unaligned
/// vector load fetches K-word `k` of all NR output columns at once.
pub fn pack_b_block(
    x: &BitMatrix,
    d0: usize,
    d1: usize,
    nr: usize,
    buf: &mut Vec<u64>,
) {
    x.pack_panels(d0, d1, nr, buf);
}

/// Raw output base shared across pool workers. Safety: the panel grid
/// assigns every (o, d) output cell to exactly one panel block, so
/// concurrent workers write disjoint elements and never alias.
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);

unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Everything a blocked worker needs: packed operands, geometry, the
/// resolved tile, and the shared output base.
#[derive(Clone, Copy)]
struct BlockedJob<'a> {
    a: &'a [u64],
    b: &'a [u64],
    kw: usize,
    o: usize,
    d: usize,
    beta: i64,
    tile: Tile,
    out: OutPtr,
}

/// Instantiate a blocked kernel for the tile's MR — the compiled lane
/// counts mirror [`Tile::LANES`] (entry points assert validity).
macro_rules! dispatch_mr {
    ($f:ident, $tile:expr, $($args:expr),+ $(,)?) => {
        match $tile.mr {
            1 => $f::<1>($($args),+),
            2 => $f::<2>($($args),+),
            4 => $f::<4>($($args),+),
            _ => $f::<8>($($args),+),
        }
    };
}

/// Portable MR x NR panel kernel: a fixed-width accumulator block
/// (registers for the small tiles) swept across K in `kb`-word
/// chunks. Pad lanes compute garbage counts that are simply never
/// stored (`mr_real`/`nr_real` clamp the writeback).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn blocked_panel_scalar<const MR: usize>(
    job: &BlockedJob,
    ap: &[u64],
    bp: &[u64],
    o_base: usize,
    d_base: usize,
    mr_real: usize,
    nr_real: usize,
) {
    let (kw, nr) = (job.kw, job.tile.nr);
    let kb = job.tile.kb.max(1);
    // nr <= 8 by Tile validation; the unused tail lanes are dead code
    // after const-folding
    let mut acc = [[0u32; 8]; MR];
    let mut k0 = 0usize;
    while k0 < kw {
        let k1 = (k0 + kb).min(kw);
        for k in k0..k1 {
            let brow = &bp[k * nr..k * nr + nr];
            for (m, accm) in acc.iter_mut().enumerate() {
                let aw = ap[k * MR + m];
                for (n, &bw) in brow.iter().enumerate() {
                    accm[n] += (!(aw ^ bw)).count_ones();
                }
            }
        }
        k0 = k1;
    }
    for (m, accm) in acc.iter().take(mr_real).enumerate() {
        for (n, &cnt) in accm.iter().take(nr_real).enumerate() {
            *job.out.0.add((o_base + m) * job.d + d_base + n) =
                (2 * cnt as i64 - job.beta) as f32;
        }
    }
}

/// AVX2 MR x 4 panel kernel: broadcast one weight word, XNOR against
/// a 4-lane activation vector, Mula nibble-LUT popcount, and
/// `_mm256_sad_epu8` into one u64-lane accumulator vector per output
/// row — MR vectors live in registers across the whole K sweep.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn blocked_panel_avx2<const MR: usize>(
    job: &BlockedJob,
    ap: &[u64],
    bp: &[u64],
    o_base: usize,
    d_base: usize,
    mr_real: usize,
    nr_real: usize,
) {
    use std::arch::x86_64::*;
    let kw = job.kw;
    let kb = job.tile.kb.max(1);
    let low_mask = _mm256_set1_epi8(0x0f);
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1,
        2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let ones = _mm256_set1_epi8(-1);
    let zero = _mm256_setzero_si256();
    let mut acc = [zero; MR];
    let mut k0 = 0usize;
    while k0 < kw {
        let k1 = (k0 + kb).min(kw);
        for k in k0..k1 {
            let bv = _mm256_loadu_si256(
                bp.as_ptr().add(k * 4) as *const __m256i
            );
            for (m, accm) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_epi64x(ap[k * MR + m] as i64);
                // XNOR: !(a ^ b) == (a ^ b) ^ ~0
                let v =
                    _mm256_xor_si256(_mm256_xor_si256(av, bv), ones);
                let lo = _mm256_and_si256(v, low_mask);
                let hi = _mm256_and_si256(
                    _mm256_srli_epi16::<4>(v),
                    low_mask,
                );
                let cnt = _mm256_add_epi8(
                    _mm256_shuffle_epi8(lut, lo),
                    _mm256_shuffle_epi8(lut, hi),
                );
                *accm = _mm256_add_epi64(
                    *accm,
                    _mm256_sad_epu8(cnt, zero),
                );
            }
        }
        k0 = k1;
    }
    for m in 0..mr_real {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc[m]);
        for (n, &cnt) in lanes.iter().take(nr_real).enumerate() {
            *job.out.0.add((o_base + m) * job.d + d_base + n) =
                (2 * cnt as i64 - job.beta) as f32;
        }
    }
}

/// AVX-512 MR x 8 panel kernel: `VPOPCNTQ` counts all 8 u64 lanes of
/// the XNOR word in a single instruction, accumulated into one
/// 8-lane vector per output row.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn blocked_panel_avx512<const MR: usize>(
    job: &BlockedJob,
    ap: &[u64],
    bp: &[u64],
    o_base: usize,
    d_base: usize,
    mr_real: usize,
    nr_real: usize,
) {
    use std::arch::x86_64::*;
    let kw = job.kw;
    let kb = job.tile.kb.max(1);
    let ones = _mm512_set1_epi64(-1);
    let mut acc = [_mm512_setzero_si512(); MR];
    let mut k0 = 0usize;
    while k0 < kw {
        let k1 = (k0 + kb).min(kw);
        for k in k0..k1 {
            // unaligned 8-lane load of the packed B panel column
            let bv = std::ptr::read_unaligned(
                bp.as_ptr().add(k * 8) as *const __m512i
            );
            for (m, accm) in acc.iter_mut().enumerate() {
                let av = _mm512_set1_epi64(ap[k * MR + m] as i64);
                let y =
                    _mm512_xor_si512(_mm512_xor_si512(av, bv), ones);
                *accm =
                    _mm512_add_epi64(*accm, _mm512_popcnt_epi64(y));
            }
        }
        k0 = k1;
    }
    for m in 0..mr_real {
        let lanes: [u64; 8] = std::mem::transmute(acc[m]);
        for (n, &cnt) in lanes.iter().take(nr_real).enumerate() {
            *job.out.0.add((o_base + m) * job.d + d_base + n) =
                (2 * cnt as i64 - job.beta) as f32;
        }
    }
}

/// The scalar blocked sweep shared by the popcnt/neon/portable tier
/// wrappers: the B panel stays resident across the po sweep, one
/// [`blocked_panel_scalar`] call per MR x NR output tile.
#[inline(always)]
unsafe fn blocked_sweep_scalar_panels<const MR: usize>(
    job: &BlockedJob,
    pb: &Block,
) {
    let (kw, nr) = (job.kw, job.tile.nr);
    for pd in pb.d0..pb.d1 {
        let bp = &job.b[pd * kw * nr..(pd + 1) * kw * nr];
        let d_base = pd * nr;
        let nr_real = (job.d - d_base).min(nr);
        for po in pb.o0..pb.o1 {
            let ap = &job.a[po * kw * MR..(po + 1) * kw * MR];
            let o_base = po * MR;
            let mr_real = (job.o - o_base).min(MR);
            blocked_panel_scalar::<MR>(
                job, ap, bp, o_base, d_base, mr_real, nr_real,
            );
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn blocked_exact_popcnt<const MR: usize>(
    job: &BlockedJob,
    pb: &Block,
) {
    blocked_sweep_scalar_panels::<MR>(job, pb)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn blocked_exact_neon<const MR: usize>(
    job: &BlockedJob,
    pb: &Block,
) {
    blocked_sweep_scalar_panels::<MR>(job, pb)
}

unsafe fn blocked_exact_portable<const MR: usize>(
    job: &BlockedJob,
    pb: &Block,
) {
    blocked_sweep_scalar_panels::<MR>(job, pb)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,popcnt")]
unsafe fn blocked_exact_avx2<const MR: usize>(
    job: &BlockedJob,
    pb: &Block,
) {
    let kw = job.kw;
    for pd in pb.d0..pb.d1 {
        let bp = &job.b[pd * kw * 4..(pd + 1) * kw * 4];
        let d_base = pd * 4;
        let nr_real = (job.d - d_base).min(4);
        for po in pb.o0..pb.o1 {
            let ap = &job.a[po * kw * MR..(po + 1) * kw * MR];
            let o_base = po * MR;
            let mr_real = (job.o - o_base).min(MR);
            blocked_panel_avx2::<MR>(
                job, ap, bp, o_base, d_base, mr_real, nr_real,
            );
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vpopcntdq,avx2,popcnt")]
unsafe fn blocked_exact_avx512<const MR: usize>(
    job: &BlockedJob,
    pb: &Block,
) {
    let kw = job.kw;
    for pd in pb.d0..pb.d1 {
        let bp = &job.b[pd * kw * 8..(pd + 1) * kw * 8];
        let d_base = pd * 8;
        let nr_real = (job.d - d_base).min(8);
        for po in pb.o0..pb.o1 {
            let ap = &job.a[po * kw * MR..(po + 1) * kw * MR];
            let o_base = po * MR;
            let mr_real = (job.o - o_base).min(MR);
            blocked_panel_avx512::<MR>(
                job, ap, bp, o_base, d_base, mr_real, nr_real,
            );
        }
    }
}

/// Tier + tile dispatch for one panel-grid block.
///
/// # Safety
/// Concurrent callers must hand workers disjoint panel blocks,
/// `job.out` must stay valid for the whole fan-out, and SIMD kinds
/// must have passed runtime detection. The vector kernels run only
/// when NR matches their lane width; any other tile routes to the
/// scalar-panel sweep under the tier's popcount feature.
unsafe fn blocked_exact_block(
    kind: KernelKind,
    job: &BlockedJob,
    pb: &Block,
) {
    match kind {
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx512 => match job.tile.nr {
            8 => dispatch_mr!(blocked_exact_avx512, job.tile, job, pb),
            4 => dispatch_mr!(blocked_exact_avx2, job.tile, job, pb),
            _ => dispatch_mr!(blocked_exact_popcnt, job.tile, job, pb),
        },
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => match job.tile.nr {
            4 => dispatch_mr!(blocked_exact_avx2, job.tile, job, pb),
            _ => dispatch_mr!(blocked_exact_popcnt, job.tile, job, pb),
        },
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => {
            dispatch_mr!(blocked_exact_neon, job.tile, job, pb)
        }
        _ => dispatch_mr!(blocked_exact_portable, job.tile, job, pb),
    }
}

/// Fused MR x NR panel: per *real* lane pair, walk the K words once,
/// tallying the per-group level histogram inline (pad lanes and the
/// phantom high half of an odd trailing word never reach the
/// histogram — same convention as [`walk_groups`]).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn blocked_fused_panel<const MR: usize>(
    job: &BlockedJob,
    g: usize,
    hist: &mut [u64; N_LEVELS],
    ap: &[u64],
    bp: &[u64],
    o_base: usize,
    d_base: usize,
    mr_real: usize,
    nr_real: usize,
) {
    let (kw, nr) = (job.kw, job.tile.nr);
    for m in 0..mr_real {
        for n in 0..nr_real {
            let mut sum = 0u32;
            for k in 0..kw {
                let y = !(ap[k * MR + m] ^ bp[k * nr + n]);
                let lo = (y as u32).count_ones();
                sum += lo;
                hist[lo as usize] += 1;
                if 2 * k + 1 < g {
                    let hi = ((y >> 32) as u32).count_ones();
                    sum += hi;
                    hist[hi as usize] += 1;
                } else {
                    // phantom half: popcount 0 by construction
                    debug_assert_eq!((y >> 32).count_ones(), 0);
                }
            }
            *job.out.0.add((o_base + m) * job.d + d_base + n) =
                (2 * sum as i64 - job.beta) as f32;
        }
    }
}

/// The fused blocked sweep shared by the tier wrappers below.
#[inline(always)]
unsafe fn blocked_fused_sweep<const MR: usize>(
    job: &BlockedJob,
    g: usize,
    pb: &Block,
) -> [u64; N_LEVELS] {
    let (kw, nr) = (job.kw, job.tile.nr);
    let mut hist = [0u64; N_LEVELS];
    for pd in pb.d0..pb.d1 {
        let bp = &job.b[pd * kw * nr..(pd + 1) * kw * nr];
        let d_base = pd * nr;
        let nr_real = (job.d - d_base).min(nr);
        for po in pb.o0..pb.o1 {
            let ap = &job.a[po * kw * MR..(po + 1) * kw * MR];
            let o_base = po * MR;
            let mr_real = (job.o - o_base).min(MR);
            blocked_fused_panel::<MR>(
                job, g, &mut hist, ap, bp, o_base, d_base, mr_real,
                nr_real,
            );
        }
    }
    hist
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn blocked_fused_popcnt<const MR: usize>(
    job: &BlockedJob,
    g: usize,
    pb: &Block,
) -> [u64; N_LEVELS] {
    blocked_fused_sweep::<MR>(job, g, pb)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn blocked_fused_neon<const MR: usize>(
    job: &BlockedJob,
    g: usize,
    pb: &Block,
) -> [u64; N_LEVELS] {
    blocked_fused_sweep::<MR>(job, g, pb)
}

unsafe fn blocked_fused_portable<const MR: usize>(
    job: &BlockedJob,
    g: usize,
    pb: &Block,
) -> [u64; N_LEVELS] {
    blocked_fused_sweep::<MR>(job, g, pb)
}

/// Tier + tile dispatch for one fused panel-grid block. The fused
/// walk needs per-group (u32-half) granularity, so every tier runs
/// the scalar-word panel under its popcount feature.
///
/// # Safety
/// As [`blocked_exact_block`].
unsafe fn blocked_fused_block(
    kind: KernelKind,
    job: &BlockedJob,
    g: usize,
    pb: &Block,
) -> [u64; N_LEVELS] {
    match kind {
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 | KernelKind::Avx512 => {
            dispatch_mr!(blocked_fused_popcnt, job.tile, job, g, pb)
        }
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => {
            dispatch_mr!(blocked_fused_neon, job.tile, job, g, pb)
        }
        _ => dispatch_mr!(blocked_fused_portable, job.tile, job, g, pb),
    }
}

/// Register-blocked exact matmul (DESIGN.md §14): pack both operands
/// into lane-interleaved panels held in `scratch`, then fan MR x NR
/// register tiles over the panel grid. [`ResolvedTile::ScalarSafe`]
/// routes to the per-word [`matmul_exact_into`] (escape hatch +
/// baseline). Bit-identical to the word path and to
/// [`SubMacEngine::matmul_exact`] at every tier, tile and thread
/// count — the hot path is all-integer popcount math.
pub fn matmul_exact_tiled_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    tile: ResolvedTile,
    scratch: &mut PackScratch,
    out: &mut [f32],
) {
    count_dispatch(kind);
    let t = match tile {
        ResolvedTile::ScalarSafe => {
            return matmul_exact_into(pool, eng, x, kind, out)
        }
        ResolvedTile::Blocked(t) => t,
    };
    assert!(t.is_valid(), "unsupported tile {}", t.name());
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    if o == 0 || d == 0 {
        return;
    }
    pack_a_block(&eng.w, 0, o, t.mr, &mut scratch.a);
    pack_b_block(x, 0, d, t.nr, &mut scratch.b);
    let job = BlockedJob {
        a: &scratch.a,
        b: &scratch.b,
        kw: eng.w.words64_per_row,
        o,
        d,
        beta: eng.beta as i64,
        tile: t,
        out: OutPtr(out.as_mut_ptr()),
    };
    let blocks =
        work_blocks(o.div_ceil(t.mr), d.div_ceil(t.nr), pool.threads());
    pool.for_each(blocks.len(), |i| {
        // safety: panel blocks are disjoint (each output cell belongs
        // to exactly one panel), `out` outlives the scoped fan-out,
        // and SIMD kinds passed runtime detection
        unsafe { blocked_exact_block(kind, &job, &blocks[i]) }
    });
}

/// Allocating convenience wrapper over [`matmul_exact_tiled_into`].
pub fn matmul_exact_tiled(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    tile: ResolvedTile,
) -> Vec<f32> {
    let mut scratch = PackScratch::default();
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    matmul_exact_tiled_into(
        pool,
        eng,
        x,
        kind,
        tile,
        &mut scratch,
        &mut out,
    );
    out
}

/// Fused exact matmul + F_MAC histogram over the blocked path: one
/// walk over the packed panels produces outputs *and* per-group level
/// histograms (genuinely fused — the operands are read once).
/// Bit-identical to [`matmul_exact_fused_into`] and the separate word
/// paths at every tier, tile and thread count.
pub fn matmul_exact_fused_tiled_into(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    tile: ResolvedTile,
    scratch: &mut PackScratch,
    out: &mut [f32],
) -> [u64; N_LEVELS] {
    count_dispatch(kind);
    let t = match tile {
        ResolvedTile::ScalarSafe => {
            return matmul_exact_fused_into(pool, eng, x, kind, out)
        }
        ResolvedTile::Blocked(t) => t,
    };
    assert!(t.is_valid(), "unsupported tile {}", t.name());
    let (o, d) = (eng.w.rows, x.rows);
    assert_eq!(x.words_per_row, eng.n_groups());
    assert_eq!(out.len(), o * d);
    if o == 0 || d == 0 {
        return [0u64; N_LEVELS];
    }
    pack_a_block(&eng.w, 0, o, t.mr, &mut scratch.a);
    pack_b_block(x, 0, d, t.nr, &mut scratch.b);
    let g = eng.n_groups();
    let job = BlockedJob {
        a: &scratch.a,
        b: &scratch.b,
        kw: eng.w.words64_per_row,
        o,
        d,
        beta: eng.beta as i64,
        tile: t,
        out: OutPtr(out.as_mut_ptr()),
    };
    let blocks =
        work_blocks(o.div_ceil(t.mr), d.div_ceil(t.nr), pool.threads());
    merge_hists(pool.map(blocks.len(), |i| {
        // safety: as in `matmul_exact_tiled_into`
        unsafe { blocked_fused_block(kind, &job, g, &blocks[i]) }
    }))
}

/// Allocating convenience wrapper over
/// [`matmul_exact_fused_tiled_into`].
pub fn matmul_exact_fused_tiled(
    pool: &ScopedPool,
    eng: &SubMacEngine,
    x: &BitMatrix,
    kind: KernelKind,
    tile: ResolvedTile,
) -> (Vec<f32>, [u64; N_LEVELS]) {
    let mut scratch = PackScratch::default();
    let mut out = vec![0.0f32; eng.w.rows * x.rows];
    let hist = matmul_exact_fused_tiled_into(
        pool,
        eng,
        x,
        kind,
        tile,
        &mut scratch,
        &mut out,
    );
    (out, hist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_engine(
        rng: &mut Rng,
        o: usize,
        k: usize,
        d: usize,
    ) -> (SubMacEngine, BitMatrix) {
        let w: Vec<f32> = (0..o * k).map(|_| rng.pm1(0.5)).collect();
        let x: Vec<f32> = (0..d * k).map(|_| rng.pm1(0.5)).collect();
        (
            SubMacEngine::new(o, k, &w, k),
            BitMatrix::pack(d, k, &x, false),
        )
    }

    fn rand_em(rng: &mut Rng) -> ErrorModel {
        let mut full = vec![vec![0.0f64; N_LEVELS]; N_LEVELS];
        for (m, row) in full.iter_mut().enumerate() {
            let mut tot = 0.0;
            for dlt in -2i64..=2 {
                let j = (m as i64 + dlt).clamp(0, 32) as usize;
                let w = rng.f64() + 0.05;
                row[j] += w;
                tot += w;
            }
            row.iter_mut().for_each(|v| *v /= tot);
        }
        ErrorModel::from_full(&full)
    }

    /// Every tier the running CPU can execute, scalar first — on an
    /// AVX-512 machine this sweeps scalar, avx2 *and* avx512.
    fn tiers() -> Vec<KernelKind> {
        KernelKind::TIERS
            .iter()
            .rev()
            .copied()
            .filter(|t| t.supported())
            .collect()
    }

    #[test]
    fn exact_matches_scalar_engine_across_tiers() {
        let mut rng = Rng::new(31);
        // includes odd group counts (ragged u64 rows) and long rows
        // that exercise the AVX2 LUT path (k = 640 -> 10 u64 words)
        for (o, k, d) in
            [(5, 64, 300), (17, 96, 131), (1, 32, 1), (3, 640, 70)]
        {
            let (eng, xb) = rand_engine(&mut rng, o, k, d);
            let want = eng.matmul_exact(&xb);
            for kind in tiers() {
                let pool = ScopedPool::sequential();
                assert_eq!(
                    matmul_exact(&pool, &eng, &xb, kind),
                    want,
                    "{} o={o} k={k} d={d}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn threaded_exact_matches_scalar_at_every_pool_size() {
        let mut rng = Rng::new(32);
        let (eng, xb) = rand_engine(&mut rng, 13, 64, 257);
        let want = eng.matmul_exact(&xb);
        for kind in tiers() {
            for threads in [1usize, 2, 3, 8, 32] {
                let pool = ScopedPool::new(threads);
                assert_eq!(
                    matmul_exact(&pool, &eng, &xb, kind),
                    want,
                    "{} threads {threads}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn small_o_splits_d_and_stays_exact() {
        // o < workers: the d-split path must still be bit-identical
        let mut rng = Rng::new(35);
        let (eng, xb) = rand_engine(&mut rng, 2, 96, 533);
        let want = eng.matmul_exact(&xb);
        for threads in [8usize, 16] {
            let pool = ScopedPool::new(threads);
            for kind in tiers() {
                assert_eq!(
                    matmul_exact(&pool, &eng, &xb, kind),
                    want,
                    "{} threads {threads}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn threaded_error_matches_scalar_bitwise() {
        let mut rng = Rng::new(33);
        let (eng, xb) = rand_engine(&mut rng, 9, 96, 200);
        let em = rand_em(&mut rng);
        for (seed, salt) in [(0u32, 0u32), (7, 0x9E3779B1), (0xDEAD, 42)]
        {
            let want = eng.matmul_error(&xb, &em, seed, salt);
            for kind in tiers() {
                for threads in [1usize, 2, 5, 16] {
                    let pool = ScopedPool::new(threads);
                    assert_eq!(
                        matmul_error(
                            &pool, &eng, &xb, &em, seed, salt, kind
                        ),
                        want,
                        "{} seed {seed} salt {salt} threads {threads}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn histogram_matches_engine() {
        let mut rng = Rng::new(34);
        let (eng, xb) = rand_engine(&mut rng, 6, 96, 77);
        let want = eng.histogram(&xb);
        for kind in tiers() {
            for threads in [1usize, 3, 9] {
                let pool = ScopedPool::new(threads);
                assert_eq!(
                    histogram(&pool, &eng, &xb, kind),
                    want,
                    "{} threads {threads}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn fused_matches_separate_paths() {
        let mut rng = Rng::new(36);
        for (o, k, d) in [(6, 96, 77), (2, 160, 210), (11, 32, 40)] {
            let (eng, xb) = rand_engine(&mut rng, o, k, d);
            let want_out = eng.matmul_exact(&xb);
            let want_hist = eng.histogram(&xb);
            for kind in tiers() {
                for threads in [1usize, 2, 7] {
                    let pool = ScopedPool::new(threads);
                    let (out, hist) =
                        matmul_exact_fused(&pool, &eng, &xb, kind);
                    assert_eq!(
                        out,
                        want_out,
                        "{} o={o} threads {threads}",
                        kind.name()
                    );
                    assert_eq!(
                        hist,
                        want_hist,
                        "{} o={o} threads {threads}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn work_blocks_cover_grid_without_empties() {
        for (o, d, w) in [
            (10, 50, 3),
            (3, 1000, 8),
            (1, 1, 1),
            (64, 64, 64),
            (2, 7, 16),
            (1, 3, 64),
            (5, 4, 0),
        ] {
            let blocks = work_blocks(o, d, w);
            let mut covered = 0usize;
            for b in &blocks {
                assert!(!b.is_empty(), "empty block in {o}x{d}/{w}");
                covered += b.len();
            }
            assert_eq!(covered, o * d, "coverage {o}x{d}/{w}");
            // memory order: each block starts where the previous ended
            let mut at = 0usize;
            for b in &blocks {
                assert_eq!(b.o0 * d + b.d0, at, "order {o}x{d}/{w}");
                at += b.len();
            }
            // o < workers engages the d-split so no worker idles
            if o < w && d >= w.div_ceil(o) {
                assert!(
                    blocks.len() >= w.min(o * d),
                    "{o}x{d}/{w}: only {} blocks",
                    blocks.len()
                );
            }
        }
    }

    #[test]
    fn kernel_kind_resolves() {
        assert_eq!(
            KernelKind::resolve("scalar").unwrap(),
            KernelKind::Scalar
        );
        let auto = KernelKind::resolve("auto").unwrap();
        assert_eq!(auto, KernelKind::detect());
        // the unknown-name error enumerates every tier, avx512
        // included
        let e = KernelKind::resolve("tpu").unwrap_err().to_string();
        for choice in KernelKind::CHOICES {
            assert!(e.contains(choice), "{e} missing {choice}");
        }
        // explicit SIMD names resolve exactly when supported — on an
        // AVX-512 machine `avx2` still resolves (clean fallback)
        for simd in ["avx2", "avx512", "neon"] {
            match KernelKind::resolve(simd) {
                Ok(k) => {
                    assert_eq!(k.name(), simd);
                    assert!(k.supported());
                }
                Err(e) => {
                    assert!(e.to_string().contains(simd), "{e}")
                }
            }
        }
    }

    #[test]
    fn detect_falls_back_in_tier_order() {
        // detect() is the first supported entry of TIERS: everything
        // ranked above the detected tier must be unsupported, and
        // scalar is always the last resort
        let det = KernelKind::detect();
        assert!(det.supported());
        for &t in KernelKind::TIERS {
            if t == det {
                break;
            }
            assert!(
                !t.supported(),
                "{} outranks detected {}",
                t.name(),
                det.name()
            );
        }
        assert!(KernelKind::Scalar.supported());
        assert_eq!(
            *KernelKind::TIERS.last().unwrap(),
            KernelKind::Scalar
        );
    }

    #[test]
    fn tile_spec_parses() {
        assert_eq!(TileSpec::parse("auto").unwrap(), TileSpec::Auto);
        assert_eq!(
            TileSpec::parse("scalar-safe").unwrap(),
            TileSpec::ScalarSafe
        );
        assert_eq!(
            TileSpec::parse("4x8").unwrap(),
            TileSpec::Fixed(Tile::new(4, 8, Tile::DEFAULT_KB))
        );
        assert_eq!(
            TileSpec::parse("2x4k16").unwrap(),
            TileSpec::Fixed(Tile::new(2, 4, 16))
        );
        for bad in
            ["", "3x4", "4x3", "4x8k0", "mrxnr", "4x", "x8", "4x8x2"]
        {
            let e = TileSpec::parse(bad);
            assert!(e.is_err(), "`{bad}` should not parse");
            let msg = e.unwrap_err().to_string();
            assert!(msg.contains("scalar-safe"), "{msg}");
        }
    }

    #[test]
    fn tile_candidates_and_defaults_are_valid() {
        for &kind in KernelKind::TIERS {
            let def = Tile::default_for(kind);
            assert!(def.is_valid());
            let cands = Tile::candidates(kind);
            assert!(
                cands.contains(&def),
                "{}: default {} not a candidate",
                kind.name(),
                def.name()
            );
            for t in cands {
                assert!(t.is_valid(), "{}", t.name());
            }
        }
    }

    #[test]
    fn blocked_tiled_matches_word_and_engine() {
        let mut rng = Rng::new(41);
        // ragged everything: o < MR, d < NR, d not a multiple of 64,
        // odd group counts
        for (o, k, d) in [
            (5, 64, 300),
            (3, 96, 7),
            (1, 32, 1),
            (2, 160, 65),
            (17, 224, 131),
        ] {
            let (eng, xb) = rand_engine(&mut rng, o, k, d);
            let want = eng.matmul_exact(&xb);
            for kind in tiers() {
                for tile in Tile::candidates(kind) {
                    for threads in [1usize, 3, 16] {
                        let pool = ScopedPool::new(threads);
                        let ctx = format!(
                            "{} {} o={o} k={k} d={d} threads={threads}",
                            kind.name(),
                            tile.name()
                        );
                        let got = matmul_exact_tiled(
                            &pool,
                            &eng,
                            &xb,
                            kind,
                            ResolvedTile::Blocked(tile),
                        );
                        assert_eq!(got, want, "blocked {ctx}");
                        let word =
                            matmul_exact(&pool, &eng, &xb, kind);
                        assert_eq!(word, want, "word {ctx}");
                    }
                }
            }
        }
    }

    #[test]
    fn fused_tiled_matches_separate_paths() {
        let mut rng = Rng::new(42);
        for (o, k, d) in [(6, 96, 77), (2, 160, 210), (3, 32, 5)] {
            let (eng, xb) = rand_engine(&mut rng, o, k, d);
            let want_out = eng.matmul_exact(&xb);
            let want_hist = eng.histogram(&xb);
            for kind in tiers() {
                for tile in
                    [Tile::default_for(kind), Tile::new(8, 8, 16)]
                {
                    for threads in [1usize, 2, 7] {
                        let pool = ScopedPool::new(threads);
                        let (out, hist) = matmul_exact_fused_tiled(
                            &pool,
                            &eng,
                            &xb,
                            kind,
                            ResolvedTile::Blocked(tile),
                        );
                        let ctx = format!(
                            "{} {} o={o} threads={threads}",
                            kind.name(),
                            tile.name()
                        );
                        assert_eq!(out, want_out, "out {ctx}");
                        assert_eq!(hist, want_hist, "hist {ctx}");
                    }
                }
            }
        }
    }

    #[test]
    fn scalar_safe_tile_routes_to_word_path() {
        let mut rng = Rng::new(43);
        let (eng, xb) = rand_engine(&mut rng, 4, 96, 33);
        let want = eng.matmul_exact(&xb);
        let want_hist = eng.histogram(&xb);
        let pool = ScopedPool::sequential();
        for kind in tiers() {
            assert_eq!(
                matmul_exact_tiled(
                    &pool,
                    &eng,
                    &xb,
                    kind,
                    ResolvedTile::ScalarSafe
                ),
                want,
                "{}",
                kind.name()
            );
            let (out, hist) = matmul_exact_fused_tiled(
                &pool,
                &eng,
                &xb,
                kind,
                ResolvedTile::ScalarSafe,
            );
            assert_eq!(out, want, "{}", kind.name());
            assert_eq!(hist, want_hist, "{}", kind.name());
        }
    }

    /// Auto-skips on runners without the VPOPCNTQ extension (the CI
    /// `cargo test avx512` step runs it everywhere; it only bites on
    /// AVX-512 hardware).
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_blocked_matches_engine_when_detected() {
        if !std::arch::is_x86_feature_detected!("avx512vpopcntdq")
            || !KernelKind::Avx512.supported()
        {
            eprintln!(
                "skipping: avx512vpopcntdq not available on this CPU"
            );
            return;
        }
        let mut rng = Rng::new(47);
        let (eng, xb) = rand_engine(&mut rng, 9, 288, 130);
        let want = eng.matmul_exact(&xb);
        let pool = ScopedPool::new(4);
        for tile in Tile::candidates(KernelKind::Avx512) {
            assert_eq!(
                matmul_exact_tiled(
                    &pool,
                    &eng,
                    &xb,
                    KernelKind::Avx512,
                    ResolvedTile::Blocked(tile),
                ),
                want,
                "tile {}",
                tile.name()
            );
        }
    }
}
