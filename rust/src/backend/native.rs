//! `NativeBackend` — the complete hardware-mode BNN forward pass on
//! host, no XLA anywhere: bit-pack -> grouped sub-MAC -> counter-PRNG
//! error-model decode -> folded batchnorm affine -> sign -> argmax.
//!
//! This is the Rust twin of `python/compile/nn.py::forward_eval` with
//! `engine='jnp'|'pallas'`: same im2col patch layout, same dummy-cell
//! biasing of partial tail groups (`centered_pad`), same per-matmul
//! PRNG salt stride, same batching and per-batch seed schedule — so
//! given the same folded tensors, error models and seed the logits are
//! bit-identical to the AOT eval artifacts (pinned by
//! `tests/backend.rs` when artifacts are present). The matmuls run on
//! the width-dispatched popcount microkernels of [`super::kernels`]
//! (tier per [`KernelKind`], fanned over the shared [`ScopedPool`]),
//! and every per-batch scratch buffer — im2col rows, packed
//! activations, matmul outputs, activation tensors — comes from the
//! plan's reusable [`Arena`], so the steady state of an accuracy or
//! F_MAC sweep allocates nothing.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure, Result};

use super::arch::{self, ArchOp, FoldedSig, ModelMeta};
use super::kernels::{self, KernelKind, ResolvedTile, Tile};
use super::{fold_hash, FmacResult, InferenceBackend};
use crate::bnn::engine::centered_pad;
use crate::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use crate::capmin::Fmac;
use crate::coordinator::store::NamedTensor;
use crate::data::synth::DatasetSpec;
use crate::data::{Loader, Split};
use crate::util::pool::ScopedPool;
use crate::util::stats::argmax;

/// Per-matmul PRNG stream decorrelation (`nn.py::_SALT_STRIDE`).
const SALT_STRIDE: u32 = 0x9E37_79B1;

/// Reusable scratch buffers for the forward pass (DESIGN.md §11).
///
/// Plain freelists of f32/u64 vectors: `take` pops (or allocates) and
/// resizes with a fill value, `put` returns capacity for the next
/// layer or batch. Lifetime rule: a buffer is either *inside* exactly
/// one live tensor/matrix or *in* the arena — every `take` in the
/// exec path has a matching `put` when its tensor is consumed, except
/// the final logits buffer, which escapes to the caller (the arena
/// simply re-grows by one buffer on the next pass).
#[derive(Default)]
pub struct Arena {
    f32s: Vec<Vec<f32>>,
    u64s: Vec<Vec<u64>>,
}

impl Arena {
    /// A recycled buffer of `len` entries, every entry set to `fill`.
    /// Call sites that fully overwrite the buffer (matmul outputs,
    /// transposes) still pay this one memset — a small, safe constant
    /// next to the O(words) kernel work per element.
    fn take_f32(&mut self, len: usize, fill: f32) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        v.clear();
        v.resize(len, fill);
        v
    }

    /// A recycled buffer initialized as a copy of `src` (no
    /// intermediate fill pass).
    fn take_f32_from(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        v.clear();
        v.extend_from_slice(src);
        v
    }

    fn put_f32(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.f32s.push(v);
        }
    }

    fn take_u64(&mut self) -> Vec<u64> {
        self.u64s.pop().unwrap_or_default()
    }

    fn put_u64(&mut self, v: Vec<u64>) {
        if v.capacity() > 0 {
            self.u64s.push(v);
        }
    }

    /// Buffers currently parked in the arena (tests pin reuse).
    pub fn parked(&self) -> usize {
        self.f32s.len() + self.u64s.len()
    }
}

/// A folded model prepared for native execution: weights bit-packed
/// once (stationary), affines and biases unpacked, shapes validated
/// against the registry's folded signature, plus the reusable scratch
/// arena shared by every pass over this plan.
pub struct NativePlan {
    pub meta: ModelMeta,
    /// One packed engine per matmul, in consumption order; `beta` is
    /// the dummy-biased effective reduction length.
    engines: Vec<SubMacEngine>,
    /// Conducting dummy rows per matmul (`centered_pad` p_on).
    pads: Vec<usize>,
    /// Folded BN affines (scale, bias) in consumption order.
    affines: Vec<(Vec<f32>, Vec<f32>)>,
    /// Final f32 logit bias.
    out_bias: Vec<f32>,
    /// Freelist of scratch arenas, shared across layers, batches and
    /// requests. Sequential passes recycle one arena; the serve
    /// batcher's per-request fan ([`NativeBackend::forward_many`])
    /// checks out one arena per concurrent request and parks them all
    /// back here, so the steady state of a serving process allocates
    /// nothing between micro-batches.
    arenas: Mutex<Vec<Arena>>,
}

impl NativePlan {
    pub fn build(model: &str, folded: &[NamedTensor]) -> Result<NativePlan> {
        let meta = arch::model_meta(model)?;
        let sig = meta.folded_signature();
        let want: usize = sig
            .iter()
            .map(|s| match s {
                FoldedSig::Affine { .. } => 2,
                _ => 1,
            })
            .sum();
        ensure!(
            folded.len() == want,
            "{model}: expected {want} folded tensors, got {}",
            folded.len()
        );
        let mut engines = vec![];
        let mut pads = vec![];
        let mut affines = vec![];
        let mut out_bias = vec![];
        let mut it = folded.iter();
        for s in &sig {
            match s {
                FoldedSig::Weight { name, o, k, kp } => {
                    let t = it.next().expect("arity checked");
                    ensure!(
                        t.shape == vec![*o, *kp],
                        "{model}/{name}: weight shape {:?}, want [{o}, \
                         {kp}]",
                        t.shape
                    );
                    let (p_on, beta_eff) = centered_pad(*k);
                    engines.push(SubMacEngine::new(
                        *o, *kp, &t.data, beta_eff,
                    ));
                    pads.push(p_on);
                }
                FoldedSig::Affine { scale, ch, .. } => {
                    let ts = it.next().expect("arity checked");
                    let tb = it.next().expect("arity checked");
                    ensure!(
                        ts.data.len() == *ch && tb.data.len() == *ch,
                        "{model}/{scale}: affine length {}/{}, want {ch}",
                        ts.data.len(),
                        tb.data.len()
                    );
                    affines.push((ts.data.clone(), tb.data.clone()));
                }
                FoldedSig::OutBias { n, .. } => {
                    let t = it.next().expect("arity checked");
                    ensure!(
                        t.data.len() == *n,
                        "{model}/out.b: length {}, want {n}",
                        t.data.len()
                    );
                    out_bias = t.data.clone();
                }
            }
        }
        Ok(NativePlan {
            meta,
            engines,
            pads,
            affines,
            out_bias,
            arenas: Mutex::new(vec![]),
        })
    }

    pub fn n_matmuls(&self) -> usize {
        self.engines.len()
    }

    /// Check a scratch arena out of the plan's freelist (allocating an
    /// empty one only when every arena is in use by a concurrent
    /// request).
    fn take_arena(&self) -> Arena {
        // observation only (DESIGN.md §17): the freelist tests pin
        // parked-buffer counts, which these counters never affect
        match self.arenas.lock().unwrap().pop() {
            Some(a) => {
                crate::obs::registry::inc("backend.arena.reuse");
                a
            }
            None => {
                crate::obs::registry::inc("backend.arena.alloc");
                Arena::default()
            }
        }
    }

    /// Park an arena back for the next pass or request.
    fn put_arena(&self, a: Arena) {
        self.arenas.lock().unwrap().push(a);
    }

    /// Buffers currently parked across all of the plan's arenas
    /// (tests pin steady-state reuse).
    pub fn parked(&self) -> usize {
        self.arenas.lock().unwrap().iter().map(Arena::parked).sum()
    }
}

/// NCHW activation block.
struct Act {
    data: Vec<f32>,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
}

/// Flattened [b, cols] activation block.
struct Flat {
    data: Vec<f32>,
    b: usize,
    cols: usize,
}

enum Tensor {
    Nchw(Act),
    Flat(Flat),
}

enum Mode<'a> {
    /// Ideal circuit (plain +-1 matmul) — the hist artifact's engine.
    Exact,
    /// Grouped sub-MAC through per-matmul error models.
    Error { ems: &'a [ErrorModel], seed: u32 },
}

/// One forward execution: walks the arch ops consuming engines and
/// affines in order, exactly like `forward_eval` walks the folded list.
struct Exec<'p, 'm> {
    plan: &'p NativePlan,
    pool: &'p ScopedPool,
    kind: KernelKind,
    /// Register-blocking tile for the exact matmuls (DESIGN.md §14);
    /// `ScalarSafe` routes back to the per-word kernels.
    tile: ResolvedTile,
    /// When false, the clean-histogram pass runs matmul and histogram
    /// as two separate walks (the pre-fusion data flow, kept for the
    /// before/after bench and as a cross-check).
    fused: bool,
    mode: Mode<'m>,
    /// F_MAC accumulation (over the dummy-biased packed operands, like
    /// the hist artifact).
    hist: Option<&'m mut Vec<Fmac>>,
    scratch: &'m mut Arena,
    eng_i: usize,
    aff_i: usize,
}

impl Exec<'_, '_> {
    /// One sub-MAC matmul: pack `x_rows` (arena-recycled storage),
    /// collect F_MAC if requested (fused with the exact matmul on the
    /// clean pass), and return the [o x d] output — an arena buffer.
    fn matmul(&mut self, x_rows: &[f32], d: usize) -> Vec<f32> {
        let i = self.eng_i;
        self.eng_i += 1;
        let eng = &self.plan.engines[i];
        debug_assert_eq!(x_rows.len(), d * eng.w.cols);
        let xb = BitMatrix::pack_with(
            self.scratch.take_u64(),
            d,
            eng.w.cols,
            x_rows,
            false,
        );
        let mut out = self.scratch.take_f32(eng.w.rows * d, 0.0);
        match self.mode {
            Mode::Exact => {
                // the exact matmuls run register-blocked over packed
                // panels; the panel buffers are arena-recycled like
                // every other per-batch scratch
                let mut ps = kernels::PackScratch {
                    a: self.scratch.take_u64(),
                    b: self.scratch.take_u64(),
                };
                match self.hist.as_deref_mut() {
                    Some(hists) if self.fused => {
                        let part = kernels::matmul_exact_fused_tiled_into(
                            self.pool, eng, &xb, self.kind, self.tile,
                            &mut ps, &mut out,
                        );
                        for (a, b) in
                            hists[i].counts.iter_mut().zip(part.iter())
                        {
                            *a += b;
                        }
                    }
                    Some(hists) => {
                        let part = kernels::histogram(
                            self.pool, eng, &xb, self.kind,
                        );
                        for (a, b) in
                            hists[i].counts.iter_mut().zip(part.iter())
                        {
                            *a += b;
                        }
                        kernels::matmul_exact_tiled_into(
                            self.pool, eng, &xb, self.kind, self.tile,
                            &mut ps, &mut out,
                        );
                    }
                    None => kernels::matmul_exact_tiled_into(
                        self.pool, eng, &xb, self.kind, self.tile,
                        &mut ps, &mut out,
                    ),
                }
                self.scratch.put_u64(ps.a);
                self.scratch.put_u64(ps.b);
            }
            Mode::Error { ems, seed } => {
                if let Some(hists) = self.hist.as_deref_mut() {
                    let part =
                        kernels::histogram(self.pool, eng, &xb, self.kind);
                    for (a, b) in
                        hists[i].counts.iter_mut().zip(part.iter())
                    {
                        *a += b;
                    }
                }
                kernels::matmul_error_into(
                    self.pool,
                    eng,
                    &xb,
                    &ems[i],
                    seed,
                    (i as u32).wrapping_mul(SALT_STRIDE),
                    self.kind,
                    &mut out,
                );
            }
        }
        self.scratch.put_u64(xb.into_data());
        out
    }

    /// im2col rows for the upcoming matmul: SAME padding with -1 (the
    /// binary "off"), feature order (channel, kr, kc) matching the OIHW
    /// weight reshape, then `p_on` conducting dummy columns and
    /// non-conducting -1 columns up to the group-padded width.
    fn conv(&mut self, a: &Act, ksize: usize, stride: usize) -> Act {
        let eng = &self.plan.engines[self.eng_i];
        let p_on = self.plan.pads[self.eng_i];
        let kp = eng.w.cols;
        let k_true = a.c * ksize * ksize;
        let (b, c, h, w) = (a.b, a.c, a.h, a.w);
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let ph = ((oh - 1) * stride + ksize).saturating_sub(h);
        let pw = ((ow - 1) * stride + ksize).saturating_sub(w);
        let (pad_top, pad_left) = (ph / 2, pw / 2);
        let d = b * oh * ow;
        let mut rows = self.scratch.take_f32(d * kp, -1.0);
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    let base = ((bi * oh + oy) * ow + ox) * kp;
                    let row = &mut rows[base..base + kp];
                    for ci in 0..c {
                        let plane = &a.data
                            [(bi * c + ci) * h * w..(bi * c + ci + 1) * h * w];
                        for kr in 0..ksize {
                            let ry =
                                oy * stride + kr;
                            if ry < pad_top || ry >= pad_top + h {
                                continue; // stays -1 (pad)
                            }
                            let y = ry - pad_top;
                            for kc in 0..ksize {
                                let rx = ox * stride + kc;
                                if rx < pad_left || rx >= pad_left + w {
                                    continue;
                                }
                                let x = rx - pad_left;
                                row[ci * ksize * ksize + kr * ksize + kc] =
                                    plane[y * w + x];
                            }
                        }
                    }
                    for v in row[k_true..k_true + p_on].iter_mut() {
                        *v = 1.0; // conducting dummy cells
                    }
                }
            }
        }
        let o = eng.w.rows;
        let out = self.matmul(&rows, d);
        self.scratch.put_f32(rows);
        // [O, D] o-major -> NCHW
        let mut y = self.scratch.take_f32(b * o * oh * ow, 0.0);
        for oi in 0..o {
            for bi in 0..b {
                let src = &out
                    [oi * d + bi * oh * ow..oi * d + (bi + 1) * oh * ow];
                let dst_base = (bi * o + oi) * oh * ow;
                y[dst_base..dst_base + oh * ow].copy_from_slice(src);
            }
        }
        self.scratch.put_f32(out);
        Act {
            data: y,
            b,
            c: o,
            h: oh,
            w: ow,
        }
    }

    fn fc(&mut self, f: &Flat) -> Flat {
        let eng = &self.plan.engines[self.eng_i];
        let p_on = self.plan.pads[self.eng_i];
        let kp = eng.w.cols;
        let k_true = f.cols;
        let (b, o) = (f.b, eng.w.rows);
        let mut rows = self.scratch.take_f32(b * kp, -1.0);
        for bi in 0..b {
            let row = &mut rows[bi * kp..(bi + 1) * kp];
            row[..k_true]
                .copy_from_slice(&f.data[bi * k_true..(bi + 1) * k_true]);
            for v in row[k_true..k_true + p_on].iter_mut() {
                *v = 1.0;
            }
        }
        let out = self.matmul(&rows, b); // [O, B] o-major
        self.scratch.put_f32(rows);
        let mut y = self.scratch.take_f32(b * o, 0.0);
        for oi in 0..o {
            for bi in 0..b {
                y[bi * o + oi] = out[oi * b + bi];
            }
        }
        self.scratch.put_f32(out);
        Flat {
            data: y,
            b,
            cols: o,
        }
    }

    fn affine_nchw(&mut self, a: &mut Act) {
        let (scale, bias) = &self.plan.affines[self.aff_i];
        self.aff_i += 1;
        debug_assert_eq!(scale.len(), a.c);
        for bi in 0..a.b {
            for ci in 0..a.c {
                let (s, t) = (scale[ci], bias[ci]);
                let base = (bi * a.c + ci) * a.h * a.w;
                for v in a.data[base..base + a.h * a.w].iter_mut() {
                    *v = *v * s + t;
                }
            }
        }
    }

    fn affine_flat(&mut self, f: &mut Flat) {
        let (scale, bias) = &self.plan.affines[self.aff_i];
        self.aff_i += 1;
        debug_assert_eq!(scale.len(), f.cols);
        for bi in 0..f.b {
            let row = &mut f.data[bi * f.cols..(bi + 1) * f.cols];
            for (v, (s, t)) in
                row.iter_mut().zip(scale.iter().zip(bias.iter()))
            {
                *v = *v * s + t;
            }
        }
    }

    fn maxpool(&mut self, a: &Act, k: usize) -> Act {
        let (oh, ow) = (a.h / k, a.w / k);
        let mut out = self
            .scratch
            .take_f32(a.b * a.c * oh * ow, f32::NEG_INFINITY);
        for bi in 0..a.b {
            for ci in 0..a.c {
                let plane =
                    &a.data[(bi * a.c + ci) * a.h * a.w..][..a.h * a.w];
                let dst =
                    &mut out[(bi * a.c + ci) * oh * ow..][..oh * ow];
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut m = f32::NEG_INFINITY;
                        for dy in 0..k {
                            for dx in 0..k {
                                m = m
                                    .max(plane[(oy * k + dy) * a.w
                                        + ox * k
                                        + dx]);
                            }
                        }
                        dst[oy * ow + ox] = m;
                    }
                }
            }
        }
        Act {
            data: out,
            b: a.b,
            c: a.c,
            h: oh,
            w: ow,
        }
    }

    fn run(&mut self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        let [c, h, w] = self.plan.meta.in_shape;
        ensure!(
            x.len() == b * c * h * w,
            "input length {} != batch {b} x {:?}",
            x.len(),
            self.plan.meta.in_shape
        );
        let input = self.scratch.take_f32_from(x);
        let mut t = Tensor::Nchw(Act {
            data: input,
            b,
            c,
            h,
            w,
        });
        let spec = self.plan.meta.spec.clone();
        for op in &spec {
            t = match (op, t) {
                (ArchOp::Conv(_, s, k), Tensor::Nchw(a)) => {
                    let y = self.conv(&a, *k, *s);
                    self.scratch.put_f32(a.data);
                    Tensor::Nchw(y)
                }
                (ArchOp::MaxPool(k), Tensor::Nchw(a)) => {
                    let y = self.maxpool(&a, *k);
                    self.scratch.put_f32(a.data);
                    Tensor::Nchw(y)
                }
                (ArchOp::Bn, Tensor::Nchw(mut a)) => {
                    self.affine_nchw(&mut a);
                    Tensor::Nchw(a)
                }
                (ArchOp::Bn, Tensor::Flat(mut f)) => {
                    self.affine_flat(&mut f);
                    Tensor::Flat(f)
                }
                (ArchOp::Sign, Tensor::Nchw(mut a)) => {
                    hard_sign(&mut a.data);
                    Tensor::Nchw(a)
                }
                (ArchOp::Sign, Tensor::Flat(mut f)) => {
                    hard_sign(&mut f.data);
                    Tensor::Flat(f)
                }
                (ArchOp::Scb(_, s), Tensor::Nchw(a)) => {
                    // y = sign(affine(conv3(h, s)))
                    let mut y = self.conv(&a, 3, *s);
                    self.affine_nchw(&mut y);
                    hard_sign(&mut y.data);
                    // z = affine(conv3(y, 1))
                    let mut z = self.conv(&y, 3, 1);
                    self.scratch.put_f32(y.data);
                    self.affine_nchw(&mut z);
                    // sc = affine(conv1(h, s))
                    let mut sc = self.conv(&a, 1, *s);
                    self.scratch.put_f32(a.data);
                    self.affine_nchw(&mut sc);
                    // h = sign(z + sc)
                    for (zv, sv) in z.data.iter_mut().zip(sc.data.iter())
                    {
                        *zv += sv;
                    }
                    self.scratch.put_f32(sc.data);
                    hard_sign(&mut z.data);
                    Tensor::Nchw(z)
                }
                (ArchOp::Flatten, Tensor::Nchw(a)) => Tensor::Flat(Flat {
                    cols: a.c * a.h * a.w,
                    b: a.b,
                    data: a.data,
                }),
                (ArchOp::Fc(_), Tensor::Flat(f)) => {
                    let y = self.fc(&f);
                    self.scratch.put_f32(f.data);
                    Tensor::Flat(y)
                }
                (ArchOp::Out(_), Tensor::Flat(f)) => {
                    let mut y = self.fc(&f);
                    self.scratch.put_f32(f.data);
                    for bi in 0..y.b {
                        let row =
                            &mut y.data[bi * y.cols..(bi + 1) * y.cols];
                        for (v, ob) in
                            row.iter_mut().zip(self.plan.out_bias.iter())
                        {
                            *v += ob;
                        }
                    }
                    Tensor::Flat(y)
                }
                (op, _) => {
                    return Err(anyhow!(
                        "op {op:?} applied to a mismatched tensor form"
                    ))
                }
            };
        }
        match t {
            Tensor::Flat(f) => {
                ensure!(f.cols == self.plan.meta.n_classes);
                Ok(f.data)
            }
            Tensor::Nchw(_) => {
                Err(anyhow!("forward ended on an unflattened tensor"))
            }
        }
    }
}

fn hard_sign(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = if *v >= 0.0 { 1.0 } else { -1.0 };
    }
}

/// The XLA-free inference backend.
pub struct NativeBackend {
    pool: ScopedPool,
    /// Resolved microkernel tier (`--kernel`, DESIGN.md §11).
    kind: KernelKind,
    /// Resolved register-blocking tile (`--tile`, DESIGN.md §14).
    tile: ResolvedTile,
    /// Fuse the clean-pass F_MAC histogram into the matmul walk
    /// (disabled only by the before/after bench).
    fused: bool,
    /// Packed plans keyed by (model, folded-content hash): weights are
    /// stationary, so a sweep of error models packs each model once.
    plans: Mutex<HashMap<(String, u64), Arc<NativePlan>>>,
}

impl NativeBackend {
    /// `threads = 0` uses all available parallelism; the kernel tier
    /// is auto-detected.
    pub fn new(threads: usize) -> NativeBackend {
        NativeBackend::with_options(threads, KernelKind::detect(), true)
    }

    /// Full control over the execution knobs (session plumbing and the
    /// kernels bench).
    pub fn with_options(
        threads: usize,
        kind: KernelKind,
        fused: bool,
    ) -> NativeBackend {
        NativeBackend::with_pool(ScopedPool::new(threads), kind, fused)
    }

    /// Run on a caller-supplied pool — a server passes
    /// [`ScopedPool::persistent`] so kernel workers are spawned once
    /// at startup and reused by every request (DESIGN.md §12).
    pub fn with_pool(
        pool: ScopedPool,
        kind: KernelKind,
        fused: bool,
    ) -> NativeBackend {
        NativeBackend {
            pool,
            kind,
            tile: ResolvedTile::Blocked(Tile::default_for(kind)),
            fused,
            plans: Mutex::new(HashMap::new()),
        }
    }

    /// Override the register-blocking tile (`--tile`): the session
    /// passes the autotuned or explicitly requested choice here;
    /// `ScalarSafe` is the escape hatch back to the per-word kernels.
    pub fn with_tile(mut self, tile: ResolvedTile) -> NativeBackend {
        self.tile = tile;
        self
    }

    /// The backend's worker pool (shared with its kernels).
    pub fn pool(&self) -> &ScopedPool {
        &self.pool
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn kernel(&self) -> KernelKind {
        self.kind
    }

    /// The resolved register-blocking tile (recorded in point meta).
    pub fn tile(&self) -> ResolvedTile {
        self.tile
    }

    fn plan(
        &self,
        model: &str,
        folded: &[NamedTensor],
    ) -> Result<Arc<NativePlan>> {
        let key = (model.to_string(), fold_hash(folded));
        if let Some(p) = self.plans.lock().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let plan = Arc::new(NativePlan::build(model, folded)?);
        self.plans.lock().unwrap().insert(key, plan.clone());
        Ok(plan)
    }

    /// One forward pass exactly as [`InferenceBackend::logits`] would
    /// run it, on the given kernel pool, with the scratch arena checked
    /// out of (and parked back into) the plan's freelist.
    fn forward_one(
        &self,
        r: &ForwardReq,
        pool: &ScopedPool,
    ) -> Result<Vec<f32>> {
        // re-home this request under its own trace id (a batched
        // request runs on a pool worker that inherited the *batcher's*
        // context); the span still nests under the submitter's span
        let _ctx = if r.trace != 0 {
            Some(
                crate::obs::TraceCtx {
                    trace_id: r.trace,
                    span: crate::obs::current_ctx().span,
                }
                .attach(),
            )
        } else {
            None
        };
        let _span = crate::span!("backend.forward");
        let plan = self.plan(r.model, r.folded)?;
        ensure!(
            r.ems.len() == plan.n_matmuls(),
            "{}: need {} error models, got {}",
            r.model,
            plan.n_matmuls(),
            r.ems.len()
        );
        let mut scratch = plan.take_arena();
        let out = Exec {
            plan: &plan,
            pool,
            kind: self.kind,
            tile: self.tile,
            fused: self.fused,
            mode: Mode::Error {
                ems: r.ems,
                seed: r.seed,
            },
            hist: None,
            scratch: &mut scratch,
            eng_i: 0,
            aff_i: 0,
        }
        .run(r.x, r.batch);
        plan.put_arena(scratch);
        out
    }

    /// Execute a micro-batch of independent forward requests in one
    /// backend entry (the serve batcher's hot path, DESIGN.md §12).
    ///
    /// Every request runs exactly as it would alone — its own batch,
    /// seed and error models through the same `Exec` walk — so a reply
    /// is bit-identical whether or not the request was coalesced with
    /// others. What batching buys is *where* the work runs: a lone
    /// request gets the whole pool for its kernels (intra-op), while
    /// two or more requests fan out across the pool workers
    /// (one sequential forward each, every stage parallel — not just
    /// the matmuls), which is what scales server throughput. Plans
    /// are resolved once up front and scratch arenas are recycled
    /// across requests via each plan's freelist.
    pub fn forward_many(
        &self,
        reqs: &[ForwardReq],
    ) -> Vec<Result<Vec<f32>>> {
        if reqs.len() <= 1 {
            return reqs
                .iter()
                .map(|r| self.forward_one(r, &self.pool))
                .collect();
        }
        // pack each distinct model once, on the caller's thread,
        // before fanning out
        for r in reqs {
            let _ = self.plan(r.model, r.folded);
        }
        let seq = ScopedPool::sequential();
        self.pool
            .map(reqs.len(), |i| self.forward_one(&reqs[i], &seq))
    }
}

/// One request of a [`NativeBackend::forward_many`] micro-batch.
pub struct ForwardReq<'a> {
    pub model: &'a str,
    pub folded: &'a [NamedTensor],
    pub ems: &'a [ErrorModel],
    pub seed: u32,
    pub x: &'a [f32],
    pub batch: usize,
    /// Request-scoped trace id (DESIGN.md §17); 0 when the caller is
    /// not serving a traced request (CLI, eval, benches).
    pub trace: u64,
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn logits(
        &self,
        model: &str,
        folded: &[NamedTensor],
        x: &[f32],
        batch: usize,
        ems: &[ErrorModel],
        seed: u32,
    ) -> Result<Vec<f32>> {
        self.forward_one(
            &ForwardReq {
                model,
                folded,
                ems,
                seed,
                x,
                batch,
                trace: 0,
            },
            &self.pool,
        )
    }

    /// Same batch/seed schedule as the trait default, but resolves the
    /// prepared plan (one content hash over the folded tensors) once
    /// per pass instead of once per batch, and reuses one scratch
    /// arena across all batches.
    fn accuracy(
        &self,
        model: &str,
        folded: &[NamedTensor],
        spec: DatasetSpec,
        ems: &[ErrorModel],
        limit: usize,
        seed: u32,
    ) -> Result<f64> {
        let plan = self.plan(model, folded)?;
        ensure!(
            ems.len() == plan.n_matmuls(),
            "{model}: need {} error models, got {}",
            plan.n_matmuls(),
            ems.len()
        );
        let eb = plan.meta.eval_batch;
        let n_classes = plan.meta.n_classes;
        let mut loader = Loader::new(spec, Split::Test, eb, limit, 0xE7A1);
        let n_batches = (limit / eb).max(1);
        let (mut correct, mut total) = (0usize, 0usize);
        let mut scratch = plan.take_arena();
        for bi in 0..n_batches {
            let batch = loader.next_batch();
            let logits = Exec {
                plan: &plan,
                pool: &self.pool,
                kind: self.kind,
                tile: self.tile,
                fused: self.fused,
                mode: Mode::Error {
                    ems,
                    // per-batch seed: decorrelates batches within one run
                    seed: seed.wrapping_add(bi as u32 * 0x9E37),
                },
                hist: None,
                scratch: &mut scratch,
                eng_i: 0,
                aff_i: 0,
            }
            .run(&batch.x, eb)?;
            for (i, &label) in batch.labels.iter().enumerate() {
                if argmax(&logits[i * n_classes..(i + 1) * n_classes])
                    == label
                {
                    correct += 1;
                }
                total += 1;
            }
            // the logits buffer came from the arena — hand it back
            scratch.put_f32(logits);
        }
        plan.put_arena(scratch);
        Ok(correct as f64 / total.max(1) as f64)
    }

    fn fmac(
        &self,
        model: &str,
        folded: &[NamedTensor],
        spec: DatasetSpec,
        limit: usize,
        seed: u64,
    ) -> Result<FmacResult> {
        let plan = self.plan(model, folded)?;
        let hb = plan.meta.hist_batch;
        let n_classes = plan.meta.n_classes;
        let mut loader =
            Loader::new(spec, Split::Train, hb, limit, seed);
        let n_batches = (limit / hb).max(1);
        let mut per = vec![Fmac::new(); plan.n_matmuls()];
        let (mut correct, mut total) = (0usize, 0usize);
        let mut scratch = plan.take_arena();
        for _ in 0..n_batches {
            let batch = loader.next_batch();
            let logits = Exec {
                plan: &plan,
                pool: &self.pool,
                kind: self.kind,
                tile: self.tile,
                fused: self.fused,
                mode: Mode::Exact,
                hist: Some(&mut per),
                scratch: &mut scratch,
                eng_i: 0,
                aff_i: 0,
            }
            .run(&batch.x, hb)?;
            for (i, &label) in batch.labels.iter().enumerate() {
                if argmax(&logits[i * n_classes..(i + 1) * n_classes])
                    == label
                {
                    correct += 1;
                }
                total += 1;
            }
            scratch.put_f32(logits);
        }
        plan.put_arena(scratch);
        let mut sum = Fmac::new();
        for f in &per {
            sum.merge(f);
        }
        Ok(FmacResult {
            per_matmul: per,
            sum,
            accuracy: correct as f64 / total.max(1) as f64,
            n_samples: total,
        })
    }
}

/// Deterministic, *untrained* folded tensors for `model`: random +-1
/// weights (group pads +1), identity affines, zero logit bias. The
/// native fallback when neither a cached trained model nor the XLA
/// trainer is available — experiments still run end-to-end, but the
/// session flags the accuracy as untrained (near-chance) and keeps the
/// tensors out of the run store so they can never masquerade as a
/// trained model.
pub fn init_folded(model: &str) -> Result<Vec<NamedTensor>> {
    use crate::util::rng::Rng;
    let meta = arch::model_meta(model)?;
    let mut seed = 0xcbf2_9ce4_8422_2325u64;
    for b in model.as_bytes() {
        seed ^= *b as u64;
        seed = seed.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut rng = Rng::new(seed);
    let mut out = vec![];
    for s in meta.folded_signature() {
        match s {
            FoldedSig::Weight { name, o, k, kp } => {
                let mut data = vec![1.0f32; o * kp];
                for oi in 0..o {
                    for ki in 0..k {
                        data[oi * kp + ki] = rng.pm1(0.5);
                    }
                }
                out.push(NamedTensor {
                    name,
                    shape: vec![o, kp],
                    data,
                });
            }
            FoldedSig::Affine { scale, bias, ch } => {
                out.push(NamedTensor {
                    name: scale,
                    shape: vec![ch],
                    data: vec![1.0; ch],
                });
                out.push(NamedTensor {
                    name: bias,
                    shape: vec![ch],
                    data: vec![0.0; ch],
                });
            }
            FoldedSig::OutBias { name, n } => {
                out.push(NamedTensor {
                    name,
                    shape: vec![n],
                    data: vec![0.0; n],
                });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_folded_matches_signature() {
        for model in arch::model_names() {
            let folded = init_folded(model).unwrap();
            let plan = NativePlan::build(model, &folded).unwrap();
            assert_eq!(
                plan.n_matmuls(),
                arch::model_meta(model).unwrap().n_matmuls()
            );
        }
    }

    #[test]
    fn tiny_logits_deterministic_and_finite() {
        let folded = init_folded("vgg3_tiny").unwrap();
        let be = NativeBackend::new(2);
        let meta = arch::model_meta("vgg3_tiny").unwrap();
        let px: usize = meta.in_shape.iter().product();
        let b = 3usize;
        let mut rng = crate::util::rng::Rng::new(12);
        let x: Vec<f32> = (0..b * px).map(|_| rng.pm1(0.5)).collect();
        let ems: Vec<ErrorModel> = (0..meta.n_matmuls())
            .map(|_| ErrorModel::identity())
            .collect();
        let a = be.logits("vgg3_tiny", &folded, &x, b, &ems, 7).unwrap();
        let bl = be.logits("vgg3_tiny", &folded, &x, b, &ems, 7).unwrap();
        assert_eq!(a, bl);
        assert_eq!(a.len(), b * meta.n_classes);
        assert!(a.iter().all(|v| v.is_finite()));
        // logits vary across samples (the net is not constant)
        assert_ne!(
            &a[..meta.n_classes],
            &a[meta.n_classes..2 * meta.n_classes]
        );
    }

    #[test]
    fn identity_error_model_is_integer_logits_plus_bias() {
        // with identity decode every matmul is the exact +-1 dot, so
        // pre-bias logits are integers
        let folded = init_folded("vgg3_tiny").unwrap();
        let be = NativeBackend::new(1);
        let meta = arch::model_meta("vgg3_tiny").unwrap();
        let px: usize = meta.in_shape.iter().product();
        let mut rng = crate::util::rng::Rng::new(5);
        let x: Vec<f32> = (0..px).map(|_| rng.pm1(0.5)).collect();
        let ems: Vec<ErrorModel> = (0..meta.n_matmuls())
            .map(|_| ErrorModel::identity())
            .collect();
        let l = be.logits("vgg3_tiny", &folded, &x, 1, &ems, 0).unwrap();
        for v in &l {
            assert_eq!(v.fract(), 0.0, "{v}");
        }
    }

    #[test]
    fn logits_identical_across_kernel_tiers_and_fusion() {
        let folded = init_folded("vgg3_tiny").unwrap();
        let meta = arch::model_meta("vgg3_tiny").unwrap();
        let px: usize = meta.in_shape.iter().product();
        let mut rng = crate::util::rng::Rng::new(21);
        let x: Vec<f32> = (0..2 * px).map(|_| rng.pm1(0.5)).collect();
        let ems: Vec<ErrorModel> = (0..meta.n_matmuls())
            .map(|_| ErrorModel::identity())
            .collect();
        let want = NativeBackend::with_options(1, KernelKind::Scalar, true)
            .logits("vgg3_tiny", &folded, &x, 2, &ems, 3)
            .unwrap();
        for kind in [KernelKind::Scalar, KernelKind::detect()] {
            for fused in [true, false] {
                let be = NativeBackend::with_options(2, kind, fused);
                let got = be
                    .logits("vgg3_tiny", &folded, &x, 2, &ems, 3)
                    .unwrap();
                assert_eq!(got, want, "{} fused={fused}", kind.name());
            }
        }
    }

    #[test]
    fn fmac_identical_across_tiles() {
        // bit-identity is tile-independent: the exact (clean) pass
        // runs register-blocked, and every tile shape — including the
        // scalar-safe word-kernel escape hatch — must produce the
        // same histograms and accuracy, fused and unfused
        let folded = init_folded("vgg3_tiny").unwrap();
        let spec = crate::data::synth::Dataset::FashionSyn.spec();
        let want = NativeBackend::with_options(1, KernelKind::Scalar, true)
            .with_tile(ResolvedTile::ScalarSafe)
            .fmac("vgg3_tiny", &folded, spec.clone(), 16, 9)
            .unwrap();
        let kind = KernelKind::detect();
        for tile in [
            ResolvedTile::ScalarSafe,
            ResolvedTile::Blocked(Tile::new(1, 1, 1)),
            ResolvedTile::Blocked(Tile::new(2, 8, 16)),
            ResolvedTile::Blocked(Tile::default_for(kind)),
        ] {
            for fused in [true, false] {
                let be = NativeBackend::with_options(2, kind, fused)
                    .with_tile(tile);
                let got = be
                    .fmac("vgg3_tiny", &folded, spec.clone(), 16, 9)
                    .unwrap();
                assert_eq!(
                    got.per_matmul,
                    want.per_matmul,
                    "tile {} fused={fused}",
                    tile.name()
                );
                assert_eq!(got.accuracy, want.accuracy);
            }
        }
    }

    #[test]
    fn arena_buffers_are_reused_across_passes() {
        let folded = init_folded("vgg3_tiny").unwrap();
        let be = NativeBackend::new(1);
        let spec = crate::data::synth::Dataset::FashionSyn.spec();
        let a = be.fmac("vgg3_tiny", &folded, spec.clone(), 16, 9).unwrap();
        let plan = be.plan("vgg3_tiny", &folded).unwrap();
        let parked = plan.parked();
        assert!(parked > 0, "arena empty after a pass");
        // a second pass must not grow the freelists (steady state)
        let b = be.fmac("vgg3_tiny", &folded, spec, 16, 9).unwrap();
        assert_eq!(a.per_matmul, b.per_matmul);
        assert_eq!(plan.parked(), parked, "arena grew");
    }

    #[test]
    fn forward_many_is_bit_identical_to_solo_requests() {
        let folded = init_folded("vgg3_tiny").unwrap();
        let meta = arch::model_meta("vgg3_tiny").unwrap();
        let px: usize = meta.in_shape.iter().product();
        let ems: Vec<ErrorModel> = (0..meta.n_matmuls())
            .map(|_| ErrorModel::identity())
            .collect();
        let mut rng = crate::util::rng::Rng::new(33);
        // six requests with distinct inputs, seeds and batch sizes
        let xs: Vec<(Vec<f32>, u32, usize)> = (0..6)
            .map(|i| {
                let b = 1 + (i % 3);
                let x: Vec<f32> =
                    (0..b * px).map(|_| rng.pm1(0.5)).collect();
                (x, 7 + i as u32, b)
            })
            .collect();
        let be = NativeBackend::new(3);
        // solo replies via the ordinary logits path
        let solo: Vec<Vec<f32>> = xs
            .iter()
            .map(|(x, seed, b)| {
                be.logits("vgg3_tiny", &folded, x, *b, &ems, *seed)
                    .unwrap()
            })
            .collect();
        let reqs: Vec<ForwardReq> = xs
            .iter()
            .map(|(x, seed, b)| ForwardReq {
                model: "vgg3_tiny",
                folded: &folded,
                ems: &ems,
                seed: *seed,
                x,
                batch: *b,
                trace: 0,
            })
            .collect();
        let batched = be.forward_many(&reqs);
        for (i, (got, want)) in
            batched.iter().zip(solo.iter()).enumerate()
        {
            assert_eq!(
                got.as_ref().unwrap(),
                want,
                "request {i} changed under micro-batching"
            );
        }
        // later micro-batches recycle the parked arenas: the freelist
        // never outgrows the worker count, however batches schedule
        let plan = be.plan("vgg3_tiny", &folded).unwrap();
        assert!(plan.parked() > 0);
        for _ in 0..4 {
            let again = be.forward_many(&reqs);
            for (got, want) in again.iter().zip(solo.iter()) {
                assert_eq!(got.as_ref().unwrap(), want);
            }
            let arenas = be
                .plans
                .lock()
                .unwrap()
                .values()
                .map(|p| p.arenas.lock().unwrap().len())
                .sum::<usize>();
            assert!(
                arenas <= be.pool.threads(),
                "arena freelist outgrew the worker count: {arenas}"
            );
        }
    }

    #[test]
    fn forward_many_reports_per_request_errors() {
        let folded = init_folded("vgg3_tiny").unwrap();
        let meta = arch::model_meta("vgg3_tiny").unwrap();
        let px: usize = meta.in_shape.iter().product();
        let ems: Vec<ErrorModel> = (0..meta.n_matmuls())
            .map(|_| ErrorModel::identity())
            .collect();
        let good: Vec<f32> = vec![1.0; px];
        let bad_ems: Vec<ErrorModel> = vec![ErrorModel::identity()];
        let reqs = vec![
            ForwardReq {
                model: "vgg3_tiny",
                folded: &folded,
                ems: &ems,
                seed: 1,
                x: &good,
                batch: 1,
                trace: 0,
            },
            // wrong error-model arity: this request fails, the other
            // still answers
            ForwardReq {
                model: "vgg3_tiny",
                folded: &folded,
                ems: &bad_ems,
                seed: 1,
                x: &good,
                batch: 1,
                trace: 0,
            },
        ];
        let be = NativeBackend::new(2);
        let out = be.forward_many(&reqs);
        assert!(out[0].is_ok());
        assert!(out[1].is_err());
    }
}
