//! Per-machine tile autotuning for the register-blocked bit-GEMM
//! (DESIGN.md §14).
//!
//! The blocked kernels ship a few (MR, NR, K-chunk) instantiations;
//! which one wins depends on the machine (register file, popcount
//! throughput, L1 size), not the workload — the operands are always
//! streamed packed words. So the choice is measured **once per
//! machine** on a fig8-shaped synthetic engine and memoized in
//! `runs/autotune.json`, keyed by `"<tier>|<cpu brand string>"` with
//! schema + kernel version fields. The resolved tile is provenance:
//! it is recorded in `PointMeta` next to the kernel tier and **never**
//! enters spec cache keys (bit-identity makes every tile choice
//! produce the same numbers).
//!
//! Cache-handling contract: any irregularity — missing file, corrupt
//! JSON, version mismatch, out-of-range tile — silently re-tunes and
//! rewrites. The cache can never panic the process, and
//! `--tile scalar-safe` bypasses this module entirely.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::backend::kernels::{
    self, KernelKind, PackScratch, ResolvedTile, Tile, TileSpec,
};
use crate::bnn::bitpack::BitMatrix;
use crate::bnn::SubMacEngine;
use crate::util::json::{obj, Json};
use crate::util::pool::ScopedPool;

/// Bumped whenever the blocked kernels change enough that a cached
/// tile choice may no longer be the winner; mismatched entries are
/// ignored and re-measured.
pub const KERNEL_VERSION: u32 = 1;

/// Schema version of `runs/autotune.json`.
const CACHE_VERSION: u32 = 1;

/// The CPU brand string (x86 cpuid leaves 0x80000002..4), or the
/// architecture name where unavailable — cache entries follow the
/// machine, not the binary.
pub fn cpu_brand() -> String {
    #[cfg(target_arch = "x86_64")]
    {
        // safety: __cpuid is always executable on x86_64; leaf
        // support is checked through the 0x8000_0000 max-leaf query
        let max = unsafe { std::arch::x86_64::__cpuid(0x8000_0000) }.eax;
        if max >= 0x8000_0004 {
            let mut bytes = Vec::with_capacity(48);
            for leaf in 0x8000_0002u32..=0x8000_0004 {
                let r = unsafe { std::arch::x86_64::__cpuid(leaf) };
                for reg in [r.eax, r.ebx, r.ecx, r.edx] {
                    bytes.extend_from_slice(&reg.to_le_bytes());
                }
            }
            let s = String::from_utf8_lossy(&bytes);
            let s = s.trim_matches(char::from(0)).trim().to_string();
            if !s.is_empty() {
                return s;
            }
        }
    }
    std::env::consts::ARCH.to_string()
}

/// Cache key for one (tier, machine) pair. Versions are separate
/// top-level fields so a kernel bump invalidates every entry at once.
pub fn cache_key(kind: KernelKind) -> String {
    format!("{}|{}", kind.name(), cpu_brand())
}

fn tile_json(t: Tile) -> Json {
    obj(vec![
        ("mr", Json::Num(t.mr as f64)),
        ("nr", Json::Num(t.nr as f64)),
        ("kb", Json::Num(t.kb as f64)),
    ])
}

/// Pattern-matching (never-panicking) tile reader: anything that is
/// not three integral in-range numbers is treated as absent.
fn tile_from_json(v: &Json) -> Option<Tile> {
    let num = |key: &str| match v.get(key) {
        Some(Json::Num(n)) if *n >= 1.0 && n.fract() == 0.0 => {
            Some(*n as usize)
        }
        _ => None,
    };
    let t = Tile::new(num("mr")?, num("nr")?, num("kb")?);
    t.is_valid().then_some(t)
}

fn versions_match(root: &Json) -> bool {
    let num_is = |key: &str, want: u32| {
        matches!(root.get(key), Some(Json::Num(n)) if *n == want as f64)
    };
    num_is("version", CACHE_VERSION)
        && num_is("kernel_version", KERNEL_VERSION)
}

/// Load the cached winner for `kind` from `path`. Any irregularity —
/// missing file, unparseable JSON, wrong schema or kernel version,
/// out-of-range tile — returns `None` and the caller re-tunes.
pub fn load_cached(kind: KernelKind, path: &Path) -> Option<Tile> {
    let text = std::fs::read_to_string(path).ok()?;
    let root = Json::parse(&text).ok()?;
    if !versions_match(&root) {
        return None;
    }
    tile_from_json(root.get("entries")?.get(&cache_key(kind))?)
}

/// Persist `tile` as the winner for `kind`, keeping any valid
/// existing entries (other tiers, or other machines sharing the runs
/// dir). Best-effort: an unwritable path just loses the memo.
pub fn save_cached(kind: KernelKind, tile: Tile, path: &Path) {
    let mut entries: BTreeMap<String, Json> = BTreeMap::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(root) = Json::parse(&text) {
            if versions_match(&root) {
                if let Some(Json::Obj(m)) = root.get("entries") {
                    for (k, v) in m {
                        if tile_from_json(v).is_some() {
                            entries.insert(k.clone(), v.clone());
                        }
                    }
                }
            }
        }
    }
    entries.insert(cache_key(kind), tile_json(tile));
    let root = obj(vec![
        ("version", Json::Num(CACHE_VERSION as f64)),
        ("kernel_version", Json::Num(KERNEL_VERSION as f64)),
        ("entries", Json::Obj(entries)),
    ]);
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    let _ = std::fs::write(path, root.to_string());
}

/// Measure every candidate tile for `kind` on a fig8-shaped synthetic
/// engine (o=32, K=288, serve-sized activation batch) and return the
/// fastest — a few milliseconds, paid once per machine.
pub fn measure_best(kind: KernelKind) -> Tile {
    let (o, k, d) = (32usize, 288usize, 768usize);
    // xorshift64*-style deterministic operands (no clock, no seed
    // plumbing): the tuner must be reproducible on one machine
    let mut state = 0x1234_5678_9abc_def0u64;
    let mut pm = |n: usize| -> Vec<f32> {
        (0..n)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                if state & 1 == 1 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect()
    };
    let w = pm(o * k);
    let x = pm(d * k);
    let eng = SubMacEngine::new(o, k, &w, k);
    let xb = BitMatrix::pack(d, k, &x, false);
    let pool = ScopedPool::sequential();
    let mut scratch = PackScratch::default();
    let mut out = vec![0.0f32; o * d];
    let mut best: Option<(Tile, std::time::Duration)> = None;
    for tile in Tile::candidates(kind) {
        let rt = ResolvedTile::Blocked(tile);
        // warm pass faults the scratch buffers + instruction cache
        kernels::matmul_exact_tiled_into(
            &pool,
            &eng,
            &xb,
            kind,
            rt,
            &mut scratch,
            &mut out,
        );
        let mut fastest = std::time::Duration::MAX;
        for _ in 0..3 {
            let t0 = Instant::now();
            kernels::matmul_exact_tiled_into(
                &pool,
                &eng,
                &xb,
                kind,
                rt,
                &mut scratch,
                &mut out,
            );
            fastest = fastest.min(t0.elapsed());
        }
        match best {
            Some((_, b)) if b <= fastest => {}
            _ => best = Some((tile, fastest)),
        }
    }
    best.map(|(t, _)| t).unwrap_or_else(|| Tile::default_for(kind))
}

/// Load-or-measure-and-save, without the process-wide memo (tests
/// drive this directly so every call re-reads the file).
pub fn tuned_tile_uncached(kind: KernelKind, path: &Path) -> Tile {
    if let Some(t) = load_cached(kind, path) {
        crate::obs::registry::inc("backend.autotune.cache_hits");
        return t;
    }
    crate::obs::registry::inc("backend.autotune.measures");
    let t = measure_best(kind);
    save_cached(kind, t, path);
    t
}

/// The autotuned tile for `kind`, memoized per (tier, machine, cache
/// path) for the life of the process — one measurement per machine,
/// then pure lookups.
pub fn tuned_tile(kind: KernelKind, path: &Path) -> Tile {
    static MEMO: OnceLock<Mutex<HashMap<String, Tile>>> = OnceLock::new();
    let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    let key = format!("{}|{}", cache_key(kind), path.display());
    if let Some(t) = memo.lock().unwrap().get(&key) {
        crate::obs::registry::inc("backend.autotune.memo_hits");
        return *t;
    }
    let t = tuned_tile_uncached(kind, path);
    memo.lock().unwrap().insert(key, t);
    t
}

/// Resolve a parsed `--tile` request for this machine: `Auto` goes
/// through the cache (measuring on first use), `ScalarSafe` bypasses
/// the blocked path, fixed tiles pass straight through.
pub fn resolve(
    spec: TileSpec,
    kind: KernelKind,
    cache_path: &Path,
) -> ResolvedTile {
    match spec {
        TileSpec::Auto => {
            ResolvedTile::Blocked(tuned_tile(kind, cache_path))
        }
        TileSpec::ScalarSafe => ResolvedTile::ScalarSafe,
        TileSpec::Fixed(t) => ResolvedTile::Blocked(t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!(
                "capmin_autotune_{tag}_{}",
                std::process::id()
            ))
            .join("autotune.json")
    }

    #[test]
    fn garbage_cache_recovers_by_retuning() {
        let path = test_path("garbage");
        if let Some(p) = path.parent() {
            let _ = std::fs::create_dir_all(p);
        }
        std::fs::write(&path, "{not json at all").unwrap();
        let kind = KernelKind::detect();
        // corrupt cache is ignored, never a panic...
        assert_eq!(load_cached(kind, &path), None);
        // ...and the uncached resolver re-tunes straight through it
        let t = tuned_tile_uncached(kind, &path);
        assert!(t.is_valid());
        // the re-tune rewrote the cache: a second load round-trips
        assert_eq!(load_cached(kind, &path), Some(t));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_mismatch_invalidates() {
        let path = test_path("version");
        let kind = KernelKind::detect();
        let tile = Tile::new(2, 4, 64);
        save_cached(kind, tile, &path);
        assert_eq!(load_cached(kind, &path), Some(tile));
        // bump kernel_version in place -> stale entry ignored
        let text = std::fs::read_to_string(&path).unwrap();
        let bumped = text.replace(
            &format!("\"kernel_version\":{KERNEL_VERSION}"),
            &format!("\"kernel_version\":{}", KERNEL_VERSION + 1),
        );
        assert_ne!(text, bumped, "kernel_version field missing");
        std::fs::write(&path, bumped).unwrap();
        assert_eq!(load_cached(kind, &path), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_roundtrips_and_merges_entries() {
        let path = test_path("merge");
        let _ = std::fs::remove_file(&path);
        let det = KernelKind::detect();
        save_cached(KernelKind::Scalar, Tile::new(4, 8, 64), &path);
        save_cached(det, Tile::new(2, 4, 16), &path);
        assert_eq!(load_cached(det, &path), Some(Tile::new(2, 4, 16)));
        if det != KernelKind::Scalar {
            // the second save merged, not clobbered
            assert_eq!(
                load_cached(KernelKind::Scalar, &path),
                Some(Tile::new(4, 8, 64))
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn out_of_range_cached_tile_is_rejected() {
        let path = test_path("range");
        let key = cache_key(KernelKind::Scalar);
        // handcraft a current-version cache whose tile has MR = 3 —
        // no such kernel instantiation exists
        let root = obj(vec![
            ("version", Json::Num(CACHE_VERSION as f64)),
            ("kernel_version", Json::Num(KERNEL_VERSION as f64)),
            (
                "entries",
                obj(vec![(
                    key.as_str(),
                    obj(vec![
                        ("mr", Json::Num(3.0)),
                        ("nr", Json::Num(4.0)),
                        ("kb", Json::Num(64.0)),
                    ]),
                )]),
            ),
        ]);
        if let Some(p) = path.parent() {
            let _ = std::fs::create_dir_all(p);
        }
        std::fs::write(&path, root.to_string()).unwrap();
        assert_eq!(load_cached(KernelKind::Scalar, &path), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resolve_maps_specs() {
        let path = test_path("resolve");
        let _ = std::fs::remove_file(&path);
        assert_eq!(
            resolve(TileSpec::ScalarSafe, KernelKind::Scalar, &path),
            ResolvedTile::ScalarSafe
        );
        let t = Tile::new(8, 4, 32);
        assert_eq!(
            resolve(TileSpec::Fixed(t), KernelKind::Scalar, &path),
            ResolvedTile::Blocked(t)
        );
        // Auto measures (scalar candidates are cheap) and caches
        match resolve(TileSpec::Auto, KernelKind::Scalar, &path) {
            ResolvedTile::Blocked(t) => assert!(t.is_valid()),
            ResolvedTile::ScalarSafe => panic!("auto must block"),
        }
        assert!(path.exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cpu_brand_is_stable_and_nonempty() {
        let b = cpu_brand();
        assert!(!b.is_empty());
        assert_eq!(b, cpu_brand());
        assert!(cache_key(KernelKind::Scalar).starts_with("scalar|"));
    }
}
