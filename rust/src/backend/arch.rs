//! Native model registry — the Rust twin of `python/compile/arch.py` +
//! `configs.py` (paper Table II at the CPU-budget widths).
//!
//! The AOT manifest records the same information for artifact wiring,
//! but the manifest only exists after `make artifacts`; this registry
//! lets the native backend derive every shape (folded tensor layout,
//! batch sizes, matmul count) without Python, XLA or artifacts. The
//! values are pinned to the default (non-`--full`) AOT configs — the
//! integration suite cross-checks them against the manifest when it is
//! present.

use anyhow::{anyhow, Result};

use crate::capmin::ARRAY_SIZE;

/// One op of an architecture spec (`python/compile/arch.py` docstring).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArchOp {
    /// Binarized conv, SAME padding with -1: (out_channels, stride,
    /// kernel size).
    Conv(usize, usize, usize),
    /// Max pool k x k, stride k.
    MaxPool(usize),
    /// Batch norm — a digital affine after export folding.
    Bn,
    /// Binarize activations to +-1.
    Sign,
    /// ResNet skip-connection block: (out_channels, stride). Expands to
    /// conv3/bn/sign + conv3/bn + projection conv1/bn + merge + sign,
    /// consuming three matmuls (see `python/compile/nn.py`).
    Scb(usize, usize),
    Flatten,
    /// Binarized fully connected: out features.
    Fc(usize),
    /// Final binarized FC with f32 bias: n_classes.
    Out(usize),
}

/// Static per-model metadata (the manifest's `ModelInfo`, natively).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: &'static str,
    pub in_shape: [usize; 3],
    pub n_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub hist_batch: usize,
    pub spec: Vec<ArchOp>,
}

fn vgg3(width: f64, fc_width: f64) -> Vec<ArchOp> {
    let c = ((64.0 * width) as usize).max(8);
    let f = ((2048.0 * fc_width) as usize).max(16);
    vec![
        ArchOp::Conv(c, 1, 3),
        ArchOp::MaxPool(2),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Conv(c, 1, 3),
        ArchOp::MaxPool(2),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Flatten,
        ArchOp::Fc(f),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Out(10),
    ]
}

fn vgg7(width: f64, fc_width: f64) -> Vec<ArchOp> {
    let c1 = ((128.0 * width) as usize).max(8);
    let c2 = ((256.0 * width) as usize).max(8);
    let c3 = ((512.0 * width) as usize).max(8);
    let f = ((1024.0 * fc_width) as usize).max(16);
    vec![
        ArchOp::Conv(c1, 1, 3),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Conv(c1, 1, 3),
        ArchOp::MaxPool(2),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Conv(c2, 1, 3),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Conv(c2, 1, 3),
        ArchOp::MaxPool(2),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Conv(c3, 1, 3),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Conv(c3, 1, 3),
        ArchOp::MaxPool(2),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Flatten,
        ArchOp::Fc(f),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Out(10),
    ]
}

fn resnet18(width: f64) -> Vec<ArchOp> {
    let b = ((64.0 * width) as usize).max(8);
    vec![
        ArchOp::Conv(b, 1, 3),
        ArchOp::Bn,
        ArchOp::Sign,
        ArchOp::Scb(b, 1),
        ArchOp::Scb(2 * b, 2),
        ArchOp::Scb(4 * b, 2),
        ArchOp::MaxPool(2),
        ArchOp::Scb(8 * b, 1),
        ArchOp::MaxPool(4),
        ArchOp::Flatten,
        ArchOp::Out(10),
    ]
}

/// The model registry at the default CPU-budget widths
/// (`python/compile/configs.py::model_configs(full=False)`).
pub fn model_meta(name: &str) -> Result<ModelMeta> {
    let mm = match name {
        "vgg3" => ModelMeta {
            name: "vgg3",
            in_shape: [1, 28, 28],
            n_classes: 10,
            train_batch: 64,
            eval_batch: 16,
            hist_batch: 32,
            spec: vgg3(0.5, 0.25),
        },
        "vgg7" => ModelMeta {
            name: "vgg7",
            in_shape: [3, 32, 32],
            n_classes: 10,
            train_batch: 32,
            eval_batch: 8,
            hist_batch: 16,
            spec: vgg7(0.25, 0.25),
        },
        "resnet18" => ModelMeta {
            name: "resnet18",
            in_shape: [3, 64, 64],
            n_classes: 10,
            train_batch: 16,
            eval_batch: 8,
            hist_batch: 8,
            spec: resnet18(0.25),
        },
        "vgg3_tiny" => ModelMeta {
            name: "vgg3_tiny",
            in_shape: [1, 28, 28],
            n_classes: 10,
            train_batch: 16,
            eval_batch: 8,
            hist_batch: 8,
            spec: vgg3(0.125, 32.0 / 2048.0),
        },
        other => {
            return Err(anyhow!(
                "unknown model `{other}` (native registry: vgg3, vgg7, \
                 resnet18, vgg3_tiny)"
            ))
        }
    };
    Ok(mm)
}

pub fn model_names() -> [&'static str; 4] {
    ["vgg3", "vgg7", "resnet18", "vgg3_tiny"]
}

impl ModelMeta {
    pub fn n_matmuls(&self) -> usize {
        self.spec
            .iter()
            .map(|op| match op {
                ArchOp::Conv(..) | ArchOp::Fc(_) | ArchOp::Out(_) => 1,
                ArchOp::Scb(..) => 3,
                _ => 0,
            })
            .sum()
    }

    /// One-line architecture description (Table II regeneration;
    /// mirrors `arch.py::describe`).
    pub fn describe(&self) -> String {
        let mut rows = vec![];
        for op in &self.spec {
            match *op {
                ArchOp::Conv(c, s, _) => rows.push(if s != 1 {
                    format!("C{c}/s{s}")
                } else {
                    format!("C{c}")
                }),
                ArchOp::MaxPool(k) => rows.push(format!("MP{k}")),
                ArchOp::Scb(c, s) => rows.push(if s != 1 {
                    format!("SCB{c}/s{s}")
                } else {
                    format!("SCB{c}")
                }),
                ArchOp::Fc(f) => rows.push(format!("FC{f}")),
                ArchOp::Out(n) => rows.push(format!("FC{n}")),
                _ => {}
            }
        }
        rows.join(" -> ")
    }

    /// Shapes of every folded hardware tensor in `export_folded` order:
    /// per matmul a padded +-1 weight `wb{i}` [O, Kp] (plus the true
    /// pre-padding reduction length), per BN a scale/bias pair, and the
    /// final f32 out bias.
    pub fn folded_signature(&self) -> Vec<FoldedSig> {
        let mut out = vec![];
        let [mut c, mut h, mut w] = self.in_shape;
        let mut flat = 0usize;
        let mut mat = 0usize;
        let mut bni = 0usize;
        let mut last_bn_ch = c;
        let mut emit_w = |out: &mut Vec<FoldedSig>, o: usize, k: usize| {
            out.push(FoldedSig::Weight {
                name: format!("wb{mat}"),
                o,
                k,
                kp: k.div_ceil(ARRAY_SIZE) * ARRAY_SIZE,
            });
            mat += 1;
        };
        let mut emit_bn = |out: &mut Vec<FoldedSig>, ch: usize| {
            out.push(FoldedSig::Affine {
                scale: format!("scale{bni}"),
                bias: format!("bias{bni}"),
                ch,
            });
            bni += 1;
        };
        for op in &self.spec {
            match *op {
                ArchOp::Conv(oc, s, k) => {
                    emit_w(&mut out, oc, c * k * k);
                    c = oc;
                    h = h.div_ceil(s);
                    w = w.div_ceil(s);
                    last_bn_ch = c;
                }
                ArchOp::MaxPool(k) => {
                    h /= k;
                    w /= k;
                }
                ArchOp::Bn => emit_bn(&mut out, last_bn_ch),
                ArchOp::Sign => {}
                ArchOp::Scb(oc, s) => {
                    emit_w(&mut out, oc, c * 9);
                    emit_bn(&mut out, oc);
                    emit_w(&mut out, oc, oc * 9);
                    emit_bn(&mut out, oc);
                    emit_w(&mut out, oc, c);
                    emit_bn(&mut out, oc);
                    c = oc;
                    h = h.div_ceil(s);
                    w = w.div_ceil(s);
                    last_bn_ch = c;
                }
                ArchOp::Flatten => {
                    flat = c * h * w;
                    last_bn_ch = flat;
                }
                ArchOp::Fc(f) => {
                    emit_w(&mut out, f, flat);
                    flat = f;
                    last_bn_ch = flat;
                }
                ArchOp::Out(n) => {
                    emit_w(&mut out, n, flat);
                    out.push(FoldedSig::OutBias {
                        name: "out.b".into(),
                        n,
                    });
                }
            }
        }
        out
    }

    /// Total binarized weight cells (pre-padding) across all matmuls.
    pub fn n_weight_bits(&self) -> usize {
        self.folded_signature()
            .iter()
            .map(|s| match s {
                FoldedSig::Weight { o, k, .. } => o * k,
                _ => 0,
            })
            .sum()
    }
}

/// One folded tensor the export stage emits, with its shape.
#[derive(Clone, Debug, PartialEq)]
pub enum FoldedSig {
    /// +-1 weight matrix [o, kp] (kp = k padded to the a=32 groups).
    Weight {
        name: String,
        o: usize,
        k: usize,
        kp: usize,
    },
    /// Folded batch-norm affine (scale/bias, `ch` each).
    Affine {
        scale: String,
        bias: String,
        ch: usize,
    },
    /// Final f32 logit bias [n].
    OutBias { name: String, n: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_aot_configs() {
        let m = model_meta("vgg3").unwrap();
        assert_eq!(m.n_matmuls(), 4);
        assert_eq!(m.describe(), "C32 -> MP2 -> C32 -> MP2 -> FC512 -> FC10");
        let m = model_meta("vgg7").unwrap();
        assert_eq!(m.n_matmuls(), 8);
        let m = model_meta("resnet18").unwrap();
        assert_eq!(m.n_matmuls(), 14);
        let m = model_meta("vgg3_tiny").unwrap();
        assert_eq!(m.n_matmuls(), 4);
        assert!(model_meta("nope").is_err());
    }

    #[test]
    fn vgg3_folded_signature_shapes() {
        let m = model_meta("vgg3").unwrap();
        let sig = m.folded_signature();
        // wb0 [32, 9->32], bn, wb1 [32, 288], bn, wb2 [512, 1568], bn,
        // wb3 [10, 512], out.b [10]
        match &sig[0] {
            FoldedSig::Weight { o, k, kp, .. } => {
                assert_eq!((*o, *k, *kp), (32, 9, 32));
            }
            other => panic!("wb0 expected, got {other:?}"),
        }
        match &sig[4] {
            FoldedSig::Weight { o, k, kp, .. } => {
                assert_eq!((*o, *k, *kp), (512, 1568, 1568));
            }
            other => panic!("wb2 expected, got {other:?}"),
        }
        match sig.last().unwrap() {
            FoldedSig::OutBias { n, .. } => assert_eq!(*n, 10),
            other => panic!("out.b expected, got {other:?}"),
        }
        assert_eq!(
            sig.iter()
                .filter(|s| matches!(s, FoldedSig::Weight { .. }))
                .count(),
            m.n_matmuls()
        );
    }

    #[test]
    fn resnet_signature_walks_strides_and_pools() {
        let m = model_meta("resnet18").unwrap();
        let sig = m.folded_signature();
        // final out matmul consumes 8b * 2 * 2 = 512 features (b = 16)
        let last_w = sig
            .iter()
            .rev()
            .find_map(|s| match s {
                FoldedSig::Weight { o, k, .. } => Some((*o, *k)),
                _ => None,
            })
            .unwrap();
        assert_eq!(last_w, (10, 128 * 2 * 2));
    }
}
