//! `XlaBackend` — the original artifact path behind the
//! [`InferenceBackend`] trait: folded tensors become PJRT literals and
//! run through the AOT `eval`/`evalp` and `hist` executables
//! (`coordinator::evaluator` / `coordinator::histogrammer`).
//!
//! Only compiled with the `xla` cargo feature; selection happens in
//! `DesignSession` (`--backend xla` or `auto` with artifacts present).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use super::{fold_hash, FmacResult, InferenceBackend};
use crate::bnn::ErrorModel;
use crate::coordinator::evaluator::{stack_error_models, Evaluator};
use crate::coordinator::histogrammer::Histogrammer;
use crate::coordinator::store::NamedTensor;
use crate::data::synth::DatasetSpec;
use crate::runtime::{lit_f32, lit_u32_scalar, to_f32, Runtime};

pub struct XlaBackend {
    rt: Arc<Runtime>,
    /// "eval" (jnp engine) or "evalp" (Pallas kernel engine).
    engine: String,
    /// Folded literals per (model, content hash): marshalled once per
    /// model, reused across the whole sweep.
    lits: Mutex<HashMap<(String, u64), Arc<Vec<xla::Literal>>>>,
}

impl XlaBackend {
    pub fn new(rt: Arc<Runtime>, engine: &str) -> XlaBackend {
        XlaBackend {
            rt,
            engine: engine.to_string(),
            lits: Mutex::new(HashMap::new()),
        }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn literals(
        &self,
        model: &str,
        folded: &[NamedTensor],
    ) -> Result<Arc<Vec<xla::Literal>>> {
        let key = (model.to_string(), fold_hash(folded));
        if let Some(l) = self.lits.lock().unwrap().get(&key) {
            return Ok(l.clone());
        }
        let lits: Vec<xla::Literal> = folded
            .iter()
            .map(|t| lit_f32(&t.shape, &t.data))
            .collect::<Result<_>>()?;
        let lits = Arc::new(lits);
        self.lits.lock().unwrap().insert(key, lits.clone());
        Ok(lits)
    }
}

impl InferenceBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn logits(
        &self,
        model: &str,
        folded: &[NamedTensor],
        x: &[f32],
        batch: usize,
        ems: &[ErrorModel],
        seed: u32,
    ) -> Result<Vec<f32>> {
        use crate::capmin::N_LEVELS;
        let mi = self.rt.manifest.model(model);
        ensure!(
            ems.len() == mi.n_matmuls,
            "{model}: need {} error models, got {}",
            mi.n_matmuls,
            ems.len()
        );
        let lits = self.literals(model, folded)?;
        let exe = self.rt.load(model, &self.engine)?;
        let x_shape = [&[batch], mi.in_shape.as_slice()].concat();
        let (cdf_v, vals_v) = stack_error_models(ems);
        let x_l = lit_f32(&x_shape, x)?;
        let cdf = lit_f32(&[mi.n_matmuls, N_LEVELS, N_LEVELS], &cdf_v)?;
        let vals = lit_f32(&[mi.n_matmuls, N_LEVELS], &vals_v)?;
        let seed_l = lit_u32_scalar(seed);
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.push(&x_l);
        inputs.push(&cdf);
        inputs.push(&vals);
        inputs.push(&seed_l);
        let outs = exe.run_borrowed(&inputs)?;
        to_f32(&outs[0])
    }

    /// Delegates to the proven [`Evaluator`] loop (same batch + seed
    /// schedule as the trait's default — one compiled executable and
    /// one cdf/vals marshalling per call instead of per batch).
    fn accuracy(
        &self,
        model: &str,
        folded: &[NamedTensor],
        spec: DatasetSpec,
        ems: &[ErrorModel],
        limit: usize,
        seed: u32,
    ) -> Result<f64> {
        let lits = self.literals(model, folded)?;
        Evaluator::new(&self.rt, &self.engine)
            .accuracy(model, &lits, spec, ems, limit, seed)
    }

    fn fmac(
        &self,
        model: &str,
        folded: &[NamedTensor],
        spec: DatasetSpec,
        limit: usize,
        seed: u64,
    ) -> Result<FmacResult> {
        let lits = self.literals(model, folded)?;
        let res = Histogrammer::new(&self.rt)
            .extract_dataset(model, &lits, spec, limit, seed)?;
        Ok(FmacResult {
            per_matmul: res.per_matmul,
            sum: res.sum,
            accuracy: res.accuracy,
            n_samples: res.n_samples,
        })
    }
}
