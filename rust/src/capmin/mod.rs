//! The paper's SW half: F_MAC histograms, CapMin (Sec. III-A) and
//! CapMin-V (Sec. III-B, Alg. 1).

pub mod capmin;
pub mod capmin_v;
pub mod histogram;

pub use capmin::{select_window, CapMinResult};
pub use capmin_v::{capmin_v, CapMinVResult};
pub use histogram::Fmac;

/// Sub-MAC levels 0..=32 for the a = 32 computing array.
pub const N_LEVELS: usize = 33;
/// Computing array size (paper Sec. IV-A2).
pub const ARRAY_SIZE: usize = 32;
