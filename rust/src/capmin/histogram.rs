//! F_MAC — absolute frequency of MAC level occurrences (paper Fig. 1).

use super::N_LEVELS;

/// Absolute-frequency histogram over the 33 sub-MAC levels.
#[derive(Clone, Debug, PartialEq)]
pub struct Fmac {
    pub counts: [u64; N_LEVELS],
}

impl Default for Fmac {
    fn default() -> Fmac {
        Fmac::new()
    }
}

impl Fmac {
    pub fn new() -> Fmac {
        Fmac {
            counts: [0; N_LEVELS],
        }
    }

    pub fn from_counts(counts: [u64; N_LEVELS]) -> Fmac {
        Fmac { counts }
    }

    /// Synthetic unimodal histogram: counts follow a gaussian bump of
    /// height `scale` at `peak` with width `sharp` — the shape trained
    /// models produce (Fig. 1). The shared fixture of the session tests
    /// and benches, also handy to probe operating points without a
    /// model.
    pub fn gaussian(peak: usize, sharp: f64, scale: f64) -> Fmac {
        let mut f = Fmac::new();
        for (m, c) in f.counts.iter_mut().enumerate() {
            let d = m as f64 - peak as f64;
            *c = (scale * (-d * d / (2.0 * sharp * sharp)).exp()) as u64;
        }
        f
    }

    /// Accumulate counts delivered by the hist artifact (f32 counts are
    /// exact integers below 2^24 per batch; summation happens here in u64).
    pub fn add_f32(&mut self, batch: &[f32]) {
        assert_eq!(batch.len(), N_LEVELS);
        for (c, &b) in self.counts.iter_mut().zip(batch) {
            debug_assert!(b >= 0.0 && b.fract() == 0.0, "count {b}");
            *c += b as u64;
        }
    }

    pub fn merge(&mut self, other: &Fmac) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Normalized frequencies.
    pub fn pmf(&self) -> [f64; N_LEVELS] {
        let t = self.total().max(1) as f64;
        let mut out = [0.0; N_LEVELS];
        for (o, &c) in out.iter_mut().zip(self.counts.iter()) {
            *o = c as f64 / t;
        }
        out
    }

    /// Normalize-and-add across benchmarks (the paper sums normalized
    /// F_MACs over all five datasets before applying CapMin, Sec. IV-B).
    pub fn combine_normalized(fmacs: &[&Fmac]) -> [f64; N_LEVELS] {
        let mut out = [0.0; N_LEVELS];
        for f in fmacs {
            let p = f.pmf();
            for (o, v) in out.iter_mut().zip(p.iter()) {
                *o += v;
            }
        }
        out
    }

    /// Dynamic range: max/min over non-zero bins (the paper observes 5-7
    /// orders of magnitude between the peak and the tails).
    pub fn dynamic_range(&self) -> f64 {
        let nz: Vec<u64> = self
            .counts
            .iter()
            .copied()
            .filter(|&c| c > 0)
            .collect();
        if nz.is_empty() {
            return 0.0;
        }
        let max = *nz.iter().max().unwrap() as f64;
        let min = *nz.iter().min().unwrap() as f64;
        max / min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_merge() {
        let mut a = Fmac::new();
        let mut batch = vec![0.0f32; N_LEVELS];
        batch[16] = 100.0;
        batch[15] = 50.0;
        a.add_f32(&batch);
        let mut b = Fmac::new();
        b.add_f32(&batch);
        a.merge(&b);
        assert_eq!(a.counts[16], 200);
        assert_eq!(a.total(), 300);
    }

    #[test]
    fn pmf_sums_to_one() {
        let mut f = Fmac::new();
        f.counts[10] = 30;
        f.counts[20] = 70;
        let p = f.pmf();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((p[20] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn combine_normalized_weighs_benchmarks_equally() {
        let mut small = Fmac::new();
        small.counts[10] = 10;
        let mut big = Fmac::new();
        big.counts[20] = 1_000_000;
        let comb = Fmac::combine_normalized(&[&small, &big]);
        assert!((comb[10] - 1.0).abs() < 1e-12);
        assert!((comb[20] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dynamic_range_over_nonzero() {
        let mut f = Fmac::new();
        f.counts[16] = 1_000_000;
        f.counts[2] = 10;
        assert_eq!(f.dynamic_range(), 100_000.0);
    }
}
