//! CapMin-V — variation-tolerant spike-time sets (paper Alg. 1).
//!
//! Starting from CapMin's S_FIRE,min and its Monte-Carlo P_map, repeat phi
//! times: find the spike time with the smallest diagonal probability
//! (most error-prone), merge it into whichever neighbour has the *smaller*
//! diagonal (boundary rows merge inward; ties arbitrary), i.e. add its
//! column into the neighbour's and drop its row and column. Each merge
//! widens the surviving spike time's decision interval, raising its
//! diagonal probability at the cost of one representable level.

use crate::analog::pmap::Pmap;

#[derive(Clone, Debug)]
pub struct CapMinVResult {
    /// Surviving levels (spike times) after phi merges, ascending.
    pub levels: Vec<usize>,
    /// The merged (k - phi)^2 matrix, padded use via `Pmap::pad_to_full`.
    pub pmap: Pmap,
    /// Merge log: (removed_level, absorbed_into_level) per step.
    pub merges: Vec<(usize, usize)>,
}

/// Alg. 1. `pmap` is CapMin's k x k matrix; `phi` the number of merges.
pub fn capmin_v(mut pmap: Pmap, phi: usize) -> CapMinVResult {
    assert!(phi < pmap.k(), "phi must leave at least one spike time");
    let mut merges = vec![];
    for _ in 0..phi {
        let j = pmap.argmin_diag();
        let k = pmap.k();
        // out-of-bound cases merge inward (Alg. 1 line 5)
        let dst = if j == 0 {
            1
        } else if j == k - 1 {
            k - 2
        } else if pmap.p[j - 1][j - 1] < pmap.p[j + 1][j + 1] {
            // left neighbour weaker -> left merge (Alg. 1 lines 6-8)
            j - 1
        } else {
            j + 1
        };
        merges.push((pmap.levels[j], pmap.levels[dst]));
        pmap.merge_into(j, dst);
    }
    CapMinVResult {
        levels: pmap.levels.clone(),
        pmap,
        merges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::capacitor::{CapacitorModel, CapacitorSolver};
    use crate::analog::montecarlo::MonteCarlo;
    use crate::analog::neuron::SpikeTimeSet;
    use crate::analog::params::AnalogParams;
    use crate::util::rng::Rng;

    fn mc_pmap(sigma: f64, lo: usize, hi: usize) -> (Pmap, SpikeTimeSet) {
        let p = AnalogParams::paper_calibrated().with_sigma(sigma);
        let c = CapacitorSolver::new(p, CapacitorModel::Physics)
            .size_for_window(lo, hi);
        let set = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
        let pm = MonteCarlo::new(p).pmap(&set, &mut Rng::new(42));
        (pm, set)
    }

    #[test]
    fn merges_reduce_k_by_phi() {
        let (pm, _) = mc_pmap(0.03, 9, 24);
        let k0 = pm.k();
        let r = capmin_v(pm, 4);
        assert_eq!(r.levels.len(), k0 - 4);
        assert_eq!(r.merges.len(), 4);
    }

    #[test]
    fn min_diagonal_improves() {
        let (pm, _) = mc_pmap(0.04, 9, 24);
        let before = pm
            .diag()
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        let r = capmin_v(pm, 5);
        let after = r
            .pmap
            .diag()
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(
            after >= before,
            "worst-case diagonal must not degrade: {before} -> {after}"
        );
    }

    #[test]
    fn rows_stay_stochastic_through_merges() {
        let (pm, _) = mc_pmap(0.05, 10, 23);
        let r = capmin_v(pm, 6);
        for s in r.pmap.row_sums() {
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn removes_mostly_fast_levels_first() {
        // with current-proportional variation the fast (high) side of the
        // window is least tolerant; clock-quantization phase effects can
        // perturb individual picks, but the removed levels should sit in
        // the upper half of the window on average
        let (pm, _) = mc_pmap(0.04, 9, 24);
        let r = capmin_v(pm, 4);
        let mean_removed: f64 = r
            .merges
            .iter()
            .map(|&(rm, _)| rm as f64)
            .sum::<f64>()
            / r.merges.len() as f64;
        assert!(
            mean_removed > 16.5,
            "removed levels should skew fast: mean {mean_removed}, \
             merges {:?}",
            r.merges
        );
    }

    #[test]
    fn identity_pmap_merges_boundary_inward() {
        let pm = Pmap::identity((10..=15).collect());
        // all diagonals equal 1.0 -> argmin is index 0 -> inward merge
        let r = capmin_v(pm, 1);
        assert_eq!(r.merges[0], (10, 11));
        assert_eq!(r.levels, vec![11, 12, 13, 14, 15]);
    }

    #[test]
    #[should_panic]
    fn phi_bounded_by_k() {
        let pm = Pmap::identity((10..=12).collect());
        capmin_v(pm, 3);
    }
}
