//! CapMin — capacitor size minimization from MAC-level statistics
//! (paper Sec. III-A).
//!
//! CapMin keeps only the k most frequently occurring MAC levels in
//! S_MAC,min; all other levels are clipped to the nearest kept level
//! (Eq. 4). Because the F_MAC histograms are unimodal (Fig. 1), the top-k
//! levels form a contiguous window; we make that explicit by selecting
//! the contiguous width-k window of *spike-time-bearing* levels (1..=32,
//! level 0 needs no spike time) with maximum covered frequency — identical
//! to top-k for unimodal inputs and well-defined for any input.

use super::{Fmac, N_LEVELS};

#[derive(Clone, Debug, PartialEq)]
pub struct CapMinResult {
    /// Number of spike times kept (the paper's k).
    pub k: usize,
    /// Smallest kept level (q_first in Eq. 4).
    pub q_lo: usize,
    /// Largest kept level (q_last in Eq. 4).
    pub q_hi: usize,
    /// Fraction of all sub-MAC occurrences inside the window.
    pub coverage: f64,
}

impl CapMinResult {
    /// Eq. (4): clip a level into the kept window.
    pub fn clip(&self, m: usize) -> usize {
        m.clamp(self.q_lo, self.q_hi)
    }

    pub fn levels(&self) -> Vec<usize> {
        (self.q_lo..=self.q_hi).collect()
    }
}

/// Select the k-level window over levels 1..=32 maximizing covered AFO.
/// Ties resolve to the lowest window (slower spike times are both cheaper
/// and more variation-tolerant — paper Sec. IV-C).
pub fn select_window(fmac: &Fmac, k: usize) -> CapMinResult {
    select_window_pmf(&fmac.pmf(), k)
}

/// Same, over an already-normalized (or combined) frequency vector.
pub fn select_window_pmf(pmf: &[f64; N_LEVELS], k: usize) -> CapMinResult {
    assert!(k >= 1 && k <= N_LEVELS - 1, "k in 1..=32");
    let total: f64 = pmf.iter().sum();
    let mut best_lo = 1usize;
    let mut best_cov = -1.0f64;
    for lo in 1..=(N_LEVELS - k) {
        let hi = lo + k - 1;
        // coverage counts only exactly-represented levels; clipped levels
        // (outside the window) are what accuracy degradation comes from
        let cov: f64 = pmf[lo..=hi].iter().sum();
        if cov > best_cov + 1e-15 {
            best_cov = cov;
            best_lo = lo;
        }
    }
    CapMinResult {
        k,
        q_lo: best_lo,
        q_hi: best_lo + k - 1,
        coverage: if total > 0.0 { best_cov / total } else { 0.0 },
    }
}

/// The k-sweep the paper's Fig. 8 walks (k = 32 down to 5).
pub fn sweep(fmac: &Fmac, ks: &[usize]) -> Vec<CapMinResult> {
    ks.iter().map(|&k| select_window(fmac, k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_fmac(peak: usize, sharp: f64) -> Fmac {
        let mut f = Fmac::new();
        for m in 0..N_LEVELS {
            let d = m as f64 - peak as f64;
            f.counts[m] = (1e9 * (-d * d / (2.0 * sharp * sharp)).exp())
                as u64;
        }
        f
    }

    #[test]
    fn baseline_k32_keeps_all_spike_levels() {
        let f = gaussian_fmac(16, 3.0);
        let r = select_window(&f, 32);
        assert_eq!((r.q_lo, r.q_hi), (1, 32));
    }

    #[test]
    fn window_centers_on_peak() {
        let f = gaussian_fmac(16, 3.0);
        let r = select_window(&f, 14);
        assert!(r.q_lo <= 16 && 16 <= r.q_hi, "{r:?}");
        assert!((r.q_hi - r.q_lo + 1) == 14);
        // symmetric-ish around the peak
        assert!((16 - r.q_lo).abs_diff(r.q_hi - 16) <= 1, "{r:?}");
    }

    #[test]
    fn coverage_monotone_in_k() {
        let f = gaussian_fmac(14, 4.0);
        let mut prev = 0.0;
        for k in [5, 8, 12, 16, 24, 32] {
            let r = select_window(&f, k);
            assert!(r.coverage >= prev - 1e-12, "k={k}");
            prev = r.coverage;
        }
        assert!(select_window(&f, 32).coverage > 0.999);
    }

    #[test]
    fn clip_is_eq4() {
        let r = CapMinResult {
            k: 14,
            q_lo: 10,
            q_hi: 23,
            coverage: 0.99,
        };
        assert_eq!(r.clip(5), 10);
        assert_eq!(r.clip(16), 16);
        assert_eq!(r.clip(30), 23);
    }

    #[test]
    fn skewed_histogram_shifts_window() {
        let f = gaussian_fmac(10, 2.0);
        let r = select_window(&f, 8);
        assert!(r.q_lo <= 10 && 10 <= r.q_hi);
        assert!(r.q_hi < 20, "window follows the skewed peak: {r:?}");
    }

    #[test]
    fn ties_pick_lowest_window() {
        // uniform histogram: every window covers the same mass
        let mut f = Fmac::new();
        for m in 1..N_LEVELS {
            f.counts[m] = 100;
        }
        let r = select_window(&f, 10);
        assert_eq!(r.q_lo, 1, "lowest window on ties: {r:?}");
    }
}
