//! Synthetic dataset substrate (Table I stand-ins; DESIGN.md §6).
//!
//! No dataset downloads exist in this environment, and CapMin consumes
//! only the MAC-level statistics of a trained BNN — a property of
//! binarized dot products, not of specific images (the paper's own Fig. 1
//! shows all five benchmarks produce near-identical histograms). Each
//! generator is a procedural, deterministic, class-conditional +-1 image
//! source with a difficulty knob chosen so the models train to accuracies
//! in the same band the paper reports.

pub mod loader;
pub mod synth;

pub use loader::{Batch, Loader, Split};
pub use synth::{Dataset, DatasetSpec};
