//! Procedural class-conditional +-1 image generators — one per paper
//! benchmark (Table I).
//!
//! Common construction: each (dataset, class) owns a fixed coarse +-1
//! template (drawn once from a class-seeded stream); a sample is the
//! template upsampled to the target resolution, randomly translated,
//! with per-pixel sign-flip noise. Per-dataset parameters (template
//! resolution, flip probability, jitter, channel coupling) give the five
//! benchmarks distinct difficulty, mirroring the easy->hard spread of
//! FashionMNIST -> Imagenette.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    FashionSyn,
    KmnistSyn,
    SvhnSyn,
    CifarSyn,
    ImagenetteSyn,
}

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Model key in the AOT manifest.
    pub model: &'static str,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    /// Coarse template grid (template is `grid x grid`).
    grid: usize,
    /// Per-pixel sign-flip probability (difficulty).
    flip_p: f64,
    /// Max |translation| in pixels.
    jitter: i64,
    /// Paper dataset this stands in for.
    pub paper_name: &'static str,
    /// Base seed decorrelating datasets.
    seed: u64,
}

impl Dataset {
    pub fn all() -> [Dataset; 5] {
        [
            Dataset::FashionSyn,
            Dataset::KmnistSyn,
            Dataset::SvhnSyn,
            Dataset::CifarSyn,
            Dataset::ImagenetteSyn,
        ]
    }

    pub fn from_name(name: &str) -> Option<Dataset> {
        Dataset::all()
            .into_iter()
            .find(|d| d.spec().name == name)
    }

    pub fn spec(&self) -> DatasetSpec {
        match self {
            Dataset::FashionSyn => DatasetSpec {
                name: "fashion_syn",
                model: "vgg3",
                channels: 1,
                height: 28,
                width: 28,
                classes: 10,
                n_train: 60000,
                n_test: 10000,
                grid: 7,
                flip_p: 0.08,
                jitter: 2,
                paper_name: "FashionMNIST",
                seed: 0xFA51_0001,
            },
            Dataset::KmnistSyn => DatasetSpec {
                name: "kmnist_syn",
                model: "vgg3",
                channels: 1,
                height: 28,
                width: 28,
                classes: 10,
                n_train: 60000,
                n_test: 10000,
                grid: 9,
                flip_p: 0.12,
                jitter: 2,
                paper_name: "KuzushijiMNIST",
                seed: 0x4B4D_0002,
            },
            Dataset::SvhnSyn => DatasetSpec {
                name: "svhn_syn",
                model: "vgg7",
                channels: 3,
                height: 32,
                width: 32,
                classes: 10,
                n_train: 73257,
                n_test: 26032,
                grid: 8,
                flip_p: 0.15,
                jitter: 3,
                paper_name: "SVHN",
                seed: 0x5348_0003,
            },
            Dataset::CifarSyn => DatasetSpec {
                name: "cifar_syn",
                model: "vgg7",
                channels: 3,
                height: 32,
                width: 32,
                classes: 10,
                n_train: 50000,
                n_test: 10000,
                grid: 8,
                flip_p: 0.18,
                jitter: 3,
                paper_name: "CIFAR10",
                seed: 0xC1FA_0004,
            },
            Dataset::ImagenetteSyn => DatasetSpec {
                name: "imagenette_syn",
                model: "resnet18",
                channels: 3,
                height: 64,
                width: 64,
                classes: 10,
                n_train: 9470,
                n_test: 3925,
                grid: 8,
                flip_p: 0.15,
                jitter: 4,
                paper_name: "Imagenette",
                seed: 0x1433_0005,
            },
        }
    }
}

impl DatasetSpec {
    pub fn pixels(&self) -> usize {
        self.channels * self.height * self.width
    }

    /// Fixed +-1 template of one class (per channel).
    fn template(&self, class: usize) -> Vec<f32> {
        let mut rng =
            Rng::new(self.seed ^ (class as u64).wrapping_mul(0x9E37));
        let g = self.grid;
        let mut t = vec![-1.0f32; self.channels * g * g];
        // structured template: a few random filled rectangles per channel
        // (gives spatial correlation, unlike iid noise)
        for ch in 0..self.channels {
            let base = ch * g * g;
            // channel coupling: channel 0 pattern reused with flips for
            // RGB sets so color carries class signal too
            let n_rects = 2 + rng.below(3) as usize;
            for _ in 0..n_rects {
                let r0 = rng.below(g as u64) as usize;
                let c0 = rng.below(g as u64) as usize;
                let rh = 1 + rng.below((g - r0) as u64) as usize;
                let rw = 1 + rng.below((g - c0) as u64) as usize;
                for r in r0..(r0 + rh).min(g) {
                    for c in c0..(c0 + rw).min(g) {
                        t[base + r * g + c] = 1.0;
                    }
                }
            }
        }
        t
    }

    /// Deterministic sample `idx` of `split`: (pixels CHW +-1, label).
    pub fn sample(&self, split: Split, idx: usize) -> (Vec<f32>, usize) {
        let split_salt = match split {
            Split::Train => 0x7121u64,
            Split::Test => 0x7E57u64,
        };
        let mut rng = Rng::new(
            self.seed
                ^ split_salt.wrapping_mul(0x2545_F491_4F6C_DD1D)
                ^ (idx as u64).wrapping_mul(0x1000_0000_1B3),
        );
        let class = rng.below(self.classes as u64) as usize;
        let t = self.template(class);
        let g = self.grid;
        let (h, w) = (self.height, self.width);
        let (dy, dx) = (
            rng.range_i64(-self.jitter, self.jitter),
            rng.range_i64(-self.jitter, self.jitter),
        );
        let mut px = vec![-1.0f32; self.pixels()];
        let sy = h as f64 / g as f64;
        let sx = w as f64 / g as f64;
        for ch in 0..self.channels {
            for r in 0..h {
                for c in 0..w {
                    let tr = ((r as i64 - dy).clamp(0, h as i64 - 1) as f64
                        / sy) as usize;
                    let tc = ((c as i64 - dx).clamp(0, w as i64 - 1) as f64
                        / sx) as usize;
                    let mut v =
                        t[ch * g * g + tr.min(g - 1) * g + tc.min(g - 1)];
                    if rng.f64() < self.flip_p {
                        v = -v;
                    }
                    px[ch * h * w + r * w + c] = v;
                }
            }
        }
        (px, class)
    }
}

pub use super::loader::Split;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        for ds in Dataset::all() {
            let spec = ds.spec();
            let (a, la) = spec.sample(Split::Train, 17);
            let (b, lb) = spec.sample(Split::Train, 17);
            assert_eq!(a, b);
            assert_eq!(la, lb);
        }
    }

    #[test]
    fn splits_differ() {
        let spec = Dataset::FashionSyn.spec();
        let (a, _) = spec.sample(Split::Train, 3);
        let (b, _) = spec.sample(Split::Test, 3);
        assert_ne!(a, b);
    }

    #[test]
    fn values_are_pm_one_and_shape_correct() {
        for ds in Dataset::all() {
            let spec = ds.spec();
            let (px, label) = spec.sample(Split::Test, 0);
            assert_eq!(px.len(), spec.pixels());
            assert!(px.iter().all(|&v| v == 1.0 || v == -1.0));
            assert!(label < spec.classes);
        }
    }

    #[test]
    fn classes_roughly_balanced() {
        let spec = Dataset::CifarSyn.spec();
        let mut counts = [0usize; 10];
        for i in 0..2000 {
            counts[spec.sample(Split::Train, i).1] += 1;
        }
        for &c in &counts {
            assert!(c > 120 && c < 280, "{counts:?}");
        }
    }

    #[test]
    fn same_class_samples_correlate_more_than_cross_class() {
        let spec = Dataset::FashionSyn.spec();
        let mut by_class: Vec<Vec<Vec<f32>>> = vec![vec![]; 10];
        let mut i = 0;
        while by_class.iter().filter(|v| v.len() >= 2).count() < 10 {
            let (px, c) = spec.sample(Split::Train, i);
            by_class[c].push(px);
            i += 1;
        }
        let corr = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>()
                / a.len() as f32
        };
        let mut same = 0.0;
        for v in &by_class {
            same += corr(&v[0], &v[1]);
        }
        same /= 10.0;
        let mut cross = 0.0;
        for c in 0..10 {
            cross += corr(&by_class[c][0], &by_class[(c + 1) % 10][0]);
        }
        cross /= 10.0;
        assert!(
            same > cross + 0.1,
            "class signal too weak: same {same} cross {cross}"
        );
    }

    #[test]
    fn from_name_roundtrip() {
        for ds in Dataset::all() {
            assert_eq!(Dataset::from_name(ds.spec().name), Some(ds));
        }
        assert_eq!(Dataset::from_name("nope"), None);
    }
}
