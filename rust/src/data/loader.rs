//! Batching pipeline: shuffled train batches, sequential eval batches,
//! targets in both one-hot +-1 (MHL) and index form.

use super::synth::DatasetSpec;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// One host-side batch, ready to become PJRT literals.
#[derive(Clone, Debug)]
pub struct Batch {
    /// NCHW pixels, +-1.
    pub x: Vec<f32>,
    /// One-hot +-1 targets [n x classes] (MHL form).
    pub y_pm: Vec<f32>,
    /// Class indices.
    pub labels: Vec<usize>,
    pub n: usize,
}

pub struct Loader {
    pub spec: DatasetSpec,
    pub split: Split,
    pub batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    epoch: usize,
    /// Cap on the split size (CPU-budget subsets; 0 = full split).
    pub limit: usize,
}

impl Loader {
    pub fn new(
        spec: DatasetSpec,
        split: Split,
        batch: usize,
        limit: usize,
        seed: u64,
    ) -> Loader {
        let full = match split {
            Split::Train => spec.n_train,
            Split::Test => spec.n_test,
        };
        let n = if limit == 0 { full } else { limit.min(full) };
        let mut l = Loader {
            spec,
            split,
            batch,
            order: (0..n).collect(),
            cursor: 0,
            rng: Rng::new(seed),
            epoch: 0,
            limit: n,
        };
        if split == Split::Train {
            let mut rng = l.rng.split(0);
            rng.shuffle(&mut l.order);
        }
        l
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn n_batches_per_epoch(&self) -> usize {
        self.len() / self.batch
    }

    /// Next batch; reshuffles per epoch on the train split, wraps on test.
    pub fn next_batch(&mut self) -> Batch {
        let b = self.batch;
        let cls = self.spec.classes;
        let px = self.spec.pixels();
        let mut x = Vec::with_capacity(b * px);
        let mut y_pm = vec![-1.0f32; b * cls];
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.epoch += 1;
                if self.split == Split::Train {
                    let mut rng = self.rng.split(self.epoch as u64);
                    rng.shuffle(&mut self.order);
                }
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            let (pix, label) = self.spec.sample(self.split, idx);
            x.extend_from_slice(&pix);
            y_pm[i * cls + label] = 1.0;
            labels.push(label);
        }
        Batch {
            x,
            y_pm,
            labels,
            n: b,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Dataset;

    #[test]
    fn batches_have_right_shapes() {
        let spec = Dataset::FashionSyn.spec();
        let mut l = Loader::new(spec.clone(), Split::Train, 8, 100, 1);
        let b = l.next_batch();
        assert_eq!(b.x.len(), 8 * spec.pixels());
        assert_eq!(b.y_pm.len(), 8 * 10);
        assert_eq!(b.labels.len(), 8);
        for (i, &label) in b.labels.iter().enumerate() {
            assert_eq!(b.y_pm[i * 10 + label], 1.0);
            let ones = b.y_pm[i * 10..(i + 1) * 10]
                .iter()
                .filter(|&&v| v == 1.0)
                .count();
            assert_eq!(ones, 1);
        }
    }

    #[test]
    fn limit_caps_split() {
        let spec = Dataset::FashionSyn.spec();
        let l = Loader::new(spec, Split::Test, 4, 32, 1);
        assert_eq!(l.len(), 32);
        assert_eq!(l.n_batches_per_epoch(), 8);
    }

    #[test]
    fn train_epochs_reshuffle_test_wraps_stably() {
        let spec = Dataset::FashionSyn.spec();
        let mut tr = Loader::new(spec.clone(), Split::Train, 16, 32, 7);
        let e0: Vec<usize> =
            (0..2).flat_map(|_| tr.next_batch().labels).collect();
        let e1: Vec<usize> =
            (0..2).flat_map(|_| tr.next_batch().labels).collect();
        assert_ne!(e0, e1, "train epochs should reshuffle");
        let mut te = Loader::new(spec, Split::Test, 16, 32, 7);
        let t0: Vec<usize> =
            (0..2).flat_map(|_| te.next_batch().labels).collect();
        let t1: Vec<usize> =
            (0..2).flat_map(|_| te.next_batch().labels).collect();
        assert_eq!(t0, t1, "test order must be stable across wraps");
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = Dataset::CifarSyn.spec();
        let mut a = Loader::new(spec.clone(), Split::Train, 8, 64, 3);
        let mut b = Loader::new(spec, Split::Train, 8, 64, 3);
        assert_eq!(a.next_batch().x, b.next_batch().x);
    }
}
