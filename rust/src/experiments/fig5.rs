//! Fig. 5 — role of the inclusion parameter k: window borders over the
//! (combined) F_MAC histogram.

use anyhow::Result;

use crate::capmin::capmin::select_window_pmf;
use crate::capmin::Fmac;
use crate::session::DesignSession;
use crate::util::table::Table;

pub fn run(session: &DesignSession,
           datasets: &[crate::data::synth::Dataset]) -> Result<()> {
    // the paper normalizes and sums F_MAC across benchmarks (Sec. IV-B)
    let mut fmacs = vec![];
    for &ds in datasets {
        fmacs.push(session.fmac(ds)?.1);
    }
    let refs: Vec<&Fmac> = fmacs.iter().collect();
    let combined = Fmac::combine_normalized(&refs);

    println!("== Fig. 5: CapMin borders over the combined histogram ==");
    let mut t = Table::new(&[
        "k", "q_first", "q_last", "coverage", "clipped mass",
    ]);
    for k in [32, 24, 16, 14, 12, 8, 5] {
        let w = select_window_pmf(&combined, k);
        t.row(vec![
            k.to_string(),
            w.q_lo.to_string(),
            w.q_hi.to_string(),
            format!("{:.5}", w.coverage),
            format!("{:.2e}", 1.0 - w.coverage),
        ]);
    }
    println!("{}", t.render());
    println!(
        "(all levels inside the borders get a unique spike time; mass \
         outside is clipped per Eq. 4)"
    );
    Ok(())
}
