//! Fig. 5 — role of the inclusion parameter k: window borders over the
//! (combined) F_MAC histogram. Empty grid: windows are re-selected on
//! the combined histogram, not on per-matmul operating points.

use std::sync::Arc;

use anyhow::Result;

use crate::capmin::capmin::select_window_pmf;
use crate::capmin::Fmac;
use crate::coordinator::config::ExperimentConfig;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::table::Table;

pub struct Fig5Plan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for Fig5Plan {
    fn name(&self) -> &'static str {
        "fig5"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Fig. 5: CapMin borders over the combined histogram".into()
    }

    fn specs(&self, _cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        vec![]
    }

    fn reduce(
        &self,
        session: &DesignSession,
        _points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        // the paper normalizes and sums F_MAC across benchmarks
        // (Sec. IV-B)
        let mut fmacs = vec![];
        for &ds in &self.datasets {
            fmacs.push(session.fmac(ds)?.1);
        }
        let refs: Vec<&Fmac> = fmacs.iter().collect();
        let combined = Fmac::combine_normalized(&refs);

        let mut rep = Report::new(self.name(), &self.title());
        let mut t = Table::new(&[
            "k", "q_first", "q_last", "coverage", "clipped mass",
        ]);
        for k in [32, 24, 16, 14, 12, 8, 5] {
            let w = select_window_pmf(&combined, k);
            t.row(vec![
                k.to_string(),
                w.q_lo.to_string(),
                w.q_hi.to_string(),
                format!("{:.5}", w.coverage),
                format!("{:.2e}", 1.0 - w.coverage),
            ]);
        }
        rep.table("", t);
        rep.text(
            "(all levels inside the borders get a unique spike time; \
             mass outside is clipped per Eq. 4)",
        );
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &Fig5Plan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}
