//! Fig. 8 — accuracy over k for each benchmark, three curves:
//!   circles:   CapMin clipping, no variation
//!   stars:     CapMin under current variation (mean of n_seeds runs)
//!   triangles: CapMin-V (merges from the k=16 set) under variation
//!
//! The whole sweep is one `query_many` batch: the session solves the
//! cache-missing operating points in parallel (the MC stage dominates)
//! and replays repeated invocations from `runs/points/`.

use anyhow::Result;

use crate::coordinator::report::{pct, Report};
use crate::session::{DesignSession, OperatingPointSpec};
use crate::util::json::Json;
use crate::util::table::Table;

pub const CAPMINV_K_START: usize = 16; // paper Sec. IV-C

pub fn run(session: &DesignSession,
           datasets: &[crate::data::synth::Dataset]) -> Result<()> {
    let cfg = session.config();
    for &ds in datasets {
        let spec = ds.spec();
        // train/extract up front so the sweep below is pure query traffic
        session.ensure_trained(ds)?;
        println!(
            "\n== Fig. 8 [{}]: accuracy over k (sigma_rel = {}, {} \
             test samples, backend = {}) ==",
            spec.name,
            cfg.sigma_rel,
            cfg.eval_limit,
            session.backend_name()
        );
        // one spec per curve point, k-major so the result walk below
        // stays aligned
        let mut specs = vec![];
        for &k in &cfg.ks {
            // circles: clipping only
            specs.push(
                OperatingPointSpec::new(ds, k, 0.0, 0).with_eval(1, 1),
            );
            // stars: clipping + variation
            specs.push(
                OperatingPointSpec::new(ds, k, cfg.sigma_rel, 0)
                    .with_eval(100, cfg.n_seeds),
            );
            // triangles: CapMin-V from k=16 merged down to k spike times
            if k < CAPMINV_K_START {
                specs.push(
                    OperatingPointSpec::new(
                        ds,
                        CAPMINV_K_START,
                        cfg.sigma_rel,
                        CAPMINV_K_START - k,
                    )
                    .with_eval(200, cfg.n_seeds),
                );
            }
        }
        let points = session.query_many(&specs)?;

        let mut t = Table::new(&[
            "k", "window", "CapMin clean", "CapMin +var", "CapMin-V +var",
        ]);
        let mut ks = vec![];
        let mut clean = vec![];
        let mut var = vec![];
        let mut capv: Vec<f64> = vec![];
        let mut it = points.iter();
        for &k in &cfg.ks {
            let p_clean = it.next().expect("clean point per k");
            let p_var = it.next().expect("variation point per k");
            let a_clean = p_clean.accuracy.expect("eval requested");
            let a_var = p_var.accuracy.expect("eval requested");
            let a_capv = if k < CAPMINV_K_START {
                let p_v = it.next().expect("capmin-v point below k=16");
                Some(p_v.accuracy.expect("eval requested"))
            } else {
                None
            };
            let w = p_clean.peak_window();
            t.row(vec![
                k.to_string(),
                format!("[{},{}]", w.q_lo, w.q_hi),
                pct(a_clean),
                pct(a_var),
                a_capv.map(pct).unwrap_or_else(|| "-".into()),
            ]);
            ks.push(k as f64);
            clean.push(a_clean);
            var.push(a_var);
            capv.push(a_capv.unwrap_or(f64::NAN));
        }
        println!("{}", t.render());
        let rep = Report::new(session.store());
        rep.save_series(
            &format!("fig8_{}", spec.name),
            vec![
                ("dataset", Json::Str(spec.name.into())),
                ("sigma_rel", Json::Num(cfg.sigma_rel)),
                ("eval_limit", Json::Num(cfg.eval_limit as f64)),
            ],
            vec![
                ("k", ks),
                ("capmin_clean", clean),
                ("capmin_var", var),
                ("capminv_var", capv),
            ],
        )?;
    }
    Ok(())
}

/// Smallest k whose clean accuracy stays within `tol` of the k=32 clean
/// accuracy (the paper's "1% accepted degradation" operating point).
pub fn choose_k(ks: &[usize], clean: &[f64], tol: f64) -> usize {
    let base = clean
        .iter()
        .zip(ks)
        .find(|&(_, &k)| k == 32)
        .map(|(&a, _)| a)
        .unwrap_or(clean[0]);
    let mut best = ks[0];
    for (&k, &a) in ks.iter().zip(clean) {
        if a >= base - tol && k < best {
            best = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::choose_k;

    #[test]
    fn choose_k_respects_tolerance() {
        let ks = [32, 24, 16, 14, 10, 6];
        let clean = [0.90, 0.90, 0.895, 0.893, 0.85, 0.60];
        assert_eq!(choose_k(&ks, &clean, 0.01), 14);
        assert_eq!(choose_k(&ks, &clean, 0.06), 10);
        assert_eq!(choose_k(&ks, &clean, 0.0005), 24);
    }
}
