//! Fig. 8 — accuracy over k for each benchmark, three curves:
//!   circles:   CapMin clipping, no variation
//!   stars:     CapMin under current variation (mean of n_seeds runs)
//!   triangles: CapMin-V (merges from the k=16 set) under variation
//!
//! As a plan, the whole sweep is *declared*: [`sweep_specs`] is the
//! grid (k-major per dataset), the planner resolves it — deduplicated
//! against every other plan in the suite (headline declares the same
//! grid and rides along for free) — and [`Fig8Plan::reduce`] is a pure
//! walk from points to tables and plot series.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::report::pct;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::json::Json;
use crate::util::table::Table;

pub const CAPMINV_K_START: usize = 16; // paper Sec. IV-C

/// The Fig. 8 grid for one dataset list: per dataset, per k — a clean
/// point, a variation point, and (below the CapMin-V start) a merged
/// point. Shared verbatim by the headline plan, so under `suite` the
/// two plans' specs collapse to one solve each.
pub fn sweep_specs(
    cfg: &ExperimentConfig,
    datasets: &[Dataset],
) -> Vec<OperatingPointSpec> {
    let mut specs = vec![];
    for &ds in datasets {
        for &k in &cfg.ks {
            // circles: clipping only
            specs.push(
                OperatingPointSpec::new(ds, k, 0.0, 0).with_eval(1, 1),
            );
            // stars: clipping + variation
            specs.push(
                OperatingPointSpec::new(ds, k, cfg.sigma_rel, 0)
                    .with_eval(100, cfg.n_seeds),
            );
            // triangles: CapMin-V from k=16 merged down to k spike
            // times
            if k < CAPMINV_K_START {
                specs.push(
                    OperatingPointSpec::new(
                        ds,
                        CAPMINV_K_START,
                        cfg.sigma_rel,
                        CAPMINV_K_START - k,
                    )
                    .with_eval(200, cfg.n_seeds),
                );
            }
        }
    }
    specs
}

/// One dataset's decoded sweep: aligned k / accuracy arrays.
pub struct SweepCurves {
    pub ks: Vec<f64>,
    pub clean: Vec<f64>,
    pub var: Vec<f64>,
    /// NaN above the CapMin-V start.
    pub capv: Vec<f64>,
    /// Peak window per k, rendered `[lo,hi]`.
    pub windows: Vec<String>,
}

/// Walk one dataset's block of resolved points (in [`sweep_specs`]
/// order) back into curves.
pub fn decode_sweep<'a>(
    cfg: &ExperimentConfig,
    points: &mut impl Iterator<Item = &'a Arc<OperatingPoint>>,
) -> SweepCurves {
    let mut c = SweepCurves {
        ks: vec![],
        clean: vec![],
        var: vec![],
        capv: vec![],
        windows: vec![],
    };
    for &k in &cfg.ks {
        let p_clean = points.next().expect("clean point per k");
        let p_var = points.next().expect("variation point per k");
        let a_clean = p_clean.accuracy.expect("eval requested");
        let a_var = p_var.accuracy.expect("eval requested");
        let a_capv = if k < CAPMINV_K_START {
            let p_v = points.next().expect("capmin-v point below k=16");
            p_v.accuracy.expect("eval requested")
        } else {
            f64::NAN
        };
        let w = p_clean.peak_window();
        c.ks.push(k as f64);
        c.clean.push(a_clean);
        c.var.push(a_var);
        c.capv.push(a_capv);
        c.windows.push(format!("[{},{}]", w.q_lo, w.q_hi));
    }
    c
}

pub struct Fig8Plan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for Fig8Plan {
    fn name(&self) -> &'static str {
        "fig8"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Fig. 8: accuracy over k (CapMin / +variation / CapMin-V)"
            .into()
    }

    fn specs(&self, cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        sweep_specs(cfg, &self.datasets)
    }

    fn reduce(
        &self,
        session: &DesignSession,
        points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let cfg = session.config();
        let mut rep = Report::new(self.name(), &self.title());
        let mut it = points.iter();
        for &ds in &self.datasets {
            let spec = ds.spec();
            rep.heading(format!(
                "{} (sigma_rel = {}, {} test samples, backend = {})",
                spec.name,
                cfg.sigma_rel,
                cfg.eval_limit,
                session.backend_name()
            ));
            let curves = decode_sweep(cfg, &mut it);
            let mut t = Table::new(&[
                "k", "window", "CapMin clean", "CapMin +var",
                "CapMin-V +var",
            ]);
            for (i, &k) in curves.ks.iter().enumerate() {
                t.row(vec![
                    (k as usize).to_string(),
                    curves.windows[i].clone(),
                    pct(curves.clean[i]),
                    pct(curves.var[i]),
                    if curves.capv[i].is_nan() {
                        "-".into()
                    } else {
                        pct(curves.capv[i])
                    },
                ]);
            }
            rep.table("", t);
            rep.series(
                &format!("fig8_{}", spec.name),
                vec![
                    (
                        "dataset".into(),
                        Json::Str(spec.name.into()),
                    ),
                    ("sigma_rel".into(), Json::Num(cfg.sigma_rel)),
                    (
                        "eval_limit".into(),
                        Json::Num(cfg.eval_limit as f64),
                    ),
                ],
                vec![
                    ("k".into(), curves.ks),
                    ("capmin_clean".into(), curves.clean),
                    ("capmin_var".into(), curves.var),
                    ("capminv_var".into(), curves.capv),
                ],
            );
        }
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &Fig8Plan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}

/// Smallest k whose clean accuracy stays within `tol` of the k=32 clean
/// accuracy (the paper's "1% accepted degradation" operating point).
pub fn choose_k(ks: &[usize], clean: &[f64], tol: f64) -> usize {
    let base = clean
        .iter()
        .zip(ks)
        .find(|&(_, &k)| k == 32)
        .map(|(&a, _)| a)
        .unwrap_or(clean[0]);
    let mut best = ks[0];
    for (&k, &a) in ks.iter().zip(clean) {
        if a >= base - tol && k < best {
            best = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_k_respects_tolerance() {
        let ks = [32, 24, 16, 14, 10, 6];
        let clean = [0.90, 0.90, 0.895, 0.893, 0.85, 0.60];
        assert_eq!(choose_k(&ks, &clean, 0.01), 14);
        assert_eq!(choose_k(&ks, &clean, 0.06), 10);
        assert_eq!(choose_k(&ks, &clean, 0.0005), 24);
    }

    #[test]
    fn sweep_grid_shape() {
        let mut cfg = ExperimentConfig::default();
        cfg.ks = vec![32, 16, 14, 10];
        let specs =
            sweep_specs(&cfg, &[Dataset::FashionSyn, Dataset::CifarSyn]);
        // per dataset: 4 clean + 4 var + 2 capmin-v (k = 14, 10)
        assert_eq!(specs.len(), 2 * (4 + 4 + 2));
        // k-major: first three entries belong to k = 32, 32, 16...
        assert_eq!(specs[0].k, 32);
        assert!(specs[0].eval.is_some());
        assert_eq!(specs[1].sigma, cfg.sigma_rel);
    }
}
