//! Fig. 3 — capacitor voltage over time for different initial currents,
//! with clock-quantized spike times. Pure analog-substrate work: the
//! plan declares an empty grid and reduces straight from the session's
//! calibrated parameters.

use std::sync::Arc;

use anyhow::Result;

use crate::analog::{clock, rc};
use crate::coordinator::config::ExperimentConfig;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::json::Json;
use crate::util::table::{si, Table};

pub struct Fig3Plan;

impl ExperimentPlan for Fig3Plan {
    fn name(&self) -> &'static str {
        "fig3"
    }

    fn title(&self) -> String {
        format!(
            "Fig. 3: V(t) for different I_init (C = {})",
            si(crate::analog::params::PAPER_BASELINE_C, "F")
        )
    }

    fn specs(&self, _cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        vec![]
    }

    fn reduce(
        &self,
        session: &DesignSession,
        _points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let p = session.params();
        let c = crate::analog::params::PAPER_BASELINE_C;
        let mut rep = Report::new(self.name(), &self.title());
        let levels = [32usize, 24, 16, 8, 4, 1];
        let mut t = Table::new(&[
            "level M", "I_init", "ideal t_fire", "clock slot",
            "quantized",
        ]);
        for &m in &levels {
            let i = rc::level_current(&p, m);
            let tf = rc::level_spike_time(&p, c, m);
            t.row(vec![
                m.to_string(),
                si(i, "A"),
                si(tf, "s"),
                clock::slot(&p, tf).to_string(),
                si(clock::quantize(&p, tf), "s"),
            ]);
        }
        rep.table("", t);

        // curve data for the highest/lowest current (plotting series)
        for &m in &[32usize, 8, 1] {
            let i = rc::level_current(&p, m);
            let t_end = 2.0 * rc::level_spike_time(&p, c, m.max(1));
            let curve =
                rc::charging_curve(&p, c, i, t_end.min(2e-6), 200);
            rep.series(
                &format!("fig3_level{m}"),
                vec![("level".into(), Json::Num(m as f64))],
                vec![
                    (
                        "t".into(),
                        curve.iter().map(|&(t, _)| t).collect(),
                    ),
                    (
                        "v".into(),
                        curve.iter().map(|&(_, v)| v).collect(),
                    ),
                ],
            );
        }
        rep.text(format!(
            "(series saved to runs/results_fig3_level*.json; Vth = {} \
             V)",
            p.vth
        ));
        Ok(rep)
    }
}

pub fn run(session: &DesignSession) -> Result<()> {
    crate::plan::planner::run_one(session, &Fig3Plan, &[])
}
