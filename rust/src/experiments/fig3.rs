//! Fig. 3 — capacitor voltage over time for different initial currents,
//! with clock-quantized spike times.

use anyhow::Result;

use crate::analog::{clock, rc};
use crate::coordinator::report::Report;
use crate::session::DesignSession;
use crate::util::json::Json;
use crate::util::table::{si, Table};

pub fn run(session: &DesignSession) -> Result<()> {
    let p = session.params();
    let c = crate::analog::params::PAPER_BASELINE_C;
    println!("== Fig. 3: V(t) for different I_init (C = {}) ==",
             si(c, "F"));
    let levels = [32usize, 24, 16, 8, 4, 1];
    let mut t = Table::new(&[
        "level M", "I_init", "ideal t_fire", "clock slot", "quantized",
    ]);
    for &m in &levels {
        let i = rc::level_current(&p, m);
        let tf = rc::level_spike_time(&p, c, m);
        t.row(vec![
            m.to_string(),
            si(i, "A"),
            si(tf, "s"),
            clock::slot(&p, tf).to_string(),
            si(clock::quantize(&p, tf), "s"),
        ]);
    }
    println!("{}", t.render());

    // curve data for the highest/lowest current (plotting series)
    let rep = Report::new(session.store());
    for &m in &[32usize, 8, 1] {
        let i = rc::level_current(&p, m);
        let t_end = 2.0 * rc::level_spike_time(&p, c, m.max(1));
        let curve = rc::charging_curve(&p, c, i, t_end.min(2e-6), 200);
        rep.save_series(
            &format!("fig3_level{m}"),
            vec![("level", Json::Num(m as f64))],
            vec![
                ("t", curve.iter().map(|&(t, _)| t).collect()),
                ("v", curve.iter().map(|&(_, v)| v).collect()),
            ],
        )?;
    }
    println!("(series saved to runs/results_fig3_level*.json; Vth = {} V)",
             p.vth);
    Ok(())
}
