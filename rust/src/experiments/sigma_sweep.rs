//! Extension experiment (paper future-work direction): variation
//! tolerance curve — accuracy vs sigma_rel at a fixed operating point,
//! CapMin (k = 14) vs CapMin-V (k = 16 capacitor, phi = 2). Quantifies
//! *how much* process variation each configuration absorbs, beyond the
//! single-sigma snapshot of Fig. 8.

use anyhow::Result;

use crate::coordinator::pipeline::Pipeline;
use crate::coordinator::report::{pct, Report};
use crate::util::json::Json;
use crate::util::table::Table;

pub fn run(pipe: &Pipeline, datasets: &[crate::data::synth::Dataset])
    -> Result<()> {
    let cfg = &pipe.cfg;
    let ev = pipe.evaluator();
    let sigmas = [0.0, 0.01, 0.02, 0.04, 0.06, 0.08];
    for &ds in datasets {
        let spec = ds.spec();
        let folded = pipe.ensure_folded(ds)?;
        let (per_fmac, _) = pipe.ensure_fmac(ds)?;
        println!(
            "\n== sigma sweep [{}]: CapMin(k=14) vs CapMin-V(16, phi=2) ==",
            spec.name
        );
        let mut t = Table::new(&["sigma_rel", "CapMin k=14", "CapMin-V"]);
        let mut xs = vec![];
        let mut a_cm = vec![];
        let mut a_cv = vec![];
        for &sigma in &sigmas {
            let hw = pipe.hw_config(&per_fmac, 14, sigma, 0);
            let a1 = ev.accuracy_multi_seed(
                spec.model, &folded, spec.clone(), &hw.ems,
                cfg.eval_limit, cfg.n_seeds, 300)?;
            let hwv = pipe.hw_config(&per_fmac, 16, sigma, 2);
            let a2 = ev.accuracy_multi_seed(
                spec.model, &folded, spec.clone(), &hwv.ems,
                cfg.eval_limit, cfg.n_seeds, 400)?;
            t.row(vec![format!("{sigma:.2}"), pct(a1), pct(a2)]);
            xs.push(sigma);
            a_cm.push(a1);
            a_cv.push(a2);
        }
        println!("{}", t.render());
        Report::new(&pipe.store).save_series(
            &format!("sigma_sweep_{}", spec.name),
            vec![("dataset", Json::Str(spec.name.into()))],
            vec![("sigma", xs), ("capmin", a_cm), ("capminv", a_cv)],
        )?;
    }
    Ok(())
}
