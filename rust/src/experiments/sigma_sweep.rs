//! Extension experiment (paper future-work direction): variation
//! tolerance curve — accuracy vs sigma_rel at a fixed operating point,
//! CapMin (k = 14) vs CapMin-V (k = 16 capacitor, phi = 2). Quantifies
//! *how much* process variation each configuration absorbs, beyond the
//! single-sigma snapshot of Fig. 8. One `query_many` batch per dataset:
//! the per-sigma Monte-Carlo solves run in parallel.

use anyhow::Result;

use crate::coordinator::report::{pct, Report};
use crate::session::{DesignSession, OperatingPointSpec};
use crate::util::json::Json;
use crate::util::table::Table;

pub fn run(session: &DesignSession,
           datasets: &[crate::data::synth::Dataset]) -> Result<()> {
    let cfg = session.config();
    let sigmas = [0.0, 0.01, 0.02, 0.04, 0.06, 0.08];
    for &ds in datasets {
        let spec = ds.spec();
        session.ensure_trained(ds)?;
        println!(
            "\n== sigma sweep [{}]: CapMin(k=14) vs CapMin-V(16, phi=2) ==",
            spec.name
        );
        let mut specs = vec![];
        for &sigma in &sigmas {
            specs.push(
                OperatingPointSpec::new(ds, 14, sigma, 0)
                    .with_eval(300, cfg.n_seeds),
            );
            specs.push(
                OperatingPointSpec::new(ds, 16, sigma, 2)
                    .with_eval(400, cfg.n_seeds),
            );
        }
        let points = session.query_many(&specs)?;
        let mut t = Table::new(&["sigma_rel", "CapMin k=14", "CapMin-V"]);
        let mut xs = vec![];
        let mut a_cm = vec![];
        let mut a_cv = vec![];
        let mut it = points.iter();
        for &sigma in &sigmas {
            let a1 = it
                .next()
                .and_then(|p| p.accuracy)
                .expect("eval requested");
            let a2 = it
                .next()
                .and_then(|p| p.accuracy)
                .expect("eval requested");
            t.row(vec![format!("{sigma:.2}"), pct(a1), pct(a2)]);
            xs.push(sigma);
            a_cm.push(a1);
            a_cv.push(a2);
        }
        println!("{}", t.render());
        Report::new(session.store()).save_series(
            &format!("sigma_sweep_{}", spec.name),
            vec![("dataset", Json::Str(spec.name.into()))],
            vec![("sigma", xs), ("capmin", a_cm), ("capminv", a_cv)],
        )?;
    }
    Ok(())
}
