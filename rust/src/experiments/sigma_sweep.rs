//! Extension experiment (paper future-work direction): variation
//! tolerance curve — accuracy vs sigma_rel at a fixed operating point,
//! CapMin (k = 14) vs CapMin-V (k = 16 capacitor, phi = 2). Quantifies
//! *how much* process variation each configuration absorbs, beyond the
//! single-sigma snapshot of Fig. 8. The plan declares the whole
//! (dataset x sigma) grid; the planner's one global batch solves the
//! per-sigma Monte-Carlo maps in parallel.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::report::pct;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::json::Json;
use crate::util::table::Table;

/// The swept sigma_rel values.
pub const SIGMAS: [f64; 6] = [0.0, 0.01, 0.02, 0.04, 0.06, 0.08];

pub struct SigmaSweepPlan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for SigmaSweepPlan {
    fn name(&self) -> &'static str {
        "sigma-sweep"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Sigma sweep: CapMin(k=14) vs CapMin-V(16, phi=2)".into()
    }

    fn specs(&self, cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        let mut specs = vec![];
        for &ds in &self.datasets {
            for &sigma in &SIGMAS {
                specs.push(
                    OperatingPointSpec::new(ds, 14, sigma, 0)
                        .with_eval(300, cfg.n_seeds),
                );
                specs.push(
                    OperatingPointSpec::new(ds, 16, sigma, 2)
                        .with_eval(400, cfg.n_seeds),
                );
            }
        }
        specs
    }

    fn reduce(
        &self,
        _session: &DesignSession,
        points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let mut rep = Report::new(self.name(), &self.title());
        let mut it = points.iter();
        for &ds in &self.datasets {
            let spec = ds.spec();
            rep.heading(spec.name.to_string());
            let mut t =
                Table::new(&["sigma_rel", "CapMin k=14", "CapMin-V"]);
            let mut xs = vec![];
            let mut a_cm = vec![];
            let mut a_cv = vec![];
            for &sigma in &SIGMAS {
                let a1 = it
                    .next()
                    .and_then(|p| p.accuracy)
                    .expect("eval requested");
                let a2 = it
                    .next()
                    .and_then(|p| p.accuracy)
                    .expect("eval requested");
                t.row(vec![format!("{sigma:.2}"), pct(a1), pct(a2)]);
                xs.push(sigma);
                a_cm.push(a1);
                a_cv.push(a2);
            }
            rep.table("", t);
            rep.series(
                &format!("sigma_sweep_{}", spec.name),
                vec![(
                    "dataset".into(),
                    Json::Str(spec.name.into()),
                )],
                vec![
                    ("sigma".into(), xs),
                    ("capmin".into(), a_cm),
                    ("capminv".into(), a_cv),
                ],
            );
        }
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &SigmaSweepPlan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}
