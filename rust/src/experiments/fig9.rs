//! Fig. 9 — capacitor size and latency of the neuron circuit: baseline
//! (one spike time per level, SoA [3]) vs CapMin (k = 14 at 1% accuracy
//! cost) vs CapMin-V (k = 16 capacitor, phi = 2 merges).
//!
//! Reported under both capacitor models (physics-mode prediction and the
//! paper-calibrated fit; DESIGN.md §4): the *shape* — CapMin wins big,
//! CapMin-V costs a small premium over CapMin — holds in both.
//!
//! The plan declares exactly two hardware-only specs (the CapMin and
//! CapMin-V operating points of the representative model); the baseline
//! row is closed-form substrate math in the reduction.

use std::sync::Arc;

use anyhow::Result;

use crate::analog::capacitor::{
    paper_fit, CapacitorModel, CapacitorSolver,
};
use crate::analog::cost::{cost, readout_energy};
use crate::analog::neuron::SpikeTimeSet;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::report::ratio;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::table::{si, Table};

pub struct Fig9Row {
    pub name: String,
    pub k: usize,
    pub c_physics: f64,
    pub c_paperfit: f64,
    pub grt: f64,
    pub energy: f64,
}

/// The CapMin k this figure reports — the paper's 1% operating point
/// is fixed at 14 regardless of the configured sweep (Fig. 9 is the
/// paper's headline comparison, not a function of `--ks`).
const K_CAPMIN: usize = 14;

/// Build the three comparison rows from the two resolved operating
/// points (CapMin at `k`, CapMin-V from k=16) plus closed-form
/// baseline math.
pub fn rows_from_points(
    session: &DesignSession,
    k: usize,
    hw_min: &OperatingPoint,
    hw_v: &OperatingPoint,
) -> Vec<Fig9Row> {
    let p = session.params();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);

    // baseline: every level 1..=32 has a spike time
    let c_base = solver.size_for_window(1, 32);
    let set_base = SpikeTimeSet::new(&p, c_base, (1..=32).collect());
    let cost_base = cost(&p, &set_base);

    // CapMin at k: capacitor sized by the peak per-matmul window
    let w = hw_min.peak_window().clone();
    let c_min = hw_min.c;
    let set_min = SpikeTimeSet::new(&p, c_min, w.levels());
    let cost_min = cost(&p, &set_min);

    // CapMin-V: k=16 capacitor, phi merges down to k spike times
    let phi = super::fig8::CAPMINV_K_START - k;
    let c16 = hw_v.c;

    vec![
        Fig9Row {
            name: "baseline (SoA [3])".into(),
            k: 32,
            c_physics: c_base,
            c_paperfit: paper_fit(32),
            grt: cost_base.grt,
            energy: cost_base.energy,
        },
        Fig9Row {
            name: format!("CapMin (k={k})"),
            k,
            c_physics: c_min,
            c_paperfit: paper_fit(k),
            grt: cost_min.grt,
            energy: cost_min.energy,
        },
        Fig9Row {
            name: format!("CapMin-V (k16 cap, phi={phi})"),
            k,
            c_physics: c16,
            c_paperfit: paper_fit(super::fig8::CAPMINV_K_START),
            grt: hw_v.grt,
            energy: readout_energy(&p, c16),
        },
    ]
}

pub struct Fig9Plan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for Fig9Plan {
    fn name(&self) -> &'static str {
        "fig9"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Fig. 9: capacitor size & latency at 1% accuracy cost".into()
    }

    fn specs(&self, cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        // the capacitor story is driven by the peak window, which
        // Fig. 1 shows is identical across benchmarks — one
        // representative model's per-matmul histograms suffice (the
        // paper's combined-F_MAC move)
        let ds = self.datasets[0];
        vec![
            OperatingPointSpec::new(ds, K_CAPMIN, 0.0, 0),
            OperatingPointSpec::new(
                ds,
                super::fig8::CAPMINV_K_START,
                cfg.sigma_rel,
                super::fig8::CAPMINV_K_START - K_CAPMIN,
            ),
        ]
    }

    fn reduce(
        &self,
        session: &DesignSession,
        points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let rows = rows_from_points(
            session,
            K_CAPMIN,
            &points[0],
            &points[1],
        );
        let mut rep = Report::new(self.name(), &self.title());
        let mut t = Table::new(&[
            "config", "k", "C (physics)", "C (paper-fit)", "GRT",
            "E/submac",
        ]);
        for r in &rows {
            t.row(vec![
                r.name.clone(),
                r.k.to_string(),
                si(r.c_physics, "F"),
                si(r.c_paperfit, "F"),
                si(r.grt, "s"),
                si(r.energy, "J"),
            ]);
        }
        rep.table("", t);
        let base = &rows[0];
        let cm = &rows[1];
        let cv = &rows[2];
        rep.text(format!(
            "capacitor reduction  : physics {} | paper-fit {}  \
             (paper: 14.08x)",
            ratio(base.c_physics / cm.c_physics),
            ratio(base.c_paperfit / cm.c_paperfit),
        ));
        rep.text(format!(
            "latency (GRT) gain   : physics {}            (paper: ~14x)",
            ratio(base.grt / cm.grt),
        ));
        rep.text(format!(
            "CapMin-V premium     : physics {} | paper-fit {} (paper: \
             +28%)",
            ratio(cv.c_physics / cm.c_physics),
            ratio(cv.c_paperfit / cm.c_paperfit),
        ));
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &Fig9Plan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}
