//! Fig. 9 — capacitor size and latency of the neuron circuit: baseline
//! (one spike time per level, SoA [3]) vs CapMin (k = 14 at 1% accuracy
//! cost) vs CapMin-V (k = 16 capacitor, phi = 2 merges).
//!
//! Reported under both capacitor models (physics-mode prediction and the
//! paper-calibrated fit; DESIGN.md §4): the *shape* — CapMin wins big,
//! CapMin-V costs a small premium over CapMin — holds in both.

use anyhow::Result;

use crate::analog::capacitor::{paper_fit, CapacitorModel, CapacitorSolver};
use crate::analog::cost::cost;
use crate::analog::neuron::SpikeTimeSet;
use crate::coordinator::report::ratio;
use crate::data::synth::Dataset;
use crate::session::{DesignSession, OperatingPointSpec};
use crate::util::table::{si, Table};

pub struct Fig9Row {
    pub name: String,
    pub k: usize,
    pub c_physics: f64,
    pub c_paperfit: f64,
    pub grt: f64,
    pub energy: f64,
}

pub fn compute(session: &DesignSession, ds: Dataset, k_capmin: usize)
    -> Result<Vec<Fig9Row>> {
    let p = session.params();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);

    // baseline: every level 1..=32 has a spike time
    let c_base = solver.size_for_window(1, 32);
    let set_base = SpikeTimeSet::new(&p, c_base, (1..=32).collect());
    let cost_base = cost(&p, &set_base);

    // CapMin at k_capmin: capacitor sized by the peak per-matmul window
    let hw_min = session
        .query(&OperatingPointSpec::new(ds, k_capmin, 0.0, 0))?;
    let w = hw_min.peak_window().clone();
    let c_min = hw_min.c;
    let set_min = SpikeTimeSet::new(&p, c_min, w.levels());
    let cost_min = cost(&p, &set_min);

    // CapMin-V: k=16 capacitor, phi merges down to k_capmin spike times
    let phi = super::fig8::CAPMINV_K_START - k_capmin;
    let hw_v = session.query(&OperatingPointSpec::new(
        ds,
        super::fig8::CAPMINV_K_START,
        session.config().sigma_rel,
        phi,
    ))?;
    let c16 = hw_v.c;
    let cost_v = crate::analog::cost::CircuitCost {
        c: c16,
        energy: 0.5 * c16 * p.vth * p.vth,
        grt: hw_v.grt,
        area: c16 / crate::analog::cost::CAP_DENSITY,
    };

    Ok(vec![
        Fig9Row {
            name: "baseline (SoA [3])".into(),
            k: 32,
            c_physics: c_base,
            c_paperfit: paper_fit(32),
            grt: cost_base.grt,
            energy: cost_base.energy,
        },
        Fig9Row {
            name: format!("CapMin (k={k_capmin})"),
            k: k_capmin,
            c_physics: c_min,
            c_paperfit: paper_fit(k_capmin),
            grt: cost_min.grt,
            energy: cost_min.energy,
        },
        Fig9Row {
            name: format!(
                "CapMin-V (k16 cap, phi={phi})"
            ),
            k: k_capmin,
            c_physics: c16,
            c_paperfit: paper_fit(super::fig8::CAPMINV_K_START),
            grt: cost_v.grt,
            energy: 0.5 * c16 * p.vth * p.vth,
        },
    ])
}

pub fn run(session: &DesignSession,
           datasets: &[crate::data::synth::Dataset]) -> Result<()> {
    // the capacitor story is driven by the peak window, which Fig. 1
    // shows is identical across benchmarks — one representative model's
    // per-matmul histograms suffice (the paper's combined-F_MAC move)
    let cfg = session.config();
    let k = cfg.ks.iter().copied().find(|&k| k == 14).unwrap_or(14);
    let rows = compute(session, datasets[0], k)?;
    println!("\n== Fig. 9: capacitor size & latency at 1% accuracy cost ==");
    let mut t = Table::new(&[
        "config", "k", "C (physics)", "C (paper-fit)", "GRT", "E/submac",
    ]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            r.k.to_string(),
            si(r.c_physics, "F"),
            si(r.c_paperfit, "F"),
            si(r.grt, "s"),
            si(r.energy, "J"),
        ]);
    }
    println!("{}", t.render());
    let base = &rows[0];
    let cm = &rows[1];
    let cv = &rows[2];
    println!(
        "capacitor reduction  : physics {} | paper-fit {}  (paper: 14.08x)",
        ratio(base.c_physics / cm.c_physics),
        ratio(base.c_paperfit / cm.c_paperfit),
    );
    println!(
        "latency (GRT) gain   : physics {}            (paper: ~14x)",
        ratio(base.grt / cm.grt),
    );
    println!(
        "CapMin-V premium     : physics {} | paper-fit {} (paper: +28%)",
        ratio(cv.c_physics / cm.c_physics),
        ratio(cv.c_paperfit / cm.c_paperfit),
    );
    Ok(())
}
