//! Fig. 6 — effect of current variation on capacitor charging: variation
//! intervals E_i vs decision intervals B_i, and the tolerance ratio
//! r_i = |B_i| / |E_i| (the monotonicity CapMin-V exploits). Pure
//! analog-substrate work on the baseline spike-time set; empty grid.

use std::sync::Arc;

use anyhow::Result;

use crate::analog::montecarlo::MonteCarlo;
use crate::analog::neuron::SpikeTimeSet;
use crate::coordinator::config::ExperimentConfig;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::table::{si, Table};

pub struct Fig6Plan;

impl ExperimentPlan for Fig6Plan {
    fn name(&self) -> &'static str {
        "fig6"
    }

    fn title(&self) -> String {
        "Fig. 6: variation vs decision intervals, baseline set".into()
    }

    fn specs(&self, _cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        vec![]
    }

    fn reduce(
        &self,
        session: &DesignSession,
        _points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let p = session.params();
        let solver = crate::analog::capacitor::CapacitorSolver::new(
            p,
            crate::analog::capacitor::CapacitorModel::Physics,
        );
        let (lo, hi) = (1usize, 32usize);
        let c = solver.size_for_window(lo, hi);
        let set = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
        let mc = MonteCarlo::new(p);
        let mut rep = Report::new(self.name(), &self.title());
        rep.text(format!(
            "(3-sigma variation intervals at sigma_rel = {})",
            p.sigma_rel
        ));
        let mut t = Table::new(&[
            "level", "t_fire", "|E_i| (3s)", "|B_i|", "r = |B|/|E|",
            "overlap?",
        ]);
        for (idx, &m) in set.levels.iter().enumerate() {
            let (e_lo, e_hi) = mc.variation_interval(&set, m);
            let e_len = e_hi - e_lo;
            let b_len = set.bucket_len(idx);
            let r = b_len / e_len;
            // striped-area check: does the 3-sigma interval cross a
            // boundary?
            let overlaps = if idx > 0 && idx < set.levels.len() - 1 {
                e_hi > set.boundaries[idx - 1]
                    || e_lo < set.boundaries[idx]
            } else {
                false
            };
            if m % 4 == 0 || m <= 2 || m >= 31 {
                t.row(vec![
                    m.to_string(),
                    si(set.times[idx], "s"),
                    si(e_len, "s"),
                    if b_len.is_finite() {
                        si(b_len, "s")
                    } else {
                        "open".into()
                    },
                    if r.is_finite() {
                        format!("{r:.2}")
                    } else {
                        "inf".into()
                    },
                    if overlaps { "YES".into() } else { "no".into() },
                ]);
            }
        }
        rep.table("", t);
        rep.text(
            "(r grows toward slow spike times: slower levels tolerate \
             more variation — the basis of CapMin-V's merge order)",
        );
        Ok(rep)
    }
}

pub fn run(session: &DesignSession) -> Result<()> {
    crate::plan::planner::run_one(session, &Fig6Plan, &[])
}
