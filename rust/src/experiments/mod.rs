//! Experiment plan definitions — one module per paper table/figure
//! (DESIGN.md §5/§10). Each module defines an
//! [`crate::plan::ExperimentPlan`]: a declared operating-point grid
//! plus a pure reduction to a typed report. The `run` functions are
//! thin single-plan wrappers over
//! [`crate::plan::planner::run_one`] for the per-figure CLI commands;
//! `capmin suite` runs the whole registry through one deduplicated
//! batch. None touches the stage graph directly.

pub mod ablation;
pub mod fig1;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod fig8;
pub mod fig9;
pub mod headline;
pub mod pareto;
pub mod sigma_sweep;
pub mod tables;

use anyhow::{anyhow, Result};

use crate::data::synth::Dataset;
use crate::util::cli::Args;

/// Datasets selected by --dataset (name | "all").
pub fn selected_datasets(args: &Args) -> Result<Vec<Dataset>> {
    match args.get("dataset") {
        None | Some("all") => Ok(Dataset::all().to_vec()),
        Some(name) => {
            let ds = Dataset::from_name(name).ok_or_else(|| {
                let valid: Vec<&str> = Dataset::all()
                    .iter()
                    .map(|d| d.spec().name)
                    .collect();
                anyhow!(
                    "unknown dataset `{name}` (valid choices: {}, all)",
                    valid.join(", ")
                )
            })?;
            Ok(vec![ds])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn selects_all_by_default() {
        assert_eq!(selected_datasets(&parse(&["x"])).unwrap().len(), 5);
        assert_eq!(
            selected_datasets(&parse(&["x", "--dataset", "all"]))
                .unwrap()
                .len(),
            5
        );
    }

    #[test]
    fn selects_one_by_name() {
        let ds = selected_datasets(&parse(&[
            "x", "--dataset", "cifar_syn",
        ]))
        .unwrap();
        assert_eq!(ds, vec![Dataset::CifarSyn]);
    }

    #[test]
    fn unknown_dataset_error_lists_choices() {
        let e = selected_datasets(&parse(&["x", "--dataset", "mnist"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("mnist"), "{e}");
        assert!(e.contains("fashion_syn"), "{e}");
        assert!(e.contains("imagenette_syn"), "{e}");
    }
}
