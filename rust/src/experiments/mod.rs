//! Experiment drivers — one module per paper table/figure (DESIGN.md §5).

pub mod ablation;
pub mod fig1;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod fig8;
pub mod fig9;
pub mod headline;
pub mod sigma_sweep;
pub mod tables;

use crate::data::synth::Dataset;
use crate::util::cli::Args;

/// Datasets selected by --dataset (name | "all").
pub fn selected_datasets(args: &Args) -> Vec<Dataset> {
    match args.get("dataset") {
        None => Dataset::all().to_vec(),
        Some("all") => Dataset::all().to_vec(),
        Some(name) => vec![Dataset::from_name(name)
            .unwrap_or_else(|| panic!("unknown dataset {name}"))],
    }
}
