//! Headline claims check: 14x capacitor reduction at <= 1% accuracy cost;
//! CapMin-V variation tolerance for a small capacitor premium.

use anyhow::Result;

use crate::analog::capacitor::paper_fit;
use crate::coordinator::report::{pct, ratio};
use crate::session::DesignSession;
use crate::util::json::Json;
use crate::util::table::si;

pub fn run(session: &DesignSession,
           datasets: &[crate::data::synth::Dataset]) -> Result<()> {
    println!("== Headline reproduction summary ==");
    // capacitor story is dataset-independent
    let c32 = paper_fit(32);
    let c14 = paper_fit(14);
    let c16 = paper_fit(16);
    println!(
        "paper-fit model : C(32) = {}  C(14) = {}  -> {}",
        si(c32, "F"),
        si(c14, "F"),
        ratio(c32 / c14)
    );
    println!(
        "CapMin-V premium: C(16)/C(14) = {} (paper: +28%)",
        ratio(c16 / c14)
    );

    // accuracy story: read the fig8 result series if present
    for &ds in datasets {
        let spec = ds.spec();
        let path = session
            .store()
            .path(&format!("results_fig8_{}.json", spec.name));
        if !path.exists() {
            println!(
                "{}: no fig8 results yet (run `capmin fig8`)",
                spec.name
            );
            continue;
        }
        let j = Json::parse(&std::fs::read_to_string(path)?)
            .map_err(anyhow::Error::msg)?;
        let s = j.req("series");
        let ks: Vec<f64> =
            s.req("k").as_arr().iter().map(|v| v.as_f64()).collect();
        let clean: Vec<f64> = s
            .req("capmin_clean")
            .as_arr()
            .iter()
            .map(|v| v.as_f64())
            .collect();
        let var: Vec<f64> = s
            .req("capmin_var")
            .as_arr()
            .iter()
            .map(|v| v.as_f64())
            .collect();
        let capv: Vec<f64> = s
            .req("capminv_var")
            .as_arr()
            .iter()
            .map(|v| v.as_f64())
            .collect();
        let ku: Vec<usize> = ks.iter().map(|&k| k as usize).collect();
        let k_star =
            super::fig8::choose_k(&ku, &clean, 0.01);
        let at = |k: usize, xs: &[f64]| {
            ku.iter()
                .position(|&kk| kk == k)
                .map(|i| xs[i])
                .unwrap_or(f64::NAN)
        };
        println!(
            "{}: clean@32 {} | clean@{k_star} {} (1% point) | \
             +var@{k_star} {} | CapMin-V@{k_star} {}",
            spec.name,
            pct(at(32, &clean)),
            pct(at(k_star, &clean)),
            pct(at(k_star, &var)),
            pct(at(k_star, &capv)),
        );
    }
    Ok(())
}
