//! Headline claims check: 14x capacitor reduction at <= 1% accuracy cost;
//! CapMin-V variation tolerance for a small capacitor premium.
//!
//! The plan declares the *same* sweep grid as Fig. 8 (via
//! [`super::fig8::sweep_specs`]) and summarizes straight from the
//! resolved points — under `suite` the planner's cross-plan dedup
//! collapses the two grids to one solve, and standalone `headline`
//! replays whatever the operating-point cache already holds instead of
//! requiring a prior `fig8` run.

use std::sync::Arc;

use anyhow::Result;

use crate::analog::capacitor::paper_fit;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::report::{pct, ratio};
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::table::si;

pub struct HeadlinePlan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for HeadlinePlan {
    fn name(&self) -> &'static str {
        "headline"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Headline reproduction summary".into()
    }

    fn specs(&self, cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        super::fig8::sweep_specs(cfg, &self.datasets)
    }

    fn reduce(
        &self,
        session: &DesignSession,
        points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let cfg = session.config();
        let mut rep = Report::new(self.name(), &self.title());

        // capacitor story is dataset-independent
        let c32 = paper_fit(32);
        let c14 = paper_fit(14);
        let c16 = paper_fit(16);
        rep.text(format!(
            "paper-fit model : C(32) = {}  C(14) = {}  -> {}",
            si(c32, "F"),
            si(c14, "F"),
            ratio(c32 / c14)
        ));
        rep.text(format!(
            "CapMin-V premium: C(16)/C(14) = {} (paper: +28%)",
            ratio(c16 / c14)
        ));

        // accuracy story, per dataset, straight from the sweep points
        let mut it = points.iter();
        for &ds in &self.datasets {
            let spec = ds.spec();
            let curves = super::fig8::decode_sweep(cfg, &mut it);
            let ku: Vec<usize> =
                curves.ks.iter().map(|&k| k as usize).collect();
            let k_star = super::fig8::choose_k(&ku, &curves.clean, 0.01);
            let at = |k: usize, xs: &[f64]| {
                ku.iter()
                    .position(|&kk| kk == k)
                    .map(|i| xs[i])
                    .unwrap_or(f64::NAN)
            };
            rep.text(format!(
                "{}: clean@32 {} | clean@{k_star} {} (1% point) | \
                 +var@{k_star} {} | CapMin-V@{k_star} {}",
                spec.name,
                pct(at(32, &curves.clean)),
                pct(at(k_star, &curves.clean)),
                pct(at(k_star, &curves.var)),
                pct(at(k_star, &curves.capv)),
            ));
        }
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &HeadlinePlan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}
