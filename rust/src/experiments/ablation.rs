//! Ablation: the two hardware-faithful divergences of DESIGN.md §6b.
//!
//! (a) window placement — per-matmul (CapMin-L, ours) vs one global
//!     window over the summed F_MAC (the paper's literal reading);
//! (b) CapMin-V merge criterion — min-diagonal (Alg. 1) vs merging from
//!     the fast end unconditionally (the naive order its analysis
//!     suggests).
//!
//! The plan declares the per-matmul ("ours") evaluation points — the
//! half that overlaps other plans' sweeps and benefits from suite
//! dedup; the ablated global-window variants are session-external by
//! construction (they bypass the operating-point space) and run inside
//! the reduction.

use std::sync::Arc;

use anyhow::Result;

use crate::analog::capacitor::{CapacitorModel, CapacitorSolver};
use crate::analog::montecarlo::MonteCarlo;
use crate::analog::neuron::SpikeTimeSet;
use crate::backend::InferenceBackend;
use crate::bnn::ErrorModel;
use crate::capmin::capmin::select_window;
use crate::capmin::Fmac;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::report::pct;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::rng::Rng;
use crate::util::table::Table;

/// k values both ablation tables sweep.
const ABLATION_KS: [usize; 3] = [16, 14, 10];

/// Global-window variant of the session's operating-point solve (the
/// ablated design): every matmul reads out through the window selected
/// on the *summed* F_MAC, exactly as a literal reading of the paper
/// prescribes.
pub fn hw_config_global(
    session: &DesignSession,
    sum_fmac: &Fmac,
    n_mat: usize,
    k: usize,
    sigma: f64,
) -> Vec<ErrorModel> {
    let cfg = session.config();
    let p = session.params().with_sigma(sigma);
    let w = select_window(sum_fmac, k);
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    let c = solver.size_for_window(w.q_lo, w.q_hi);
    let set = SpikeTimeSet::new(&p, c, w.levels());
    let mc = MonteCarlo::new(p).with_settings(
        cfg.mc_settings().expect("mc mode validated at session build"),
    );
    // sigma == 0 short-circuits inside full_map to the exact clean map
    let full = mc.full_map(&set, &mut Rng::new(cfg.seed ^ 0xAB1A));
    let em = ErrorModel::from_full(&full);
    vec![em; n_mat]
}

pub struct AblationPlan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for AblationPlan {
    fn name(&self) -> &'static str {
        "ablation"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Ablation: window placement & CapMin-V merge criterion".into()
    }

    fn specs(&self, _cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        let mut specs = vec![];
        for &ds in &self.datasets {
            for k in ABLATION_KS {
                specs.push(
                    OperatingPointSpec::new(ds, k, 0.0, 0)
                        .with_eval(1, 1),
                );
            }
        }
        specs
    }

    fn reduce(
        &self,
        session: &DesignSession,
        points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let cfg = session.config();
        let backend = session.backend()?;
        let mut rep = Report::new(self.name(), &self.title());

        rep.heading(
            "Ablation (a): per-matmul windows vs one global window",
        );
        let mut t = Table::new(&[
            "dataset", "k", "per-matmul (ours)",
            "global (paper literal)",
        ]);
        let mut it = points.iter();
        for &ds in &self.datasets {
            let spec = ds.spec();
            let folded = session.folded(ds)?;
            let (_, sum) = session.fmac(ds)?;
            let n_matmuls =
                crate::backend::arch::model_meta(spec.model)?
                    .n_matmuls();
            for k in ABLATION_KS {
                let ours = it.next().expect("one point per (ds, k)");
                let a_ours = ours.accuracy.expect("eval requested");
                let glob =
                    hw_config_global(session, &sum, n_matmuls, k, 0.0);
                let a_glob = backend.accuracy(
                    spec.model,
                    &folded,
                    spec.clone(),
                    &glob,
                    cfg.eval_limit,
                    1,
                )?;
                t.row(vec![
                    spec.name.into(),
                    k.to_string(),
                    pct(a_ours),
                    pct(a_glob),
                ]);
            }
        }
        rep.table("", t);
        rep.text(
            "(dummy-cell biasing centers all groups on the peak, so \
             the global window only loses where per-layer supports \
             still differ — see DESIGN.md §6b)",
        );

        rep.heading("Ablation (b): CapMin-V merge criterion");
        let mut t = Table::new(&[
            "phi", "min-diag merge (Alg. 1)", "fast-end merge (naive)",
        ]);
        let p = session.params();
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let (lo, hi) = (9usize, 24usize);
        let c = solver.size_for_window(lo, hi);
        let set = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
        let mc = MonteCarlo::new(p).with_settings(cfg.mc_settings()?);
        // the baseline P_map is phi-independent: extract it once and
        // clone per merge depth
        let pm = mc.pmap(&set, &mut Rng::new(11));
        for phi in [2usize, 4, 6] {
            // Alg. 1
            let alg1 =
                crate::capmin::capmin_v::capmin_v(pm.clone(), phi);
            let set1 = SpikeTimeSet::new(&p, c, alg1.levels.clone());
            let d1 = mc
                .pmap(&set1, &mut Rng::new(12))
                .diag()
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            // naive: drop the phi fastest levels
            let naive: Vec<usize> = (lo..=hi - phi).collect();
            let set2 = SpikeTimeSet::new(&p, c, naive);
            let d2 = mc
                .pmap(&set2, &mut Rng::new(12))
                .diag()
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            t.row(vec![
                phi.to_string(),
                format!("{d1:.3}"),
                format!("{d2:.3}"),
            ]);
        }
        rep.table("", t);
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &AblationPlan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}
