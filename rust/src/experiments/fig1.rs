//! Fig. 1 — absolute frequencies of MAC level occurrences (summed over
//! layers) on the training sets, per benchmark. A plan with an empty
//! grid: the F_MAC histograms come straight from the session's
//! memoized extraction, not from operating-point queries.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::ExperimentConfig;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::json::Json;
use crate::util::table::Table;

pub struct Fig1Plan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for Fig1Plan {
    fn name(&self) -> &'static str {
        "fig1"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Fig. 1: F_MAC histograms (summed over layers)".into()
    }

    fn specs(&self, _cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        vec![]
    }

    fn reduce(
        &self,
        session: &DesignSession,
        _points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let mut rep = Report::new(self.name(), &self.title());
        for &ds in &self.datasets {
            let spec = ds.spec();
            let (_per, sum) = session.fmac(ds)?;
            let mut t = Table::new(&["level", "count", "log10", "bar"]);
            let max = *sum.counts.iter().max().unwrap() as f64;
            for (m, &c) in sum.counts.iter().enumerate() {
                let l10 = if c > 0 { (c as f64).log10() } else { 0.0 };
                let bar_len = if max > 1.0 && c > 0 {
                    (40.0 * (c as f64).ln() / max.ln()).round() as usize
                } else {
                    0
                };
                t.row(vec![
                    m.to_string(),
                    c.to_string(),
                    format!("{l10:.2}"),
                    "#".repeat(bar_len),
                ]);
            }
            rep.heading(format!(
                "{} (paper: {})",
                spec.name, spec.paper_name
            ));
            rep.table("", t);
            rep.text(format!(
                "dynamic range (max/min nonzero): {:.1e}  | paper \
                 observes 1e5..1e7 between peak and tails",
                sum.dynamic_range()
            ));
            rep.series(
                &format!("fig1_{}", spec.name),
                vec![(
                    "dataset".into(),
                    Json::Str(spec.name.into()),
                )],
                vec![(
                    "counts".into(),
                    sum.counts.iter().map(|&c| c as f64).collect(),
                )],
            );
        }
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &Fig1Plan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}
