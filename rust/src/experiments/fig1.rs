//! Fig. 1 — absolute frequencies of MAC level occurrences (summed over
//! layers) on the training sets, per benchmark.

use anyhow::Result;

use crate::coordinator::report::Report;
use crate::session::DesignSession;
use crate::util::json::Json;
use crate::util::table::Table;

pub fn run(session: &DesignSession,
           datasets: &[crate::data::synth::Dataset]) -> Result<()> {
    println!("== Fig. 1: F_MAC histograms (summed over layers) ==");
    for &ds in datasets {
        let spec = ds.spec();
        let (_per, sum) = session.fmac(ds)?;
        let mut t = Table::new(&["level", "count", "log10", "bar"]);
        let max = *sum.counts.iter().max().unwrap() as f64;
        for (m, &c) in sum.counts.iter().enumerate() {
            let l10 = if c > 0 { (c as f64).log10() } else { 0.0 };
            let bar_len = if max > 1.0 && c > 0 {
                (40.0 * (c as f64).ln() / max.ln()).round() as usize
            } else {
                0
            };
            t.row(vec![
                m.to_string(),
                c.to_string(),
                format!("{l10:.2}"),
                "#".repeat(bar_len),
            ]);
        }
        println!("\n-- {} (paper: {}) --", spec.name, spec.paper_name);
        println!("{}", t.render());
        println!(
            "dynamic range (max/min nonzero): {:.1e}  | paper observes \
             1e5..1e7 between peak and tails",
            sum.dynamic_range()
        );
        let rep = Report::new(session.store());
        rep.save_series(
            &format!("fig1_{}", spec.name),
            vec![("dataset", Json::Str(spec.name.into()))],
            vec![(
                "counts",
                sum.counts.iter().map(|&c| c as f64).collect(),
            )],
        )?;
    }
    Ok(())
}
