//! Design-space explorer — CapMin vs CapMin-V Pareto frontiers over
//! accuracy / energy / area / latency (DESIGN.md §13).
//!
//! The grid is fig8's sweep *verbatim* ([`super::fig8::sweep_specs`]):
//! under `suite` the pareto plan rides the same solves as fig8 and
//! headline for free, and standalone it replays them from the point
//! cache. The reduction prices every resolved point through its
//! [`CostVector`] and extracts the non-dominated subset per dataset
//! with [`crate::util::pareto`] — answering the query class the paper
//! never asks: "what is the cheapest operating point above X%
//! accuracy?"

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::report::pct;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::json::Json;
use crate::util::pareto::{
    hypervolume, minimized, non_dominated, Sense,
};
use crate::util::table::{si, Table};

use super::fig8::CAPMINV_K_START;

/// Objective directions of a priced point's report coordinates:
/// (accuracy, energy, area, latency).
pub const SENSES: [Sense; 4] = [
    Sense::Maximize,
    Sense::Minimize,
    Sense::Minimize,
    Sense::Minimize,
];

/// One candidate design in a dataset's frontier report.
pub struct Candidate {
    /// "capmin" (clipping + variation) or "capmin-v" (k=16 cap,
    /// merged down under variation).
    pub family: &'static str,
    pub k: usize,
    pub phi: usize,
    pub point: Arc<OperatingPoint>,
}

impl Candidate {
    /// Raw objective row in [`SENSES`] order.
    pub fn objectives(&self) -> Vec<f64> {
        let cv = &self.point.cost;
        vec![
            self.point.accuracy.expect("eval requested"),
            cv.energy,
            cv.area,
            cv.latency,
        ]
    }
}

/// Walk one dataset's block of resolved fig8-grid points (clean /
/// var / capmin-v per k) into frontier candidates: the two
/// variation-realistic families the paper compares. Clean points are
/// consumed (grid alignment) but not priced — a frontier without
/// variation is not a hardware claim.
pub fn candidates<'a>(
    cfg: &ExperimentConfig,
    points: &mut impl Iterator<Item = &'a Arc<OperatingPoint>>,
) -> Vec<Candidate> {
    let mut out = vec![];
    for &k in &cfg.ks {
        let _clean = points.next().expect("clean point per k");
        let p_var = points.next().expect("variation point per k");
        out.push(Candidate {
            family: "capmin",
            k,
            phi: 0,
            point: Arc::clone(p_var),
        });
        if k < CAPMINV_K_START {
            let p_v = points.next().expect("capmin-v point below k=16");
            out.push(Candidate {
                family: "capmin-v",
                k,
                phi: CAPMINV_K_START - k,
                point: Arc::clone(p_v),
            });
        }
    }
    out
}

/// Indices of the non-dominated candidates over all four objectives.
pub fn frontier(cands: &[Candidate]) -> Vec<usize> {
    let vals: Vec<Vec<f64>> = cands
        .iter()
        .map(|c| minimized(&c.objectives(), &SENSES))
        .collect();
    non_dominated(&vals)
}

/// Normalized accuracy-vs-energy hypervolume of one family's
/// candidates: objectives (1 - accuracy, energy / e_max) against the
/// reference (1, 1) + eps, so the indicator lives in [0, 1] and is
/// comparable across families *within* one report (e_max is the
/// dataset's worst energy).
pub fn family_hypervolume(
    cands: &[Candidate],
    family: &str,
    e_max: f64,
) -> f64 {
    let vals: Vec<Vec<f64>> = cands
        .iter()
        .filter(|c| c.family == family)
        .map(|c| {
            let o = c.objectives();
            vec![1.0 - o[0], o[1] / e_max]
        })
        .collect();
    hypervolume(&vals, &[1.0 + 1e-9, 1.0 + 1e-9])
}

pub struct ParetoPlan {
    pub datasets: Vec<Dataset>,
}

impl ExperimentPlan for ParetoPlan {
    fn name(&self) -> &'static str {
        "pareto"
    }

    fn scope(&self) -> String {
        crate::plan::dataset_scope(&self.datasets)
    }

    fn title(&self) -> String {
        "Pareto: accuracy / energy / area / latency frontiers \
         (CapMin vs CapMin-V)"
            .into()
    }

    fn specs(&self, cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        // fig8's grid verbatim: zero extra solves under suite
        super::fig8::sweep_specs(cfg, &self.datasets)
    }

    fn reduce(
        &self,
        session: &DesignSession,
        points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let cfg = session.config();
        let mut rep = Report::new(self.name(), &self.title());
        let mut it = points.iter();
        for &ds in &self.datasets {
            let spec = ds.spec();
            rep.heading(format!(
                "{} (sigma_rel = {}, {} test samples)",
                spec.name, cfg.sigma_rel, cfg.eval_limit
            ));
            let cands = candidates(cfg, &mut it);
            let front = frontier(&cands);
            let on_front =
                |i: usize| front.binary_search(&i).is_ok();

            let mut t = Table::new(&[
                "config", "k", "phi", "C", "spikes", "E/pass", "area",
                "latency", "accuracy", "front",
            ]);
            for (i, c) in cands.iter().enumerate() {
                let cv = &c.point.cost;
                t.row(vec![
                    c.family.into(),
                    c.k.to_string(),
                    c.phi.to_string(),
                    si(cv.c, "F"),
                    cv.spike_times.to_string(),
                    si(cv.energy, "J"),
                    si(cv.area, "m2"),
                    si(cv.latency, "s"),
                    pct(c.point.accuracy.expect("eval requested")),
                    if on_front(i) { "*".into() } else { "".into() },
                ]);
            }
            rep.table("", t);

            let e_max = cands
                .iter()
                .map(|c| c.point.cost.energy)
                .fold(0.0, f64::max);
            rep.text(format!(
                "frontier: {}/{} non-dominated | hypervolume \
                 (accuracy x energy, normalized): capmin {:.4} | \
                 capmin-v {:.4}",
                front.len(),
                cands.len(),
                family_hypervolume(&cands, "capmin", e_max),
                family_hypervolume(&cands, "capmin-v", e_max),
            ));

            // the explorer's headline query: cheapest energy within
            // 1% of the best achievable accuracy on this dataset
            let best_acc = cands
                .iter()
                .map(|c| c.point.accuracy.expect("eval requested"))
                .fold(0.0, f64::max);
            if let Some(c) = cands
                .iter()
                .filter(|c| {
                    c.point.accuracy.expect("eval requested")
                        >= best_acc - 0.01
                })
                .min_by(|a, b| {
                    a.point
                        .cost
                        .energy
                        .partial_cmp(&b.point.cost.energy)
                        .unwrap()
                })
            {
                rep.text(format!(
                    "cheapest within 1% of best accuracy ({}): {} \
                     k={} phi={} at {} per pass, {}",
                    pct(best_acc),
                    c.family,
                    c.k,
                    c.phi,
                    si(c.point.cost.energy, "J"),
                    pct(c.point.accuracy.expect("eval requested")),
                ));
            }

            let col = |f: &dyn Fn(&Candidate) -> f64| -> Vec<f64> {
                cands.iter().map(f).collect()
            };
            rep.series(
                &format!("pareto_{}", spec.name),
                vec![
                    ("dataset".into(), Json::Str(spec.name.into())),
                    ("sigma_rel".into(), Json::Num(cfg.sigma_rel)),
                    (
                        "objectives".into(),
                        Json::Str(
                            "accuracy max, energy/area/latency min"
                                .into(),
                        ),
                    ),
                ],
                vec![
                    ("k".into(), col(&|c| c.k as f64)),
                    ("phi".into(), col(&|c| c.phi as f64)),
                    (
                        "family".into(),
                        col(&|c| {
                            if c.family == "capmin" { 0.0 } else { 1.0 }
                        }),
                    ),
                    (
                        "accuracy".into(),
                        col(&|c| {
                            c.point.accuracy.expect("eval requested")
                        }),
                    ),
                    ("energy".into(), col(&|c| c.point.cost.energy)),
                    ("area".into(), col(&|c| c.point.cost.area)),
                    (
                        "latency".into(),
                        col(&|c| c.point.cost.latency),
                    ),
                    (
                        "on_front".into(),
                        (0..cands.len())
                            .map(|i| if on_front(i) { 1.0 } else { 0.0 })
                            .collect(),
                    ),
                ],
            );
        }
        Ok(rep)
    }
}

pub fn run(
    session: &DesignSession,
    datasets: &[Dataset],
) -> Result<()> {
    crate::plan::planner::run_one(
        session,
        &ParetoPlan {
            datasets: datasets.to_vec(),
        },
        &[],
    )
}
