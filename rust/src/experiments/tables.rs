//! Tables I & II as experiment plans — regenerated from the data
//! registry and the native model registry (`backend::arch`); no
//! manifest or artifacts needed, so both declare an empty grid.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::ExperimentConfig;
use crate::data::synth::Dataset;
use crate::plan::report::Report;
use crate::plan::ExperimentPlan;
use crate::session::{DesignSession, OperatingPoint, OperatingPointSpec};
use crate::util::table::Table;

pub struct Table1Plan;

impl ExperimentPlan for Table1Plan {
    fn name(&self) -> &'static str {
        "table1"
    }

    fn title(&self) -> String {
        "Table I: datasets".into()
    }

    fn specs(&self, _cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        vec![]
    }

    fn reduce(
        &self,
        _session: &DesignSession,
        _points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let mut rep = Report::new(self.name(), &self.title());
        let mut t = Table::new(&[
            "name", "stands in for", "#train", "#test", "dim",
            "#classes",
        ]);
        for ds in Dataset::all() {
            let s = ds.spec();
            t.row(vec![
                s.name.into(),
                s.paper_name.into(),
                s.n_train.to_string(),
                s.n_test.to_string(),
                format!("({},{},{})", s.channels, s.height, s.width),
                s.classes.to_string(),
            ]);
        }
        rep.table("", t);
        Ok(rep)
    }
}

pub struct Table2Plan;

impl ExperimentPlan for Table2Plan {
    fn name(&self) -> &'static str {
        "table2"
    }

    fn title(&self) -> String {
        "Table II: BNN architectures".into()
    }

    fn specs(&self, _cfg: &ExperimentConfig) -> Vec<OperatingPointSpec> {
        vec![]
    }

    fn reduce(
        &self,
        session: &DesignSession,
        _points: &[Arc<OperatingPoint>],
    ) -> Result<Report> {
        let mut rep = Report::new(self.name(), &self.title());
        // prefer the AOT manifest when available: it records the widths
        // the artifacts were actually built at (--full or CPU-budget)
        #[cfg(feature = "xla")]
        if crate::runtime::artifacts_dir()
            .join("manifest.json")
            .exists()
        {
            rep.text("(from the AOT manifest)");
            let manifest = &session.runtime()?.manifest;
            let mut t = Table::new(&[
                "model", "architecture", "params", "matmuls",
                "MHL margin",
            ]);
            for (name, m) in &manifest.models {
                if name == "vgg3_tiny" {
                    continue; // test-only twin
                }
                t.row(vec![
                    name.clone(),
                    m.description.clone(),
                    m.n_params.to_string(),
                    m.n_matmuls.to_string(),
                    format!("{}", m.mhl_b),
                ]);
            }
            rep.table("", t);
            if !manifest.full {
                rep.text(
                    "(CPU-budget widths; `make artifacts` with --full \
                     restores the paper's exact channel plan — \
                     DESIGN.md §6)",
                );
            }
            return Ok(rep);
        }
        let _ = &session;
        rep.text("(native registry, DESIGN.md §9)");
        let mut t = Table::new(&[
            "model", "architecture", "binary weights", "matmuls",
        ]);
        for name in crate::backend::arch::model_names() {
            if name == "vgg3_tiny" {
                continue; // test-only twin
            }
            let m = crate::backend::arch::model_meta(name)?;
            t.row(vec![
                name.to_string(),
                m.describe(),
                m.n_weight_bits().to_string(),
                m.n_matmuls().to_string(),
            ]);
        }
        rep.table("", t);
        rep.text(
            "(CPU-budget widths; `make artifacts` with --full restores \
             the paper's exact channel plan — DESIGN.md §6)",
        );
        Ok(rep)
    }
}

pub fn table1(session: &DesignSession) -> Result<()> {
    crate::plan::planner::run_one(session, &Table1Plan, &[])
}

pub fn table2(session: &DesignSession) -> Result<()> {
    crate::plan::planner::run_one(session, &Table2Plan, &[])
}
