//! Tables I & II regeneration from the data registry and the native
//! model registry (`backend::arch`) — no manifest or artifacts needed.

use anyhow::Result;

use crate::data::synth::Dataset;
use crate::session::DesignSession;
use crate::util::table::Table;

pub fn table1(_session: &DesignSession) -> Result<()> {
    println!("== Table I: datasets ==");
    let mut t = Table::new(&[
        "name", "stands in for", "#train", "#test", "dim", "#classes",
    ]);
    for ds in Dataset::all() {
        let s = ds.spec();
        t.row(vec![
            s.name.into(),
            s.paper_name.into(),
            s.n_train.to_string(),
            s.n_test.to_string(),
            format!("({},{},{})", s.channels, s.height, s.width),
            s.classes.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

pub fn table2(session: &DesignSession) -> Result<()> {
    // prefer the AOT manifest when available: it records the widths
    // the artifacts were actually built at (--full or CPU-budget)
    #[cfg(feature = "xla")]
    if crate::runtime::artifacts_dir().join("manifest.json").exists() {
        println!(
            "== Table II: BNN architectures (from the AOT manifest) =="
        );
        let manifest = &session.runtime()?.manifest;
        let mut t = Table::new(&[
            "model", "architecture", "params", "matmuls", "MHL margin",
        ]);
        for (name, m) in &manifest.models {
            if name == "vgg3_tiny" {
                continue; // test-only twin
            }
            t.row(vec![
                name.clone(),
                m.description.clone(),
                m.n_params.to_string(),
                m.n_matmuls.to_string(),
                format!("{}", m.mhl_b),
            ]);
        }
        println!("{}", t.render());
        if !manifest.full {
            println!(
                "(CPU-budget widths; `make artifacts` with --full \
                 restores the paper's exact channel plan — DESIGN.md §6)"
            );
        }
        return Ok(());
    }
    let _ = &session;
    println!(
        "== Table II: BNN architectures (native registry, DESIGN.md \
         §9) =="
    );
    let mut t = Table::new(&[
        "model", "architecture", "binary weights", "matmuls",
    ]);
    for name in crate::backend::arch::model_names() {
        if name == "vgg3_tiny" {
            continue; // test-only twin
        }
        let m = crate::backend::arch::model_meta(name)?;
        t.row(vec![
            name.to_string(),
            m.describe(),
            m.n_weight_bits().to_string(),
            m.n_matmuls().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "(CPU-budget widths; `make artifacts` with --full restores \
         the paper's exact channel plan — DESIGN.md §6)"
    );
    Ok(())
}
