//! Training driver: Rust owns the loop, LR schedule, batching and
//! logging; the AOT train-step artifact owns the math (fwd/bwd/Adam).

use anyhow::Result;

use crate::data::Loader;
use crate::runtime::{
    lit_f32, lit_f32_scalar, lit_u32, lit_zeros, to_f32_scalar,
    Runtime,
};

/// Trained model: the init/train artifacts' params+state literals, plus
/// the loss curve for EXPERIMENTS.md.
pub struct Trained {
    pub model: String,
    pub params_state: Vec<xla::Literal>,
    pub losses: Vec<f32>,
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime) -> Trainer<'rt> {
        Trainer { rt }
    }

    /// Train `model` on `loader` for `steps` steps. The LR halving
    /// schedule mirrors the paper (halve every `halve_every` steps).
    pub fn train(
        &self,
        model: &str,
        loader: &mut Loader,
        steps: usize,
        lr0: f64,
        halve_every: usize,
        seed: u64,
        log: &mut dyn FnMut(usize, f32),
    ) -> Result<Trained> {
        let mi = self.rt.manifest.model(model);
        let init = self.rt.load(model, "init")?;
        let train = self.rt.load(model, "train")?;

        let key = lit_u32(&[2], &[(seed >> 32) as u32, seed as u32])?;
        let mut params_state = init.run(&[key])?;
        let np = mi.n_params;

        // Adam state starts at zero
        let mut m: Vec<xla::Literal> = Vec::with_capacity(np);
        let mut v: Vec<xla::Literal> = Vec::with_capacity(np);
        for sig in &train.sig.inputs[mi.n_params + mi.n_state..]
            [..mi.n_params]
        {
            m.push(lit_zeros(&sig.shape)?);
        }
        for sig in &train.sig.inputs
            [2 * mi.n_params + mi.n_state..][..mi.n_params]
        {
            v.push(lit_zeros(&sig.shape)?);
        }

        let in_shape = &mi.in_shape;
        let tb = mi.train_batch;
        let x_shape =
            [&[tb], in_shape.as_slice()].concat();
        let mut losses = Vec::with_capacity(steps);
        for step in 1..=steps {
            let batch = loader.next_batch();
            let lr = lr0 * 0.5f64.powi((step / halve_every.max(1)) as i32);
            let x = lit_f32(&x_shape, &batch.x)?;
            let y = lit_f32(&[tb, mi.n_classes], &batch.y_pm)?;
            let mut inputs: Vec<&xla::Literal> =
                params_state.iter().collect();
            inputs.extend(m.iter());
            inputs.extend(v.iter());
            let step_l = lit_f32_scalar(step as f32);
            let lr_l = lit_f32_scalar(lr as f32);
            inputs.push(&step_l);
            inputs.push(&lr_l);
            inputs.push(&x);
            inputs.push(&y);
            let mut outs = train.run_borrowed(&inputs)?;
            let loss = to_f32_scalar(outs.last().unwrap())?;
            losses.push(loss);
            outs.pop();
            let vv: Vec<xla::Literal> = outs.split_off(
                mi.n_params + mi.n_state + np,
            );
            let mm: Vec<xla::Literal> =
                outs.split_off(mi.n_params + mi.n_state);
            params_state = outs;
            m = mm;
            v = vv;
            log(step, loss);
        }
        Ok(Trained {
            model: model.to_string(),
            params_state,
            losses,
        })
    }

    /// Fold a trained model into the hardware tensors (export artifact).
    pub fn export(&self, trained: &Trained) -> Result<Vec<xla::Literal>> {
        let export = self.rt.load(&trained.model, "export")?;
        let refs: Vec<&xla::Literal> =
            trained.params_state.iter().collect();
        export.run_borrowed(&refs)
    }

    /// Clean train-split loss-proxy evaluation is done by the evaluator on
    /// the folded model; the trainer only reports the loss curve.
    pub fn final_loss(trained: &Trained) -> f32 {
        *trained.losses.last().unwrap_or(&f32::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Dataset;
    use crate::data::Split;

    #[test]
    fn tiny_model_trains_and_loss_drops() {
        if !crate::runtime::artifacts_dir().join("manifest.json").exists()
        {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::new().unwrap();
        let tr = Trainer::new(&rt);
        let mut loader = Loader::new(
            Dataset::FashionSyn.spec(),
            Split::Train,
            rt.manifest.model("vgg3_tiny").train_batch,
            256,
            1,
        );
        let trained = tr
            .train("vgg3_tiny", &mut loader, 25, 1e-2, 1000, 7,
                   &mut |_, _| {})
            .unwrap();
        assert_eq!(trained.losses.len(), 25);
        let first = trained.losses[..5].iter().sum::<f32>() / 5.0;
        let last = trained.losses[20..].iter().sum::<f32>() / 5.0;
        assert!(
            last < first,
            "loss should fall: {first} -> {last} ({:?})",
            trained.losses
        );
        // export folds to the manifest's folded signature
        let folded = tr.export(&trained).unwrap();
        assert_eq!(
            folded.len(),
            rt.manifest.model("vgg3_tiny").n_folded
        );
    }
}
