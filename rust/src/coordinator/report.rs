//! Low-level result persistence: JSON series into
//! `runs/results_*.json` (EXPERIMENTS.md references these), plus the
//! `pct`/`ratio` formatting helpers the plan reductions share.
//!
//! Since the plan engine (DESIGN.md §10) this is the storage backend
//! of the unified reporter — `plan::report::persist_series` writes
//! every `Section::Series` through [`Report::save_series`], so the
//! file format (and its consumers) survived the refactor unchanged.

use anyhow::Result;

use super::store::Store;
use crate::util::json::{arr_f64, obj, Json};

pub struct Report<'s> {
    pub store: &'s Store,
}

impl<'s> Report<'s> {
    pub fn new(store: &'s Store) -> Report<'s> {
        Report { store }
    }

    /// Persist a named result series (figure data) as JSON.
    pub fn save_series(
        &self,
        name: &str,
        meta: Vec<(&str, Json)>,
        series: Vec<(&str, Vec<f64>)>,
    ) -> Result<()> {
        let mut fields = meta;
        let mut s = vec![];
        for (k, v) in series {
            s.push((k, arr_f64(&v)));
        }
        fields.push(("series", obj(s)));
        self.store
            .save_text(&format!("results_{name}.json"),
                       &obj(fields).to_string())?;
        Ok(())
    }
}

/// Format an accuracy as the paper plots it.
pub fn pct(a: f64) -> String {
    format!("{:.1}%", 100.0 * a)
}

/// Format a multiplicative ratio.
pub fn ratio(r: f64) -> String {
    format!("{r:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::Store;

    #[test]
    fn saves_parseable_series() {
        let dir = std::env::temp_dir().join(format!(
            "capmin_report_test_{}",
            std::process::id()
        ));
        let store = Store::new(dir.to_str().unwrap()).unwrap();
        let r = Report::new(&store);
        r.save_series(
            "fig8_test",
            vec![("dataset", Json::Str("x".into()))],
            vec![("k", vec![32.0, 16.0]), ("acc", vec![0.9, 0.8])],
        )
        .unwrap();
        let text = std::fs::read_to_string(
            store.path("results_fig8_test.json"),
        )
        .unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(
            j.req("series").req("acc").as_arr()[1].as_f64(),
            0.8
        );
    }

    #[test]
    fn formatting() {
        assert_eq!(pct(0.914), "91.4%");
        assert_eq!(ratio(14.083), "14.08x");
    }
}
