//! Experiment configuration: defaults sized for the single-core CPU
//! testbed, every knob overridable from the CLI (DESIGN.md §6).

use anyhow::{anyhow, ensure, Result};

use crate::util::cli::Args;

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Training steps per model (paper: 100-200 epochs on GPUs; the
    /// synthetic tasks converge in a few hundred steps).
    pub train_steps: usize,
    /// Initial learning rate (paper: 1e-3; halved on a schedule).
    pub lr0: f64,
    /// Halve the LR every this many steps (paper: every 10th/50th epoch).
    pub lr_halve_every: usize,
    /// Training-set subset size (0 = full Table I size).
    pub train_limit: usize,
    /// Test-set subset for accuracy sweeps.
    pub eval_limit: usize,
    /// Training-set subset for F_MAC extraction.
    pub hist_limit: usize,
    /// Relative current variation sigma (paper's process variation).
    pub sigma_rel: f64,
    /// Monte-Carlo samples per spike time (paper: 1000). In `--mc
    /// fast` this is the per-level draw budget cap.
    pub mc_samples: usize,
    /// Monte-Carlo solve mode: "paper" (fixed-draw Sec. IV-C),
    /// "fast" (stratified antithetic draws + Wilson early stopping),
    /// or "analytic" (closed-form oracle, zero draws) — DESIGN.md
    /// §15. Part of the hw cache key (modes agree statistically, not
    /// bitwise).
    pub mc_mode: String,
    /// Fast-mode stopping tolerance: target per-bucket 95% Wilson
    /// half-width (also folded into the hw key in fast mode).
    pub mc_tol: f64,
    /// k values of the Fig. 8 sweep.
    pub ks: Vec<usize>,
    /// Seeds for variation runs (paper: average of 3).
    pub n_seeds: usize,
    /// Evaluation engine artifact: "eval" (jnp) or "evalp" (Pallas).
    /// Only meaningful on the XLA backend.
    pub engine: String,
    /// Inference backend: "native" (host sub-MAC engine, no XLA),
    /// "xla" (AOT artifacts through PJRT), or "auto" (xla when the
    /// build and machine have it, else native) — DESIGN.md §9.
    pub backend: String,
    /// Worker threads for solve batches, MC level sweeps and native
    /// kernels (0 = all cores, resolved through
    /// `std::thread::available_parallelism`). Never changes results —
    /// the *resolved* count is recorded in point metadata, not cache
    /// keys.
    pub threads: usize,
    /// Native microkernel tier: "auto" (runtime CPU detection),
    /// "scalar" (portable fallback), or an explicit SIMD tier
    /// ("avx2"/"avx512"/"neon", accepted only when detected) —
    /// DESIGN.md §11. Never changes results (kernels are
    /// bit-identical); the resolved tier is recorded in point
    /// metadata, not cache keys.
    pub kernel: String,
    /// Register-blocking tile for the exact matmuls: "auto"
    /// (per-machine autotune, cached in `<run_dir>/autotune.json`),
    /// an explicit "MRxNR[kKB]" (e.g. "4x8" or "4x8k32"), or
    /// "scalar-safe" (bypass the blocked path entirely) — DESIGN.md
    /// §14. Never changes results; the resolved tile is recorded in
    /// point metadata, not cache keys.
    pub tile: String,
    /// Directory for cached runs (trained weights, F_MACs, results).
    pub run_dir: String,
    /// Persist operating points to `<run_dir>/points/` (DESIGN.md §7);
    /// `--no-point-cache` disables the disk layer for cold-path timing.
    pub point_cache: bool,
    /// Base seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            train_steps: 300,
            lr0: 1e-2,
            lr_halve_every: 100,
            train_limit: 4096,
            eval_limit: 256,
            hist_limit: 512,
            sigma_rel: 0.02,
            mc_samples: 1000,
            mc_mode: "paper".to_string(),
            mc_tol: crate::analog::montecarlo::MC_DEFAULT_TOL,
            ks: vec![32, 28, 24, 20, 18, 16, 14, 12, 10, 8, 6, 5],
            n_seeds: 3,
            engine: "eval".to_string(),
            backend: "auto".to_string(),
            threads: 0,
            kernel: "auto".to_string(),
            tile: "auto".to_string(),
            run_dir: "runs".to_string(),
            point_cache: true,
            seed: 42,
        }
    }
}

impl ExperimentConfig {
    pub fn from_args(args: &Args) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        if args.flag("quick") {
            // smoke-test scale: seconds, not minutes
            c.train_steps = 30;
            c.train_limit = 256;
            c.eval_limit = 64;
            c.hist_limit = 64;
            c.mc_samples = 200;
            c.ks = vec![32, 24, 16, 14, 10, 6];
            c.n_seeds = 1;
        }
        if args.flag("paper-scale") {
            // full Table I splits + paper step counts; hours of CPU time
            c.train_steps = 2000;
            c.train_limit = 0;
            c.eval_limit = 0;
            c.hist_limit = 4096;
        }
        c.train_steps = args.usize_or("steps", c.train_steps);
        c.lr0 = args.f64_or("lr", c.lr0);
        c.lr_halve_every =
            args.usize_or("lr-halve-every", c.lr_halve_every);
        c.train_limit = args.usize_or("train-limit", c.train_limit);
        c.eval_limit = args.usize_or("eval-limit", c.eval_limit);
        c.hist_limit = args.usize_or("hist-limit", c.hist_limit);
        c.sigma_rel = args.f64_or("sigma", c.sigma_rel);
        c.mc_samples = args.usize_or("mc-samples", c.mc_samples);
        if let Some(mode) =
            args.choice("mc", crate::analog::montecarlo::McMode::CHOICES)?
        {
            c.mc_mode = mode;
        }
        c.mc_tol = args.f64_or("mc-tol", c.mc_tol);
        ensure!(
            c.mc_tol > 0.0 && c.mc_tol < 0.5,
            "bad --mc-tol `{}`: expected a probability half-width in \
             (0, 0.5)",
            c.mc_tol
        );
        c.n_seeds = args.usize_or("seeds", c.n_seeds);
        if let Some(engine) = args.choice("engine", &["eval", "evalp"])?
        {
            c.engine = engine;
        }
        c.backend = args.str_or("backend", &c.backend);
        // validate early so a typo fails before any work happens
        crate::backend::BackendKind::parse(&c.backend)?;
        c.threads = args.usize_or("threads", c.threads);
        if let Some(kernel) =
            args.choice("kernel", crate::backend::kernels::KernelKind::CHOICES)?
        {
            c.kernel = kernel;
        }
        // validate the shape early (the session re-parses to resolve)
        if let Some(tile) = args.validated("tile", |s| {
            crate::backend::kernels::TileSpec::parse(s)
                .map(|_| s.to_string())
        })? {
            c.tile = tile;
        }
        c.run_dir = args.str_or("run-dir", &c.run_dir);
        c.point_cache = !args.flag("no-point-cache");
        c.seed = args.usize_or("seed", c.seed as usize) as u64;
        if let Some(ks) = args.get("ks") {
            c.ks = ks
                .split(',')
                .map(|s| {
                    s.trim().parse::<usize>().map_err(|_| {
                        anyhow!(
                            "bad --ks entry `{}`: expected a \
                             comma-separated list of integers, e.g. \
                             --ks 32,24,16,14,10,6",
                            s.trim()
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            for &k in &c.ks {
                ensure!(
                    (1..=32).contains(&k),
                    "bad --ks entry `{k}`: CapMin k must be in 1..=32"
                );
            }
            ensure!(!c.ks.is_empty(), "--ks must list at least one k");
        }
        Ok(c)
    }

    /// The Monte-Carlo knob bundle the solver consumes. Errors only on
    /// an invalid `mc_mode` string (CLI paths validate at parse time;
    /// this covers hand-built configs).
    pub fn mc_settings(
        &self,
    ) -> Result<crate::analog::montecarlo::McSettings> {
        Ok(crate::analog::montecarlo::McSettings {
            mode: crate::analog::montecarlo::McMode::parse(
                &self.mc_mode,
            )?,
            samples: self.mc_samples,
            tol: self.mc_tol,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let c = ExperimentConfig::from_args(&parse(&["x"])).unwrap();
        assert_eq!(c.train_steps, 300);
        assert!(c.point_cache);
        let c = ExperimentConfig::from_args(&parse(&[
            "x", "--steps", "7", "--sigma", "0.05", "--ks", "32,16,8",
            "--no-point-cache",
        ]))
        .unwrap();
        assert_eq!(c.train_steps, 7);
        assert_eq!(c.sigma_rel, 0.05);
        assert_eq!(c.ks, vec![32, 16, 8]);
        assert!(!c.point_cache);
    }

    #[test]
    fn quick_mode_shrinks_everything() {
        let c = ExperimentConfig::from_args(&parse(&["x", "--quick"]))
            .unwrap();
        assert!(c.train_steps <= 30);
        assert!(c.eval_limit <= 64);
        assert_eq!(c.n_seeds, 1);
    }

    #[test]
    fn backend_and_threads_flags() {
        let c = ExperimentConfig::from_args(&parse(&["x"])).unwrap();
        assert_eq!(c.backend, "auto");
        assert_eq!(c.threads, 0);
        let c = ExperimentConfig::from_args(&parse(&[
            "x", "--backend", "native", "--threads", "3",
        ]))
        .unwrap();
        assert_eq!(c.backend, "native");
        assert_eq!(c.threads, 3);
        let e = ExperimentConfig::from_args(&parse(&[
            "x", "--backend", "tpu",
        ]))
        .unwrap_err();
        assert!(e.to_string().contains("tpu"), "{e}");
    }

    #[test]
    fn kernel_flag_validates_choices() {
        let c = ExperimentConfig::from_args(&parse(&["x"])).unwrap();
        assert_eq!(c.kernel, "auto");
        let c = ExperimentConfig::from_args(&parse(&[
            "x", "--kernel", "scalar",
        ]))
        .unwrap();
        assert_eq!(c.kernel, "scalar");
        let e = ExperimentConfig::from_args(&parse(&[
            "x", "--kernel", "sse9",
        ]))
        .unwrap_err();
        assert!(e.to_string().contains("sse9"), "{e}");
    }

    #[test]
    fn tile_flag_validates_shape() {
        let c = ExperimentConfig::from_args(&parse(&["x"])).unwrap();
        assert_eq!(c.tile, "auto");
        for good in ["auto", "scalar-safe", "4x8", "2x4k16"] {
            let c = ExperimentConfig::from_args(&parse(&[
                "x", "--tile", good,
            ]))
            .unwrap();
            assert_eq!(c.tile, good);
        }
        let e = ExperimentConfig::from_args(&parse(&[
            "x", "--tile", "3x5",
        ]))
        .unwrap_err();
        assert!(e.to_string().contains("3x5"), "{e}");
        assert!(e.to_string().contains("scalar-safe"), "{e}");
    }

    #[test]
    fn mc_flag_validates_choices_and_tol() {
        use crate::analog::montecarlo::{McMode, MC_DEFAULT_TOL};
        let c = ExperimentConfig::from_args(&parse(&["x"])).unwrap();
        assert_eq!(c.mc_mode, "paper");
        assert_eq!(c.mc_tol, MC_DEFAULT_TOL);
        let s = c.mc_settings().unwrap();
        assert_eq!(s.mode, McMode::Paper);
        assert_eq!(s.samples, 1000);
        let c = ExperimentConfig::from_args(&parse(&[
            "x", "--mc", "fast", "--mc-tol", "0.02",
        ]))
        .unwrap();
        assert_eq!(c.mc_mode, "fast");
        assert_eq!(c.mc_tol, 0.02);
        assert_eq!(c.mc_settings().unwrap().mode, McMode::Fast);
        let e = ExperimentConfig::from_args(&parse(&[
            "x", "--mc", "spice",
        ]))
        .unwrap_err();
        assert!(e.to_string().contains("spice"), "{e}");
        let e = ExperimentConfig::from_args(&parse(&[
            "x", "--mc-tol", "0.7",
        ]))
        .unwrap_err();
        assert!(e.to_string().contains("0.7"), "{e}");
    }

    #[test]
    fn bad_ks_is_an_error_naming_the_value() {
        let e = ExperimentConfig::from_args(&parse(&[
            "x", "--ks", "32,banana",
        ]))
        .unwrap_err();
        assert!(e.to_string().contains("banana"), "{e}");
        let e = ExperimentConfig::from_args(&parse(&["x", "--ks", "0,4"]))
            .unwrap_err();
        assert!(e.to_string().contains("1..=32"), "{e}");
    }
}
