//! Hardware-mode accuracy evaluation: run the eval artifact (grouped
//! sub-MAC path, jnp or Pallas engine) with *per-matmul* level-transition
//! CDFs as runtime inputs — CapMin clipping, Monte-Carlo variation, and
//! CapMin-V merged read-outs are all just different matrices, so a whole
//! k-sweep reuses one compiled executable.

use anyhow::Result;

use crate::bnn::ErrorModel;
use crate::capmin::N_LEVELS;
use crate::data::{Loader, Split};
use crate::runtime::{lit_f32, lit_u32_scalar, to_f32, Runtime};
use crate::util::stats::argmax;

pub struct Evaluator<'rt> {
    pub rt: &'rt Runtime,
    /// "eval" (jnp engine) or "evalp" (Pallas kernel engine).
    pub engine: String,
}

/// Stack per-matmul error models into the artifacts' [n_mat, 33, 33] cdf
/// and [n_mat, 33] vals input tensors.
pub fn stack_error_models(ems: &[ErrorModel]) -> (Vec<f32>, Vec<f32>) {
    let mut cdf = Vec::with_capacity(ems.len() * N_LEVELS * N_LEVELS);
    let mut vals = Vec::with_capacity(ems.len() * N_LEVELS);
    for em in ems {
        cdf.extend_from_slice(&em.cdf);
        vals.extend_from_slice(&em.vals);
    }
    (cdf, vals)
}

impl<'rt> Evaluator<'rt> {
    pub fn new(rt: &'rt Runtime, engine: &str) -> Evaluator<'rt> {
        Evaluator {
            rt,
            engine: engine.to_string(),
        }
    }

    /// Accuracy of `folded` on the test split under per-matmul error
    /// models `ems`, over `limit` samples, with PRNG seed `seed`.
    pub fn accuracy(
        &self,
        model: &str,
        folded: &[xla::Literal],
        spec: crate::data::synth::DatasetSpec,
        ems: &[ErrorModel],
        limit: usize,
        seed: u32,
    ) -> Result<f64> {
        let mi = self.rt.manifest.model(model);
        anyhow::ensure!(
            ems.len() == mi.n_matmuls,
            "need {} error models, got {}",
            mi.n_matmuls,
            ems.len()
        );
        let eval = self.rt.load(model, &self.engine)?;
        let eb = mi.eval_batch;
        let x_shape = [&[eb], mi.in_shape.as_slice()].concat();
        let mut loader = Loader::new(spec, Split::Test, eb, limit, 0xE7A1);
        let n_batches = (limit / eb).max(1);

        let (cdf_v, vals_v) = stack_error_models(ems);
        let cdf = lit_f32(&[mi.n_matmuls, N_LEVELS, N_LEVELS], &cdf_v)?;
        let vals = lit_f32(&[mi.n_matmuls, N_LEVELS], &vals_v)?;
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let batch = loader.next_batch();
            let x = lit_f32(&x_shape, &batch.x)?;
            // per-batch seed: decorrelates batches within one run
            let seed_l =
                lit_u32_scalar(seed.wrapping_add(bi as u32 * 0x9E37));
            let mut inputs: Vec<&xla::Literal> = folded.iter().collect();
            inputs.push(&x);
            inputs.push(&cdf);
            inputs.push(&vals);
            inputs.push(&seed_l);
            let outs = eval.run_borrowed(&inputs)?;
            let logits = to_f32(&outs[0])?;
            for (i, &label) in batch.labels.iter().enumerate() {
                let row =
                    &logits[i * mi.n_classes..(i + 1) * mi.n_classes];
                if argmax(row) == label {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Mean accuracy over `n_seeds` PRNG seeds (paper: average of 3 runs
    /// for the variation curves).
    pub fn accuracy_multi_seed(
        &self,
        model: &str,
        folded: &[xla::Literal],
        spec: crate::data::synth::DatasetSpec,
        ems: &[ErrorModel],
        limit: usize,
        n_seeds: usize,
        base_seed: u32,
    ) -> Result<f64> {
        let mut acc = 0.0;
        for s in 0..n_seeds {
            acc += self.accuracy(
                model,
                folded,
                spec.clone(),
                ems,
                limit,
                base_seed.wrapping_add(s as u32 * 7919),
            )?;
        }
        Ok(acc / n_seeds as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stacking_preserves_layout() {
        let a = ErrorModel::identity();
        let mut b = ErrorModel::identity();
        b.vals[0] = 5.0;
        let (cdf, vals) = stack_error_models(&[a.clone(), b]);
        assert_eq!(cdf.len(), 2 * 33 * 33);
        assert_eq!(vals.len(), 2 * 33);
        assert_eq!(vals[33], 5.0);
        assert_eq!(&cdf[..33 * 33], a.cdf.as_slice());
    }
}
