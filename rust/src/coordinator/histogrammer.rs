//! F_MAC extraction: forward passes over the training set through the
//! hist artifact, accumulating per-matmul and summed level histograms
//! (the SW half of CapMin; paper Fig. 1 / Sec. IV-B).

use anyhow::Result;

use crate::capmin::{Fmac, N_LEVELS};
use crate::data::{Loader, Split};
use crate::runtime::{lit_f32, to_f32, Runtime};
use crate::util::stats::argmax;

pub struct HistResult {
    pub per_matmul: Vec<Fmac>,
    pub sum: Fmac,
    /// Clean accuracy measured on the same passes (sanity signal).
    pub accuracy: f64,
    pub n_samples: usize,
}

pub struct Histogrammer<'rt> {
    pub rt: &'rt Runtime,
}

impl<'rt> Histogrammer<'rt> {
    pub fn new(rt: &'rt Runtime) -> Histogrammer<'rt> {
        Histogrammer { rt }
    }

    /// Run `limit` training samples of `dataset` through the model's hist
    /// artifact (batch size fixed by the manifest).
    pub fn extract(
        &self,
        model: &str,
        folded: &[xla::Literal],
        loader: &mut Loader,
        limit: usize,
    ) -> Result<HistResult> {
        let mi = self.rt.manifest.model(model);
        let hist = self.rt.load(model, "hist")?;
        let hb = mi.hist_batch;
        let x_shape = [&[hb], mi.in_shape.as_slice()].concat();
        let n_batches = (limit / hb).max(1);

        let mut per = vec![Fmac::new(); mi.n_matmuls];
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..n_batches {
            let batch = loader.next_batch();
            let x = lit_f32(&x_shape, &batch.x)?;
            let mut inputs: Vec<&xla::Literal> = folded.iter().collect();
            inputs.push(&x);
            let outs = hist.run_borrowed(&inputs)?;
            let fmac = to_f32(&outs[0])?; // [n_matmuls, 33]
            for (i, f) in per.iter_mut().enumerate() {
                f.add_f32(&fmac[i * N_LEVELS..(i + 1) * N_LEVELS]);
            }
            let logits = to_f32(&outs[1])?;
            for (i, &label) in batch.labels.iter().enumerate() {
                let row =
                    &logits[i * mi.n_classes..(i + 1) * mi.n_classes];
                if argmax(row) == label {
                    correct += 1;
                }
                total += 1;
            }
        }
        let mut sum = Fmac::new();
        for f in &per {
            sum.merge(f);
        }
        Ok(HistResult {
            per_matmul: per,
            sum,
            accuracy: correct as f64 / total.max(1) as f64,
            n_samples: total,
        })
    }

    /// Convenience: loader construction + extraction.
    pub fn extract_dataset(
        &self,
        model: &str,
        folded: &[xla::Literal],
        spec: crate::data::synth::DatasetSpec,
        limit: usize,
        seed: u64,
    ) -> Result<HistResult> {
        let mi = self.rt.manifest.model(model);
        let mut loader =
            Loader::new(spec, Split::Train, mi.hist_batch, limit, seed);
        self.extract(model, folded, &mut loader, limit)
    }
}
